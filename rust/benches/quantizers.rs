//! Bench: end-to-end quantizer wall-clock per method (the Table 4
//! duration column, regenerated on this host at tiny scale) plus the
//! layer-level kernels of the host-side baselines (GPTQ column loop,
//! AWQ grid search, LoftQ SVD iteration).

use repro::benchharness::Bench;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::model::TINY;
use repro::pipeline::{DEFAULT_GROUP, DEFAULT_RANK};
use repro::quant::QuantSpec;
use repro::quantizers::{by_name, AwqLite, Gptq, LoftQ, QuantizeCtx};
use repro::runtime::Runtime;
use repro::tensor::{Rng, Tensor};

fn main() {
    let mut bench = Bench::new();

    // --- layer-level kernels (no artifacts needed) ---
    let mut rng = Rng::new(2);
    let (d_in, d_out) = (256, 256);
    let w = Tensor::randn(&[d_in, d_out], 0.1, &mut rng);
    let x = Tensor::randn(&[512, d_in], 1.0, &mut rng);
    let spec = QuantSpec::new(2, 64);

    let h = x.transpose().unwrap().matmul(&x).unwrap().scale(2.0);
    bench.run("gptq_layer_256x256", 1, 3, || {
        std::hint::black_box(Gptq::default().quantize_layer(&w, &h, spec).unwrap());
    });
    bench.run("awq_layer_256x256", 1, 3, || {
        std::hint::black_box(AwqLite::default().quantize_layer(&w, &x, spec).unwrap());
    });
    let mut srng = Rng::new(3);
    bench.run("loftq_layer_256x256_r16", 1, 3, || {
        std::hint::black_box(
            LoftQ::default().decompose(&w, 2, 64, 16, &mut srng).unwrap(),
        );
    });

    // --- whole-model quantization (needs artifacts + a model) ---
    let Ok(runtime) = Runtime::new("artifacts") else {
        bench.finish("quantizers (no PJRT)");
        return;
    };
    if !runtime.has_artifact("bw_calib_tiny_r16_g64") {
        println!("note  artifacts missing; skipping whole-model benches");
        bench.finish("quantizers");
        return;
    }
    let params = TINY.init_params(11);
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 11);
    let batcher = Batcher::new(TINY.calib_batch, TINY.seq_len);
    let mut crng = Rng::new(12);
    let calib: Vec<_> = (0..2).map(|_| batcher.lm_batch(&corpus, &mut crng)).collect();

    for method in ["rtn", "qlora", "gptq", "awq", "loftq", "omniquant", "apiq-lw", "apiq-bw"] {
        let q = by_name(method).unwrap();
        let ctx = QuantizeCtx {
            runtime: &runtime,
            cfg: TINY,
            params: &params,
            spec,
            rank: DEFAULT_RANK,
            scale: 1.0,
            calib: &calib,
            seed: 5,
            verbose: false,
        };
        // single iteration: these are seconds-scale "Table 4 duration" runs
        bench.run(&format!("quantize_tiny_2bit_{method}"), 0, 1, || {
            std::hint::black_box(q.quantize(&ctx).unwrap());
        });
    }
    bench.note("Table 4 shape check: gptq fastest; apiq-bw ~3-4x faster than apiq-lw".to_string());
    bench.finish("quantizers");
}
