//! Bench: KV-cached incremental decode vs full-prefix recompute, plus
//! the kernel-trajectory artifact.
//!
//! The acceptance metric for the serving subsystem: decode cost per
//! emitted token must stop growing linearly with prefix length.  Runs
//! the tiny config (CI-sized) across increasing new-token budgets and
//! reports tokens/s for both paths plus the speedup, a per-step latency
//! curve for the cached path at growing prefix lengths, and — since the
//! SIMD compute core landed — a batch-1 decode measurement written to
//! `BENCH_kernels.json` (override the path with `REPRO_BENCH_OUT`) so
//! the tokens/s + GFLOP/s trajectory is machine-readable per kernel
//! variant and thread count.

use repro::benchharness::Bench;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::kernels;
use repro::model::{ModelConfig, TINY};
use repro::quant::QuantSpec;
use repro::quantizers::{QuantizeCtx, Quantizer, Rtn};
use repro::runtime::Runtime;
use repro::serve::decode::{generate, generate_recompute};
use repro::serve::spec::generate_speculative;
use repro::serve::KvCache;
use repro::tensor::Rng;

/// FLOPs the linear layers spend per decoded token (2 per MAC; the
/// attention dot-products are prefix-dependent and excluded, so this is
/// the weight-streaming GFLOP/s the fused kernels sustain).
fn linear_flops_per_token(cfg: &ModelConfig) -> f64 {
    let d = cfg.d_model as f64;
    let f = cfg.d_ffn as f64;
    let v = cfg.vocab as f64;
    2.0 * (cfg.n_layers as f64 * (4.0 * d * d + 3.0 * d * f) + d * v)
}

struct JsonEntry {
    name: String,
    tokens_per_sec: f64,
    gflops: f64,
}

fn write_kernels_json(cfg: &ModelConfig, entries: &[JsonEntry]) {
    let path =
        std::env::var("REPRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    let mut results = String::new();
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            results.push_str(",\n");
        }
        results.push_str(&format!(
            "    {{\"name\": \"{}\", \"tokens_per_sec\": {:.2}, \"gflops\": {:.3}}}",
            e.name, e.tokens_per_sec, e.gflops
        ));
    }
    let json = format!(
        "{{\n  \"schema\": \"bench_kernels_v1\",\n  \"config\": \"{}\",\n  \
         \"kernel\": \"{}\",\n  \"simd_supported\": {},\n  \"threads\": {},\n  \
         \"linear_flops_per_token\": {:.0},\n  \"results\": [\n{}\n  ]\n}}\n",
        cfg.name,
        kernels::active().name(),
        kernels::simd_supported(),
        repro::kernels::pool::pool_threads(),
        linear_flops_per_token(cfg),
        results
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("note  wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// One per-k entry of the speculative-decode sweep.
struct SpecEntry {
    k: usize,
    tokens_per_sec: f64,
    acceptance: f64,
    proposed: usize,
    accepted: usize,
    draft_overhead: f64,
}

/// Merge the spec sweep into `BENCH_serve.json` (the serving-trajectory
/// artifact `repro bench-serve` writes): existing fields are kept, any
/// previous "spec" array is replaced.  Creates a minimal artifact when
/// none exists yet (e.g. the kernels CI job runs this bench alone).
fn merge_spec_into_bench_serve(entries: &[SpecEntry]) {
    use repro::serve::json::Json;
    let path = std::env::var("REPRO_BENCH_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut fields: Vec<(String, Json)> = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(s.trim()).ok())
    {
        Some(Json::Obj(prev)) => prev.into_iter().filter(|(k, _)| k != "spec").collect(),
        _ => vec![("bench".to_string(), Json::from("serve"))],
    };
    let arr: Vec<Json> = entries
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("k".to_string(), Json::from(e.k)),
                (
                    "tokens_per_sec".to_string(),
                    Json::Num((e.tokens_per_sec * 10.0).round() / 10.0),
                ),
                (
                    "acceptance".to_string(),
                    Json::Num((e.acceptance * 1000.0).round() / 1000.0),
                ),
                ("proposed".to_string(), Json::from(e.proposed)),
                ("accepted".to_string(), Json::from(e.accepted)),
                (
                    "draft_overhead".to_string(),
                    Json::Num((e.draft_overhead * 1000.0).round() / 1000.0),
                ),
            ])
        })
        .collect();
    fields.push(("spec".to_string(), Json::Arr(arr)));
    match std::fs::write(&path, Json::Obj(fields).render() + "\n") {
        Ok(()) => println!("note  merged spec sweep into {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

fn main() {
    let mut bench = Bench::new();
    let params = TINY.init_params(11);
    let runtime = Runtime::new("artifacts").unwrap();
    let ctx = QuantizeCtx {
        runtime: &runtime,
        cfg: TINY,
        params: &params,
        spec: QuantSpec::new(2, 64),
        rank: 16,
        scale: 1.0,
        calib: &[],
        seed: 5,
        verbose: false,
    };
    let r = Rtn.run(&ctx).unwrap();
    let model = PackedModel::from_quant_result(TINY, &r, 64, 1.0).unwrap();
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 7);
    let flops_tok = linear_flops_per_token(&TINY);
    let mut entries: Vec<JsonEntry> = Vec::new();

    println!(
        "kernel: {} (simd_supported: {}), threads: {}",
        kernels::active().name(),
        kernels::simd_supported(),
        repro::kernels::pool::pool_threads()
    );

    // --- batch-1 decode: the tentpole hot path ---
    let prompt_len = 16;
    let prompt1 = Batcher::new(1, prompt_len)
        .lm_batch(&corpus, &mut Rng::new(13))
        .tokens;
    for new_tokens in [64usize, 128] {
        let mean = bench
            .run(&format!("decode_cached_1x{new_tokens}"), 1, 3, || {
                std::hint::black_box(generate(&model, &prompt1, new_tokens, None).unwrap());
            })
            .mean_s;
        let tps = new_tokens as f64 / mean;
        bench.note(format!(
            "batch-1 decode, {new_tokens} new tokens: {tps:.0} tok/s \
             ({:.2} linear GFLOP/s)",
            tps * flops_tok / 1e9
        ));
        entries.push(JsonEntry {
            name: format!("decode_cached_1x{new_tokens}"),
            tokens_per_sec: tps,
            gflops: tps * flops_tok / 1e9,
        });
    }

    // --- end-to-end decode: cached vs recompute at growing budgets ---
    let gen_batch = 2;
    let prompt = Batcher::new(gen_batch, prompt_len)
        .lm_batch(&corpus, &mut Rng::new(9))
        .tokens;
    for new_tokens in [16usize, 64, 128] {
        let cached = bench
            .run(&format!("decode_cached_{gen_batch}x{new_tokens}"), 1, 3, || {
                std::hint::black_box(generate(&model, &prompt, new_tokens, None).unwrap());
            })
            .mean_s;
        let recompute = bench
            .run(&format!("decode_recompute_{gen_batch}x{new_tokens}"), 1, 3, || {
                std::hint::black_box(
                    generate_recompute(&model, &prompt, new_tokens, None).unwrap(),
                );
            })
            .mean_s;
        let toks = (gen_batch * new_tokens) as f64;
        bench.note(format!(
            "{new_tokens} new tokens: cached {:.0} tok/s vs recompute {:.0} tok/s ({:.2}x)",
            toks / cached,
            toks / recompute,
            recompute / cached
        ));
        entries.push(JsonEntry {
            name: format!("decode_cached_{gen_batch}x{new_tokens}"),
            tokens_per_sec: toks / cached,
            gflops: toks / cached * flops_tok / 1e9,
        });
    }

    // --- speculative decode: tokens/sec + acceptance per draft depth k ---
    // k = 0 is the no-speculation baseline through the same code path;
    // the draft is the target's own first-half prefix cut, so acceptance
    // reflects how well shallow layers track the full model.
    let draft = model.prefix_cut((TINY.n_layers / 2).max(1)).unwrap();
    let spec_new = 64usize;
    let mut spec_entries: Vec<SpecEntry> = Vec::new();
    for kk in [0usize, 2, 4, 8] {
        let mut last = None;
        let mean = bench
            .run(&format!("decode_spec_k{kk}"), 1, 3, || {
                let r =
                    generate_speculative(&model, &draft, &prompt1, spec_new, None, 16, kk).unwrap();
                last = Some(std::hint::black_box(r));
            })
            .mean_s;
        let rep = last.expect("at least one timed iteration");
        let tps = spec_new as f64 / mean;
        let acceptance = rep.acceptance();
        bench.note(format!(
            "speculative k={kk}: {tps:.0} tok/s, acceptance {:.1}% ({}/{}), \
             draft overhead {:.1}%",
            acceptance * 100.0,
            rep.accepted,
            rep.proposed,
            rep.draft_overhead() * 100.0
        ));
        spec_entries.push(SpecEntry {
            k: kk,
            tokens_per_sec: tps,
            acceptance,
            proposed: rep.proposed,
            accepted: rep.accepted,
            draft_overhead: rep.draft_overhead(),
        });
    }
    merge_spec_into_bench_serve(&spec_entries);

    // --- per-step latency at growing prefix: O(T) vs O(T^2) shape ---
    for prefix in [32usize, 128, 512] {
        let seq: Vec<i32> = (0..prefix as i32).map(|t| t % TINY.vocab as i32).collect();
        let mut cache = KvCache::new(TINY.n_layers, TINY.d_model, prefix + 8);
        model.forward_chunk(&seq, &mut cache).unwrap();
        let tok = [(prefix % TINY.vocab) as i32];
        let step_mean = bench
            .run(&format!("step_after_prefix_{prefix}"), 1, 5, || {
                // one single-token chunk against the warm cache (the 8
                // spare slots cover warmup + timed iterations)
                if cache.remaining() > 0 {
                    std::hint::black_box(model.forward_chunk(&tok, &mut cache).unwrap());
                }
            })
            .mean_s;
        bench.note(format!(
            "one cached step after {prefix}-token prefix: {:.3}ms",
            step_mean * 1e3
        ));
    }

    write_kernels_json(&TINY, &entries);
    bench.finish("decode");
}
