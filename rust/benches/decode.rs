//! Bench: KV-cached incremental decode vs full-prefix recompute.
//!
//! The acceptance metric for the serving subsystem: decode cost per
//! emitted token must stop growing linearly with prefix length.  Runs
//! the tiny config (CI-sized) across increasing new-token budgets and
//! reports tokens/s for both paths plus the speedup, and a per-step
//! latency curve for the cached path at growing prefix lengths.

use repro::benchharness::Bench;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::model::TINY;
use repro::quant::QuantSpec;
use repro::quantizers::{QuantizeCtx, Quantizer, Rtn};
use repro::runtime::Runtime;
use repro::serve::decode::{generate, generate_recompute};
use repro::serve::KvCache;
use repro::tensor::Rng;

fn main() {
    let mut bench = Bench::new();
    let params = TINY.init_params(11);
    let runtime = Runtime::new("artifacts").unwrap();
    let ctx = QuantizeCtx {
        runtime: &runtime,
        cfg: TINY,
        params: &params,
        spec: QuantSpec::new(2, 64),
        rank: 16,
        scale: 1.0,
        calib: &[],
        seed: 5,
        verbose: false,
    };
    let r = Rtn.run(&ctx).unwrap();
    let model = PackedModel::from_quant_result(TINY, &r, 64, 1.0).unwrap();
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 7);

    // --- end-to-end decode: cached vs recompute at growing budgets ---
    let gen_batch = 2;
    let prompt_len = 16;
    let prompt = Batcher::new(gen_batch, prompt_len)
        .lm_batch(&corpus, &mut Rng::new(9))
        .tokens;
    for new_tokens in [16usize, 64, 128] {
        let cached = bench
            .run(&format!("decode_cached_{gen_batch}x{new_tokens}"), 1, 3, || {
                std::hint::black_box(generate(&model, &prompt, new_tokens, None).unwrap());
            })
            .mean_s;
        let recompute = bench
            .run(&format!("decode_recompute_{gen_batch}x{new_tokens}"), 1, 3, || {
                std::hint::black_box(
                    generate_recompute(&model, &prompt, new_tokens, None).unwrap(),
                );
            })
            .mean_s;
        let toks = (gen_batch * new_tokens) as f64;
        bench.note(format!(
            "{new_tokens} new tokens: cached {:.0} tok/s vs recompute {:.0} tok/s ({:.2}x)",
            toks / cached,
            toks / recompute,
            recompute / cached
        ));
    }

    // --- per-step latency at growing prefix: O(T) vs O(T^2) shape ---
    for prefix in [32usize, 128, 512] {
        let seq: Vec<i32> = (0..prefix as i32).map(|t| t % TINY.vocab as i32).collect();
        let mut cache = KvCache::new(TINY.n_layers, TINY.d_model, prefix + 8);
        model.forward_chunk(&seq, &mut cache).unwrap();
        let tok = [(prefix % TINY.vocab) as i32];
        let step_mean = bench
            .run(&format!("step_after_prefix_{prefix}"), 1, 5, || {
                // one single-token chunk against the warm cache (the 8
                // spare slots cover warmup + timed iterations)
                if cache.remaining() > 0 {
                    std::hint::black_box(model.forward_chunk(&tok, &mut cache).unwrap());
                }
            })
            .mean_s;
        bench.note(format!(
            "one cached step after {prefix}-token prefix: {:.3}ms",
            step_mean * 1e3
        ));
    }

    bench.finish("decode");
}
