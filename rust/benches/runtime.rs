//! Bench: PJRT runtime hot path — artifact execute latency for the three
//! step kinds on the request path (eval forward, finetune step, bw-calib
//! step), plus the host<->literal marshalling overhead the L3 coordinator
//! adds on top of pure XLA execution.

use repro::benchharness::Bench;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::model::TINY;
use repro::quant::QuantSpec;
use repro::runtime::{Bindings, Runtime};
use repro::tensor::{Rng, Tensor};

fn main() {
    let mut bench = Bench::new();
    let Ok(runtime) = Runtime::new("artifacts") else {
        bench.finish("runtime (no PJRT)");
        return;
    };
    if !runtime.has_artifact("logits_fp_tiny") {
        println!("note  artifacts missing; run `make artifacts`");
        bench.finish("runtime");
        return;
    }

    let params = TINY.init_params(11);
    let qparams = TINY.init_qparams(QuantSpec::new(2, 64), 16, false, 12);
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 11);
    let batch = Batcher::new(TINY.batch, TINY.seq_len).lm_batch(&corpus, &mut Rng::new(13));
    let n_tok = (TINY.batch * TINY.seq_len) as f64;

    // eval forward, fp vs quantized (the pallas-kerneled path)
    let r = bench.run("exec_logits_fp_tiny", 2, 8, || {
        let bind = Bindings::new().group("params", &params).int("tokens", &batch.tokens);
        std::hint::black_box(runtime.run("logits_fp_tiny", &bind).unwrap());
    });
    let fp_mean = r.mean_s;
    bench.note(format!("fp forward: {:.0} tokens/s", n_tok / fp_mean));

    let r = bench.run("exec_logits_q_tiny_2bit", 2, 8, || {
        let bind = Bindings::new()
            .group("params", &params)
            .group("qparams", &qparams)
            .int("tokens", &batch.tokens)
            .scalar("bits", 2.0)
            .scalar("scale", 1.0);
        std::hint::black_box(runtime.run("logits_q_tiny_r16_g64", &bind).unwrap());
    });
    let q_mean = r.mean_s;
    bench.note(format!(
        "quantized forward: {:.0} tokens/s ({:.2}x fp)",
        n_tok / q_mean,
        q_mean / fp_mean
    ));

    // finetune step (fwd+bwd+adam in one execute)
    let trainable = |k: &str| k.ends_with("lora_a") || k.ends_with("lora_b");
    let m = qparams.filtered(trainable).zeros_like();
    let v = m.clone();
    bench.run("exec_finetune_step_tiny", 1, 5, || {
        let bind = Bindings::new()
            .group("params", &params)
            .group("qparams", &qparams)
            .group("m", &m)
            .group("v", &v)
            .int("tokens", &batch.tokens)
            .tensor("mask", &batch.mask)
            .scalar("t", 1.0)
            .scalar("lr", 1e-3)
            .scalar("wd", 0.0)
            .scalar("bits", 2.0)
            .scalar("scale", 1.0)
            .scalar("lr_attn_mul", 1.0)
            .scalar("lr_ffn_mul", 1.0);
        std::hint::black_box(runtime.run("finetune_step_tiny_r16_g64", &bind).unwrap());
    });

    // bw calibration step (the ApiQ inner loop)
    let bp = params.view("blocks.0.");
    let bqp = qparams.view("blocks.0.");
    let mb = bqp.zeros_like();
    let vb = mb.clone();
    let x = Tensor::zeros(&[TINY.calib_batch, TINY.seq_len, TINY.d_model]);
    bench.run("exec_bw_calib_step_tiny", 1, 5, || {
        let bind = Bindings::new()
            .group("bp", &bp)
            .group("bqp", &bqp)
            .group("m", &mb)
            .group("v", &vb)
            .tensor("x", &x)
            .tensor("xq", &x)
            .scalar("t", 1.0)
            .scalar("lr_ab", 1e-3)
            .scalar("lr_gb", 5e-3)
            .scalar("wd_ab", 0.0)
            .scalar("wd_gb", 0.0)
            .scalar("bits", 2.0)
            .scalar("scale", 1.0);
        std::hint::black_box(runtime.run("bw_calib_tiny_r16_g64", &bind).unwrap());
    });

    // marshalling overhead: Bindings -> literals without compute, measured
    // through the cheapest artifact (embed_fwd)
    let embed = params.get("embed").unwrap().clone();
    let toks = Batcher::new(TINY.calib_batch, TINY.seq_len)
        .lm_batch(&corpus, &mut Rng::new(14))
        .tokens;
    bench.run("exec_embed_fwd_tiny (marshal-dominated)", 2, 10, || {
        let bind = Bindings::new().tensor("embed", &embed).int("tokens", &toks);
        std::hint::black_box(runtime.run("embed_fwd_tiny", &bind).unwrap());
    });

    println!("\n{}", runtime.stats_report());
    bench.finish("runtime");
}
