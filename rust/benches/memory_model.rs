//! Bench/report: the Fig. 2 memory-accounting table at Llama-2-7B scale
//! (exact paper cross-check) and Table 4 quantization-peak predictions.
//! Analytic, so "benchmarking" here means validating the numbers against
//! the paper's and printing them for EXPERIMENTS.md.

use repro::benchharness::Bench;
use repro::metrics::memory::{ArchShape, MemoryBreakdown, MemoryModel, Regime};
use repro::quant::QuantSpec;

fn main() {
    let mut bench = Bench::new();
    let m = MemoryModel::new(ArchShape::llama2_7b());

    println!("Fig. 2 cross-check (Llama-2-7B, GB):");
    for (name, regime, paper_w, paper_opt) in [
        ("full-ft", Regime::FullFt, 12.6, 26.4),
        ("lora-r64", Regime::Lora { rank: 64 }, 12.6, 5.3),
        ("qlora-4bit-r64", Regime::QLora { rank: 64, spec: QuantSpec::new(4, 64) }, 4.6, 5.3),
    ] {
        let b = m.breakdown(regime);
        let w = MemoryBreakdown::gb(b.weights);
        let o = MemoryBreakdown::gb(b.optimizer);
        println!(
            "  {name:<16} weights {w:6.1} (paper {paper_w:5.1})   optimizer {o:6.1} (paper {paper_opt:5.1})   total {:6.1}",
            MemoryBreakdown::gb(b.total())
        );
        bench.note(format!(
            "{name}: weights {w:.1}GB vs paper {paper_w}GB ({:+.0}%), optimizer {o:.1}GB vs paper {paper_opt}GB",
            (w - paper_w) / paper_w * 100.0
        ));
    }

    println!("\nTable 4 peak-memory predictions (Llama-2-7B, 2-bit, GB):");
    let spec = QuantSpec::new(2, 64);
    let calib = 128 * 2048u64;
    for (method, paper_gb) in [
        ("gptq", 6.0),
        ("omniquant", 12.0),
        ("loftq", 14.0),
        ("apiq-lw", 6.0),
        ("apiq-bw", 12.0),
    ] {
        let gb = m.quantization_peak(method, spec, 64, calib) as f64 / 1e9;
        println!("  {method:<10} {gb:6.1} (paper {paper_gb:5.1})");
        bench.note(format!("{method}: peak {gb:.1}GB vs paper {paper_gb}GB"));
    }

    // time the model itself (trivially fast — the point is it's analytic)
    bench.run("memory_breakdown_eval", 10, 100, || {
        std::hint::black_box(m.breakdown(Regime::QLora { rank: 64, spec }));
    });
    bench.finish("memory_model");
}
