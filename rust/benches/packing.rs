//! Bench: bit-packing / unpacking and host fake-quant throughput — the
//! storage path every deployed quantized layer goes through (supports the
//! Fig. 2 / Table 4 storage-format claims with measured numbers).

use repro::benchharness::Bench;
use repro::quant::affine::{open_clip, quantize_ints};
use repro::quant::{fakequant, nf_fakequant, pack_codes, unpack_codes, QuantSpec};
use repro::tensor::{Rng, Tensor};

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::new(1);
    // Llama-2-7B's largest layer shape scaled down 4x per dim
    let (d_in, d_out) = (1024, 2752);
    let w = Tensor::randn(&[d_in, d_out], 0.1, &mut rng);
    let (g, b) = open_clip(d_in, d_out, 64);
    let n = d_in * d_out;

    for bits in [2u32, 3, 4] {
        let spec = QuantSpec::new(bits, 64);
        let (codes, _, _) = quantize_ints(&w, &g, &b, spec).unwrap();

        let r = bench.run(&format!("quantize_ints_{bits}bit_{d_in}x{d_out}"), 1, 5, || {
            std::hint::black_box(quantize_ints(&w, &g, &b, spec).unwrap());
        });
        let mean_s = r.mean_s;
        bench.note(format!(
            "quantize {bits}-bit: {:.1} Mweights/s",
            n as f64 / mean_s / 1e6
        ));

        let r = bench.run(&format!("pack_codes_{bits}bit"), 1, 10, || {
            std::hint::black_box(pack_codes(&codes, bits));
        });
        let mean_s = r.mean_s;
        bench.note(format!("pack {bits}-bit: {:.1} Mcodes/s", n as f64 / mean_s / 1e6));

        let packed = pack_codes(&codes, bits);
        let r = bench.run(&format!("unpack_codes_{bits}bit"), 1, 10, || {
            std::hint::black_box(unpack_codes(&packed, bits, n));
        });
        let mean_s = r.mean_s;
        bench.note(format!("unpack {bits}-bit: {:.1} Mcodes/s", n as f64 / mean_s / 1e6));
    }

    bench.run("fakequant_affine_2bit", 1, 5, || {
        std::hint::black_box(fakequant(&w, &g, &b, QuantSpec::new(2, 64)).unwrap());
    });
    bench.run("fakequant_nf_2bit", 1, 3, || {
        std::hint::black_box(nf_fakequant(&w, 2, 64).unwrap());
    });

    bench.finish("packing");
}
