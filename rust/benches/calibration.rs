//! Bench: the ApiQ calibration pipeline at step granularity — lw-calib
//! steps per layer shape, bw-calib steps, stream advancement — the
//! numbers behind the Table 4 lw-vs-bw duration ratio and the §Perf
//! optimization log in EXPERIMENTS.md.

use repro::benchharness::Bench;
use repro::calib::CalibStreams;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::model::TINY;
use repro::quant::QuantSpec;
use repro::runtime::{Bindings, Runtime};
use repro::tensor::{Rng, Tensor};

fn main() {
    let mut bench = Bench::new();
    let Ok(runtime) = Runtime::new("artifacts") else {
        bench.finish("calibration (no PJRT)");
        return;
    };
    if !runtime.has_artifact("lw_calib_tiny_256x256_r16_g64") {
        println!("note  artifacts missing; run `make artifacts`");
        bench.finish("calibration");
        return;
    }

    let params = TINY.init_params(11);
    let qparams = TINY.init_qparams(QuantSpec::new(2, 64), 16, false, 12);
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 11);
    let batcher = Batcher::new(TINY.calib_batch, TINY.seq_len);
    let mut crng = Rng::new(15);
    let calib: Vec<_> = (0..2).map(|_| batcher.lm_batch(&corpus, &mut crng)).collect();
    let n_tok = TINY.calib_batch * TINY.seq_len;

    // lw calib step per layer shape
    for (d_in, d_out) in [(256usize, 256usize), (256, 768), (768, 256)] {
        let name = format!("lw_calib_tiny_{d_in}x{d_out}_r16_g64");
        let mut rng = Rng::new(16);
        let w = Tensor::randn(&[d_in, d_out], 0.1, &mut rng);
        let x = Tensor::randn(&[n_tok, d_in], 1.0, &mut rng);
        let qp = {
            let mut ps = repro::model::ParamStore::new();
            ps.insert("gamma", Tensor::full(&[d_in / 64, d_out], 4.0));
            ps.insert("beta", Tensor::full(&[d_in / 64, d_out], 4.0));
            ps.insert("lora_a", Tensor::kaiming(&[d_in, 16], &mut rng));
            ps.insert("lora_b", Tensor::zeros(&[d_out, 16]));
            ps
        };
        let m = qp.zeros_like();
        let v = qp.zeros_like();
        bench.run(&format!("lw_calib_step_{d_in}x{d_out}"), 1, 5, || {
            let bind = Bindings::new()
                .tensor("w", &w)
                .group("qp", &qp)
                .group("m", &m)
                .group("v", &v)
                .tensor("x", &x)
                .tensor("xq", &x)
                .scalar("t", 1.0)
                .scalar("lr_ab", 1e-3)
                .scalar("lr_gb", 5e-3)
                .scalar("wd_ab", 0.0)
                .scalar("wd_gb", 0.0)
                .scalar("bits", 2.0)
                .scalar("scale", 1.0);
            std::hint::black_box(runtime.run(&name, &bind).unwrap());
        });
    }

    // stream machinery
    let mut streams = CalibStreams::init(&runtime, TINY, &params, &calib).unwrap();
    let bp = params.view("blocks.0.");
    let bqp = qparams.view("blocks.0.");
    bench.run("stream_advance_fp_block", 1, 5, || {
        let mut s2 = CalibStreams {
            cfg: streams.cfg,
            x_fp: streams.x_fp.clone(),
            x_q: streams.x_q.clone(),
        };
        s2.advance_fp(&runtime, &bp).unwrap();
        std::hint::black_box(&s2);
    });
    bench.run("stream_advance_q_block", 1, 5, || {
        let mut s2 = CalibStreams {
            cfg: streams.cfg,
            x_fp: streams.x_fp.clone(),
            x_q: streams.x_q.clone(),
        };
        s2.advance_q(&runtime, &bp, &bqp, 16, 64, 2.0, 1.0).unwrap();
        std::hint::black_box(&s2);
    });
    // keep streams "used" for the borrow checker's sake
    streams.sync_q_to_fp();

    // derived ratio: a full lw block (4 stages x layers x epochs) vs a bw
    // block (epochs) from the measured step times gets reported by the
    // quantizers bench; here we report the per-step per-token cost.
    bench.note(format!("calib token batch = {n_tok} tokens"));
    bench.finish("calibration");
}
