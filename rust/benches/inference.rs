//! Bench: the native packed-weight serving path.  (1) layer level — the
//! fused dequantize-on-the-fly GEMM (`PackedLinear::matmul_fused`)
//! against the naive dequantize-then-dense-matmul it replaces, across
//! bit-widths and batch sizes; (2) model level — end-to-end greedy decode
//! tokens/sec on the tiny config, packed vs dense fp.  Needs no
//! artifacts and no PJRT.

use repro::benchharness::Bench;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::{generate_greedy, PackedModel};
use repro::model::TINY;
use repro::quant::affine::{open_clip, quantize_ints};
use repro::quant::{PackedLinear, QuantSpec};
use repro::quantizers::{QuantizeCtx, Quantizer, Rtn};
use repro::runtime::Runtime;
use repro::tensor::{Rng, Tensor};

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::new(1);

    // --- layer level: Llama-2-7B's largest layer scaled down 4x per dim ---
    let (d_in, d_out) = (1024usize, 2752usize);
    let w = Tensor::randn(&[d_in, d_out], 0.1, &mut rng);
    let (g, b) = open_clip(d_in, d_out, 64);
    for bits in [2u32, 3, 4] {
        let spec = QuantSpec::new(bits, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec).unwrap();
        for n_tok in [1usize, 16] {
            let x = Tensor::randn(&[n_tok, d_in], 1.0, &mut rng);
            let fused_mean = bench
                .run(&format!("fused_{bits}bit_{d_in}x{d_out}_n{n_tok}"), 1, 5, || {
                    std::hint::black_box(pl.matmul_fused(&x).unwrap());
                })
                .mean_s;
            let naive_mean = bench
                .run(&format!("dequant_dense_{bits}bit_{d_in}x{d_out}_n{n_tok}"), 1, 5, || {
                    let dense = pl.dequantize().unwrap();
                    std::hint::black_box(x.matmul(&dense).unwrap());
                })
                .mean_s;
            bench.note(format!(
                "{bits}-bit n={n_tok}: fused {:.3}ms vs dequant+matmul {:.3}ms ({:.2}x)",
                fused_mean * 1e3,
                naive_mean * 1e3,
                naive_mean / fused_mean
            ));
        }
    }

    // --- model level: tiny end-to-end decode, packed 2-bit vs dense fp ---
    let params = TINY.init_params(11);
    let runtime = Runtime::new("artifacts").unwrap();
    let ctx = QuantizeCtx {
        runtime: &runtime,
        cfg: TINY,
        params: &params,
        spec: QuantSpec::new(2, 64),
        rank: 16,
        scale: 1.0,
        calib: &[],
        seed: 5,
        verbose: false,
    };
    let r = Rtn.run(&ctx).unwrap();
    let packed = PackedModel::from_quant_result(TINY, &r, 64, 1.0).unwrap();
    let dense = PackedModel::build(TINY, &params, None, QuantSpec::new(16, 64), 1.0).unwrap();
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 7);
    let prompt = Batcher::new(4, 16).lm_batch(&corpus, &mut Rng::new(9)).tokens;
    let new_tokens = 16;

    let rep = generate_greedy(&packed, &prompt, new_tokens).unwrap();
    bench.note(format!(
        "tiny packed 2-bit greedy decode: {:.1} tokens/s ({:.2} MB resident, {:.3} bits/weight)",
        rep.tokens_per_sec(),
        packed.resident_bytes() as f64 / 1e6,
        packed.effective_bits()
    ));
    let rep = generate_greedy(&dense, &prompt, new_tokens).unwrap();
    bench.note(format!(
        "tiny dense fp greedy decode: {:.1} tokens/s ({:.2} MB resident)",
        rep.tokens_per_sec(),
        dense.resident_bytes() as f64 / 1e6
    ));

    bench.finish("inference");
}
