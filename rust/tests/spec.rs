//! Speculative decoding tests: the draft/verify engine must emit
//! **bitwise identical** token streams to plain decode (greedy AND
//! seeded sampling, every block size, every k), the KV rollback
//! primitives must free emptied tail pages without disturbing CoW
//! sharers, and the scheduler must fall back per sequence when the
//! draft pool is exhausted or acceptance collapses.  Everything runs
//! without artifacts or PJRT.

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::model::{ParamStore, TINY};
use repro::quant::QuantSpec;
use repro::serve::decode::{generate, generate_paged};
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::spec::generate_speculative;
use repro::serve::{BlockPool, PagedKvCache, SamplingParams, SchedConfig, Scheduler};
use repro::tensor::{IntTensor, Rng, Tensor};
use std::sync::Arc;

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(batch: usize, len: usize, seed: u64) -> IntTensor {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(batch, len).lm_batch(&corpus, &mut Rng::new(seed ^ 0x77)).tokens
}

// ---------------------------------------------------------------------------
// speculative == plain decode, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn speculative_greedy_matches_plain_across_block_sizes_and_k() {
    let model = packed_tiny(3);
    let draft = model.prefix_cut(2).unwrap();
    let prompt = tiny_prompt(2, 9, 15);
    let flat = generate(&model, &prompt, 12, None).unwrap();
    for bs in [1usize, 7, 64] {
        let paged = generate_paged(&model, &prompt, 12, None, bs).unwrap();
        assert_eq!(paged.tokens, flat.tokens);
        for k in [1usize, 4, 8] {
            let spec = generate_speculative(&model, &draft, &prompt, 12, None, bs, k).unwrap();
            assert_eq!(
                spec.gen.tokens, flat.tokens,
                "speculative greedy (bs {bs}, k {k}) must be bit-identical to plain decode"
            );
        }
    }
}

#[test]
fn speculative_sampling_matches_plain_across_block_sizes_and_k() {
    let model = packed_tiny(7);
    let draft = model.prefix_cut(2).unwrap();
    let prompt = tiny_prompt(2, 6, 19);
    let p = SamplingParams { temperature: 0.9, top_k: 50, top_p: 0.95, seed: 123 };
    let flat = generate(&model, &prompt, 10, Some(&p)).unwrap();
    for bs in [1usize, 7, 64] {
        for k in [1usize, 4, 8] {
            let spec =
                generate_speculative(&model, &draft, &prompt, 10, Some(&p), bs, k).unwrap();
            assert_eq!(
                spec.gen.tokens, flat.tokens,
                "the target's rng stream must advance exactly once per emitted token \
                 (bs {bs}, k {k})"
            );
        }
    }
}

#[test]
fn speculative_with_disagreeing_draft_is_still_bitwise() {
    // A draft with completely different weights proposes near-garbage;
    // the verify loop must reject its way to the exact plain stream.
    let model = packed_tiny(11);
    let garbage_draft = packed_tiny(99);
    let prompt = tiny_prompt(1, 8, 23);
    let want = generate(&model, &prompt, 16, None).unwrap();
    let spec = generate_speculative(&model, &garbage_draft, &prompt, 16, None, 4, 4).unwrap();
    assert_eq!(spec.gen.tokens, want.tokens);
    assert!(spec.proposed > 0, "the draft did propose");
    assert!(
        spec.accepted <= spec.proposed,
        "sanity: acceptance counts proposals"
    );
}

#[test]
fn full_depth_self_draft_accepts_every_greedy_proposal() {
    // prefix_cut at full depth IS the target: greedy proposals always
    // equal the target argmax, so every proposal is accepted and each
    // cycle emits k+1 tokens.
    let model = packed_tiny(13);
    let draft = model.prefix_cut(TINY.n_layers).unwrap();
    let prompt = tiny_prompt(1, 6, 29);
    let want = generate(&model, &prompt, 15, None).unwrap();
    let spec = generate_speculative(&model, &draft, &prompt, 15, None, 8, 4).unwrap();
    assert_eq!(spec.gen.tokens, want.tokens);
    assert!(spec.proposed > 0);
    assert_eq!(
        spec.accepted, spec.proposed,
        "an identical draft must never be rejected under greedy decode"
    );
}

#[test]
fn k_zero_degenerates_to_plain_paged_decode() {
    let model = packed_tiny(17);
    let draft = model.prefix_cut(1).unwrap();
    let prompt = tiny_prompt(1, 5, 31);
    let want = generate(&model, &prompt, 8, None).unwrap();
    let spec = generate_speculative(&model, &draft, &prompt, 8, None, 4, 0).unwrap();
    assert_eq!(spec.gen.tokens, want.tokens);
    assert_eq!(spec.proposed, 0, "k = 0 never consults the draft");
    assert_eq!(spec.draft_secs, 0.0);
}

// ---------------------------------------------------------------------------
// KV rollback primitives
// ---------------------------------------------------------------------------

fn rows(d: usize, t: usize, base: f32) -> Vec<f32> {
    (0..t * d).map(|i| base + i as f32).collect()
}

#[test]
fn truncate_frees_emptied_tail_pages() {
    let (layers, d, bs) = (1usize, 2usize, 4usize);
    let mut pool = BlockPool::new(layers, d, bs, 8);
    let mut c = PagedKvCache::new(&pool);
    c.reserve(10, &mut pool).unwrap();
    let k = rows(d, 10, 0.0);
    c.write_rows(&mut pool, 0, &k, &k).unwrap();
    c.advance(10);
    assert_eq!(c.n_blocks(), 3);
    assert_eq!(pool.stats().used_blocks, 3);

    // 10 -> 5 positions: page 3 empties and returns to the free list,
    // page 2 keeps slot 0 committed.
    c.truncate(5, &mut pool);
    assert_eq!(c.len(), 5);
    assert_eq!(c.n_blocks(), 2);
    assert_eq!(pool.stats().used_blocks, 2);
    assert_eq!(pool.stats().free_blocks, 1);

    // the surviving rows are untouched
    let segs = c.segments(&pool, 0, 5);
    assert_eq!(segs[0].as_f32().0, &k[..4 * d]);
    assert_eq!(segs[1].as_f32().0, &k[4 * d..5 * d]);

    // truncate at or past the current length is a no-op
    c.truncate(5, &mut pool);
    c.truncate(99, &mut pool);
    assert_eq!(c.len(), 5);
    assert_eq!(c.n_blocks(), 2);

    // a page-boundary truncate keeps exactly the covering pages
    c.truncate(4, &mut pool);
    assert_eq!(c.n_blocks(), 1);

    // re-growing after a rollback overwrites the garbage tail slots
    c.reserve(6, &mut pool).unwrap();
    let k2 = rows(d, 2, 500.0);
    c.write_rows(&mut pool, 0, &k2, &k2).unwrap();
    c.advance(2);
    let segs = c.segments(&pool, 0, 6);
    assert_eq!(&segs[1].as_f32().0[..2 * d], &k2[..]);

    // truncate(0) releases everything
    c.truncate(0, &mut pool);
    assert_eq!(c.len(), 0);
    assert_eq!(c.n_blocks(), 0);
    assert_eq!(pool.stats().used_blocks, 0);
}

#[test]
fn truncate_of_shared_tail_drops_the_entry_without_scrubbing() {
    let (layers, d, bs) = (1usize, 2usize, 4usize);
    let mut pool = BlockPool::new(layers, d, bs, 8);
    let mut a = PagedKvCache::new(&pool);
    a.reserve(6, &mut pool).unwrap();
    let k = rows(d, 6, 0.0);
    a.write_rows(&mut pool, 0, &k, &k).unwrap();
    a.advance(6);

    // child maps both pages (full block 0 + partial tail block 1)
    let b = PagedKvCache::fork_prefix(&a, 6, &mut pool).unwrap();
    let tail = a.block_at(4);
    assert_eq!(pool.ref_count(tail), 2);

    // the parent rolls back into the shared tail: its entry is dropped,
    // the refcount falls to 1, and the CHILD's rows are untouched.
    a.truncate(3, &mut pool);
    assert_eq!(a.n_blocks(), 1);
    assert_eq!(pool.ref_count(tail), 1, "release, not scrub");
    let segs = b.segments(&pool, 0, 6);
    assert_eq!(segs[1].as_f32().0, &k[4 * d..], "sharer still reads its committed rows");

    // the parent re-appends: it must get a DIFFERENT page than the
    // child's still-held tail (refcount 1 != free), and reserve CoWs
    // the still-shared block 0 before the parent writes position 3.
    a.reserve(6, &mut pool).unwrap();
    assert_ne!(a.block_at(4), tail);
    let k2 = rows(d, 3, 900.0);
    a.write_rows(&mut pool, 0, &k2, &k2).unwrap();
    a.advance(3);
    let segs = b.segments(&pool, 0, 6);
    assert_eq!(segs[0].as_f32().0, &k[..4 * d], "parent's regrowth never touches the child");
    assert_eq!(segs[1].as_f32().0, &k[4 * d..]);

    // and the reverse direction: a CHILD truncating away still-shared
    // pages releases its entries while the parent keeps reading.
    let mut pool = BlockPool::new(layers, d, bs, 8);
    let mut a = PagedKvCache::new(&pool);
    a.reserve(6, &mut pool).unwrap();
    a.write_rows(&mut pool, 0, &k, &k).unwrap();
    a.advance(6);
    let mut b = PagedKvCache::fork_prefix(&a, 6, &mut pool).unwrap();
    let (b0, b1) = (a.block_at(0), a.block_at(4));
    assert_eq!((pool.ref_count(b0), pool.ref_count(b1)), (2, 2));
    b.truncate(0, &mut pool);
    assert_eq!((pool.ref_count(b0), pool.ref_count(b1)), (1, 1));
    let segs = a.segments(&pool, 0, 6);
    assert_eq!(segs[0].as_f32().0, &k[..4 * d]);
    assert_eq!(segs[1].as_f32().0, &k[4 * d..]);
}

// ---------------------------------------------------------------------------
// scheduler integration: bitwise streams, counters, fallbacks
// ---------------------------------------------------------------------------

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn done_of(events: &[StepEvent], key: u64) -> Option<(&Vec<i32>, usize, FinishReason)> {
    events.iter().find_map(|e| match e {
        StepEvent::Done { key: k, tokens, prompt_len, finish, .. } if *k == key => {
            Some((tokens, *prompt_len, *finish))
        }
        _ => None,
    })
}

fn spec_cfg(speculate: usize) -> SchedConfig {
    SchedConfig {
        max_batch: 4,
        max_new_cap: 64,
        max_prompt: 64,
        kv_block: 4,
        speculate,
        ..SchedConfig::default()
    }
}

#[test]
fn scheduler_speculation_is_bitwise_and_counts_acceptance() {
    let model = packed_tiny(37);
    let draft = Arc::new(model.prefix_cut(2).unwrap());
    let pa = tiny_prompt(1, 9, 41).data().to_vec();
    let pb = tiny_prompt(1, 6, 42).data().to_vec();

    let mut sched = Scheduler::with_draft(&model, spec_cfg(4), draft);
    sched.submit(req(1, pa.clone(), 14));
    let mut rb = req(2, pb.clone(), 10);
    rb.sampling = Some(SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.9, seed: 7 });
    sched.submit(rb);
    let events = drain(&mut sched);

    // greedy request: equal to solo plain generation
    let solo = IntTensor::new(vec![1, pa.len()], pa.clone()).unwrap();
    let want = generate(&model, &solo, 14, None).unwrap();
    let (tokens, _, finish) = done_of(&events, 1).expect("done");
    assert_eq!(finish, FinishReason::Length);
    assert_eq!(&want.tokens[0][..], &tokens[..], "speculation changed a greedy stream");

    // sampled request: equal to solo seeded generation (scheduler seeds
    // stream 0 for every request)
    let p = SamplingParams { temperature: 0.8, top_k: 40, top_p: 0.9, seed: 7 };
    let solo = IntTensor::new(vec![1, pb.len()], pb.clone()).unwrap();
    let want = generate(&model, &solo, 10, Some(&p)).unwrap();
    let (tokens, _, _) = done_of(&events, 2).expect("done");
    assert_eq!(&want.tokens[0][..], &tokens[..], "speculation changed a sampled stream");

    // pool-wide counters moved and the per-request stats carry them
    let s = sched.spec_stats().expect("speculating scheduler reports spec stats");
    assert!(s.proposed > 0, "drafting happened");
    assert!(s.cycles > 0);
    let per_req: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Done { stats, .. } => Some(stats.spec_proposed),
            _ => None,
        })
        .collect();
    assert!(per_req.iter().any(|&p| p > 0), "done stats carry spec counters");

    // every page reclaimed on both pools
    assert_eq!(sched.kv_stats().used_blocks, 0);
    assert_eq!(s.draft_kv.used_blocks, 0, "draft pages drain with their sequences");
    assert!(s.draft_kv.peak_resident_blocks > 0, "the draft did hold KV");
}

#[test]
fn scheduler_speculation_matches_full_depth_draft_throughput_invariants() {
    // Full-depth self-draft: greedy acceptance is total, so the stream
    // arrives in fewer scheduler steps than tokens — the observable
    // speedup — while staying bitwise identical.
    let model = packed_tiny(43);
    let draft = Arc::new(model.prefix_cut(TINY.n_layers).unwrap());
    let prompt = tiny_prompt(1, 6, 44).data().to_vec();

    let mut sched = Scheduler::with_draft(&model, spec_cfg(4), draft);
    sched.submit(req(1, prompt.clone(), 13));
    let mut steps = 0usize;
    let mut events = Vec::new();
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        steps += 1;
        assert!(steps < 1000);
    }
    let solo = IntTensor::new(vec![1, prompt.len()], prompt).unwrap();
    let want = generate(&model, &solo, 13, None).unwrap();
    let (tokens, _, _) = done_of(&events, 1).expect("done");
    assert_eq!(&want.tokens[0][..], &tokens[..]);
    let s = sched.spec_stats().unwrap();
    assert_eq!(s.accepted, s.proposed, "identical draft, greedy: full acceptance");
    assert!(
        steps < 13,
        "k=4 full acceptance must emit 13 tokens in fewer than 13 steps (took {steps})"
    );
}

#[test]
fn acceptance_collapse_falls_back_to_plain_decode() {
    // A garbage draft (different weights entirely) gets ~chance-level
    // acceptance; after a full rolling window the sequence must stop
    // speculating, finish on the plain path, and still be bitwise right.
    let model = packed_tiny(47);
    let garbage = Arc::new(packed_tiny(101));
    let prompt = tiny_prompt(1, 8, 48).data().to_vec();

    let mut sched = Scheduler::with_draft(&model, spec_cfg(4), garbage);
    sched.submit(req(1, prompt.clone(), 32));
    let events = drain(&mut sched);

    let solo = IntTensor::new(vec![1, prompt.len()], prompt).unwrap();
    let want = generate(&model, &solo, 32, None).unwrap();
    let (tokens, _, finish) = done_of(&events, 1).expect("done");
    assert_eq!(finish, FinishReason::Length);
    assert_eq!(&want.tokens[0][..], &tokens[..]);

    let s = sched.spec_stats().unwrap();
    assert!(
        s.fallbacks >= 1,
        "chance-level acceptance must trip the collapse fallback (acceptance {:.3})",
        s.accepted as f64 / s.proposed.max(1) as f64
    );
    assert_eq!(s.draft_kv.used_blocks, 0, "fallback released the draft pages");
}

#[test]
fn draft_pool_exhaustion_falls_back_to_plain_decode() {
    // One 4-position draft page can never hold a 10-token prompt: the
    // very first cycle falls back, and the request still completes
    // bitwise identical on the plain path.
    let model = packed_tiny(53);
    let draft = Arc::new(model.prefix_cut(2).unwrap());
    let mut cfg = spec_cfg(4);
    cfg.draft_kv_blocks_total = 1;
    let prompt = tiny_prompt(1, 10, 54).data().to_vec();

    let mut sched = Scheduler::with_draft(&model, cfg, draft);
    sched.submit(req(1, prompt.clone(), 8));
    let events = drain(&mut sched);

    let solo = IntTensor::new(vec![1, prompt.len()], prompt).unwrap();
    let want = generate(&model, &solo, 8, None).unwrap();
    let (tokens, _, _) = done_of(&events, 1).expect("done");
    assert_eq!(&want.tokens[0][..], &tokens[..]);

    let s = sched.spec_stats().unwrap();
    assert!(s.fallbacks >= 1, "draft pool exhaustion must fall back");
    assert_eq!(s.proposed, 0, "nothing was ever drafted");
    assert_eq!(s.draft_kv.used_blocks, 0);
}

#[test]
fn stop_token_mid_speculative_cycle_ends_the_stream_exactly() {
    // Use the plain 3rd generated token as the stop: wherever that value
    // first fires, the speculative scheduler must emit exactly the
    // stream a NON-speculative scheduler emits and stop the same way —
    // even when its verify chunk ran past the stop position.
    let model = packed_tiny(59);
    let draft = Arc::new(model.prefix_cut(TINY.n_layers).unwrap());
    let prompt = tiny_prompt(1, 5, 60).data().to_vec();
    let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
    let stop = generate(&model, &solo, 3, None).unwrap().tokens[0][prompt.len() + 2];

    let mut plain = Scheduler::new(&model, spec_cfg(0));
    let mut r = req(1, prompt.clone(), 16);
    r.stop = Some(stop);
    plain.submit(r);
    let plain_events = drain(&mut plain);
    let (want_tokens, _, want_finish) = done_of(&plain_events, 1).expect("plain done");

    let mut sched = Scheduler::with_draft(&model, spec_cfg(4), draft);
    let mut r = req(1, prompt.clone(), 16);
    r.stop = Some(stop);
    sched.submit(r);
    let events = drain(&mut sched);
    let (tokens, _, finish) = done_of(&events, 1).expect("done");
    assert_eq!(finish, want_finish);
    assert_eq!(finish, FinishReason::Stop, "the stop token fires within 16 tokens");
    assert_eq!(
        &tokens[..],
        &want_tokens[..],
        "stream must end exactly at the stop token even when the verify \
         chunk ran past it"
    );
    assert_eq!(sched.kv_stats().used_blocks, 0);
}
