//! Native inference engine tests: bit-packing vs a naive reference, the
//! fused packed GEMM vs dequantize-then-matmul, and whole-model packed vs
//! dense forward equivalence.  Everything here runs without artifacts or
//! PJRT (the stub runtime is enough).

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::eval::{Evaluator, ModelMode};
use repro::infer::{generate_greedy, PackedModel};
use repro::model::{ParamStore, LINEAR_NAMES, TINY};
use repro::quant::affine::{fakequant, open_clip, quantize_ints};
use repro::quant::{pack_codes, unpack_codes, PackedLinear, QuantSpec};
use repro::runtime::Runtime;
use repro::tensor::{Rng, Tensor};

// ---------------------------------------------------------------------------
// pack_codes / unpack_codes vs a naive bit-by-bit reference
// ---------------------------------------------------------------------------

/// Naive reference: write every code's bits, LSB-first, into a flat bit
/// vector, then fold into little-endian bytes.
fn pack_naive(codes: &[u32], bits: u32) -> Vec<u8> {
    let mut bitvec: Vec<bool> = Vec::with_capacity(codes.len() * bits as usize);
    for &c in codes {
        for j in 0..bits {
            bitvec.push((c >> j) & 1 == 1);
        }
    }
    let mut out = vec![0u8; bitvec.len().div_ceil(8)];
    for (i, &bit) in bitvec.iter().enumerate() {
        if bit {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

#[test]
fn pack_matches_naive_bit_reference() {
    let mut rng = Rng::new(0xB17);
    for bits in 1u32..=8 {
        let mask = (1u32 << bits) - 1;
        // deliberately include lengths that are not multiples of 8 (and
        // don't fill whole bytes) plus degenerate and larger sizes
        for n in [1usize, 2, 3, 5, 7, 9, 13, 100, 257] {
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & mask).collect();
            let packed = pack_codes(&codes, bits);
            let naive = pack_naive(&codes, bits);
            assert_eq!(packed, naive, "bits={bits} n={n}: packed bytes differ from reference");
            assert_eq!(
                unpack_codes(&packed, bits, n),
                codes,
                "bits={bits} n={n}: roundtrip failed"
            );
        }
    }
}

#[test]
fn pack_empty_is_empty() {
    assert!(pack_codes(&[], 3).is_empty());
    assert!(unpack_codes(&[], 3, 0).is_empty());
}

// ---------------------------------------------------------------------------
// fused packed matmul vs dequantize + dense matmul
// ---------------------------------------------------------------------------

#[test]
fn fused_matmul_matches_dense_all_bits() {
    let mut rng = Rng::new(31);
    for bits in [2u32, 3, 4] {
        for group in [32usize, 64] {
            let spec = QuantSpec::new(bits, group);
            let (d_in, d_out) = (128usize, 96usize);
            let w = Tensor::randn(&[d_in, d_out], 0.3, &mut rng);
            let (g, b) = open_clip(d_in, d_out, group);
            let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
            let pl = PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec).unwrap();
            let dense = pl.dequantize().unwrap();
            for n_tok in [1usize, 9] {
                let x = Tensor::randn(&[n_tok, d_in], 1.0, &mut rng);
                let fused = pl.matmul_fused(&x).unwrap();
                let want = x.matmul(&dense).unwrap();
                let rel =
                    fused.sub(&want).unwrap().fro_norm() / want.fro_norm().max(1e-12);
                assert!(
                    rel <= 1e-5,
                    "bits={bits} group={group} n={n_tok}: rel err {rel}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// whole-model equivalence: packed forward == dense-dequantized forward
// ---------------------------------------------------------------------------

/// Open-clip qparams with live (random) LoRA B so the adapter path
/// contributes to the output.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

#[test]
fn packed_model_matches_dense_dequantized_forward() {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(3);
    let qp = open_qparams_with_lora(spec, 8, 41);

    let packed = PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap();
    assert!(packed.effective_bits() < 3.0, "2-bit model should pack tight");

    // dense reference: fake-quantize every linear host-side, serve at
    // "16-bit" (dense weights) with identical adapters
    let mut dparams = params.clone();
    for blk in 0..TINY.n_layers {
        for lin in LINEAR_NAMES {
            let key = TINY.weight_key(blk, lin);
            let prefix = TINY.qparam_prefix(blk, lin);
            let w = dparams.require(&key).unwrap().clone();
            let gamma = qp.require(&format!("{prefix}gamma")).unwrap();
            let beta = qp.require(&format!("{prefix}beta")).unwrap();
            dparams.insert(key, fakequant(&w, gamma, beta, spec).unwrap());
        }
    }
    let dense = PackedModel::build(TINY, &dparams, Some(&qp), QuantSpec::new(16, 64), 1.0).unwrap();
    assert!(dense.resident_bytes() > packed.resident_bytes());

    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 6);
    let toks = Batcher::new(2, 12).lm_batch(&corpus, &mut Rng::new(8)).tokens;
    let lp = packed.logits(&toks).unwrap();
    let ld = dense.logits(&toks).unwrap();
    assert_eq!(lp.shape(), &[2, 12, TINY.vocab]);
    assert!(lp.all_finite());
    let rel = lp.sub(&ld).unwrap().fro_norm() / ld.fro_norm().max(1e-12);
    assert!(rel <= 1e-5, "packed vs dense forward rel err {rel}");
}

#[test]
fn dora_model_runs_and_rescales() {
    let spec = QuantSpec::new(3, 64);
    let params = TINY.init_params(9);
    let mut qp = TINY.init_qparams(spec, 4, true, 10);
    // double every magnitude: outputs must change vs mag=1
    let base = PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap();
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".mag") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 2.0;
            }
        }
    }
    let doubled = PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap();
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 2);
    let toks = Batcher::new(1, 8).lm_batch(&corpus, &mut Rng::new(3)).tokens;
    let l1 = base.logits(&toks).unwrap();
    let l2 = doubled.logits(&toks).unwrap();
    assert!(l1.all_finite() && l2.all_finite());
    assert!(l1.sub(&l2).unwrap().fro_norm() > 1e-3, "mag rescale had no effect");
}

// ---------------------------------------------------------------------------
// greedy decoding + artifact-free perplexity
// ---------------------------------------------------------------------------

#[test]
fn greedy_decode_deterministic_and_in_vocab() {
    let params = TINY.init_params(13);
    let qp = open_qparams_with_lora(QuantSpec::new(2, 64), 4, 14);
    let model = PackedModel::build(TINY, &params, Some(&qp), QuantSpec::new(2, 64), 1.0).unwrap();
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 15);
    let prompt = Batcher::new(3, 8).lm_batch(&corpus, &mut Rng::new(16)).tokens;
    let a = generate_greedy(&model, &prompt, 6).unwrap();
    let b = generate_greedy(&model, &prompt, 6).unwrap();
    assert_eq!(a.tokens.len(), 3);
    for row in &a.tokens {
        assert_eq!(row.len(), 8 + 6);
        assert!(row.iter().all(|&t| (0..TINY.vocab as i32).contains(&t)));
    }
    assert_eq!(a.tokens, b.tokens, "greedy decoding must be deterministic");
    assert!(a.new_tokens == 6 && a.prompt_len == 8);
    assert!(a.tokens_per_sec() > 0.0);
}

#[test]
fn native_perplexity_runs_without_artifacts() {
    // The stub runtime cannot execute artifacts, but native modes never
    // ask it to.
    let runtime = Runtime::new("definitely_missing_artifacts_dir").unwrap();
    let ev = Evaluator::new(&runtime, TINY);
    let params = TINY.init_params(21);
    let qp = open_qparams_with_lora(QuantSpec::new(2, 64), 4, 22);
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 23);
    let batcher = Batcher::new(2, 16);
    let mut rng = Rng::new(24);
    let batches: Vec<_> = (0..2).map(|_| batcher.lm_batch(&corpus, &mut rng)).collect();

    let fp = ev
        .perplexity(&ModelMode::NativeFp, &params, None, &batches)
        .unwrap();
    assert!(fp.is_finite() && fp > 1.0, "fp ppl {fp}");

    let mode = ModelMode::NativeQuant { bits: 2, group: 64, scale: 1.0 };
    let q = ev.perplexity(&mode, &params, Some(&qp), &batches).unwrap();
    assert!(q.is_finite() && q > 1.0, "2-bit ppl {q}");
}
