//! Paged KV memory tests: paged decode must be bitwise identical to the
//! flat-slab oracle at every block size, prefix sharing must be
//! refcount/copy-on-write correct, the scheduler must admit by block
//! budget (backoff on exhaustion, reclaim after eviction), and sharing
//! must show up as fewer resident blocks.  Everything runs without
//! artifacts or PJRT.

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::model::{ParamStore, TINY};
use repro::quant::QuantSpec;
use repro::serve::decode::{generate, generate_paged};
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::{BlockPool, PagedKvCache, SamplingParams, SchedConfig, Scheduler};
use repro::tensor::{IntTensor, Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(batch: usize, len: usize, seed: u64) -> IntTensor {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(batch, len).lm_batch(&corpus, &mut Rng::new(seed ^ 0x77)).tokens
}

// ---------------------------------------------------------------------------
// paged decode == flat decode, bit for bit, at every block size
// ---------------------------------------------------------------------------

#[test]
fn paged_greedy_matches_flat_across_block_sizes() {
    let model = packed_tiny(3);
    let prompt = tiny_prompt(3, 9, 15);
    let flat = generate(&model, &prompt, 12, None).unwrap();
    for bs in [1usize, 7, 64] {
        let paged = generate_paged(&model, &prompt, 12, None, bs).unwrap();
        assert_eq!(
            paged.tokens, flat.tokens,
            "paged decode (block size {bs}) must be bit-identical to the flat slab"
        );
    }
}

#[test]
fn paged_sampling_matches_flat_across_block_sizes() {
    let model = packed_tiny(7);
    let prompt = tiny_prompt(2, 6, 19);
    let p = SamplingParams { temperature: 0.9, top_k: 50, top_p: 0.95, seed: 123 };
    let flat = generate(&model, &prompt, 10, Some(&p)).unwrap();
    for bs in [1usize, 7, 64] {
        let paged = generate_paged(&model, &prompt, 10, Some(&p), bs).unwrap();
        assert_eq!(
            paged.tokens, flat.tokens,
            "identical logits + identical rng streams => identical samples (bs {bs})"
        );
    }
}

#[test]
fn paged_chunk_logits_match_flat_bitwise() {
    // Stronger than token equality: the paged prefill chunk's logits and
    // a subsequent paged step must equal the flat-path logits bitwise.
    let model = packed_tiny(5);
    let prompt = tiny_prompt(1, 10, 31);
    let toks = prompt.data().to_vec();

    let mut flat_cache = repro::serve::KvCache::new(TINY.n_layers, TINY.d_model, 16);
    let flat_chunk = model.forward_chunk(&toks, &mut flat_cache).unwrap();

    let mut pool = BlockPool::new(TINY.n_layers, TINY.d_model, 3, 16);
    let mut cache = PagedKvCache::new(&pool);
    let paged_chunk = model.forward_chunk_paged(&toks, &mut cache, &mut pool).unwrap();
    assert_eq!(paged_chunk.data(), flat_chunk.data(), "prefill logits differ");

    let next = [toks[3]];
    let mut refs = vec![&mut flat_cache];
    let flat_step = model.forward_step(&next, &mut refs).unwrap();
    let mut prefs = vec![&mut cache];
    let paged_step = model.forward_step_paged(&next, &mut prefs, &mut pool).unwrap();
    assert_eq!(paged_step.data(), flat_step.data(), "decode step logits differ");
}

#[test]
fn batched_prefill_matches_sequential_chunks_bitwise() {
    // prefill_batch folds ragged sequences into one pass; each row must
    // come out exactly as a solo forward_chunk_paged would produce it.
    let model = packed_tiny(11);
    let pa = tiny_prompt(1, 9, 40).data().to_vec();
    let pb = tiny_prompt(1, 5, 41).data().to_vec();
    let vocab = model.cfg.vocab;

    let mut pool = BlockPool::new(TINY.n_layers, TINY.d_model, 4, 32);
    let mut ca = PagedKvCache::new(&pool);
    let mut cb = PagedKvCache::new(&pool);
    ca.reserve(pa.len(), &mut pool).unwrap();
    cb.reserve(pb.len(), &mut pool).unwrap();
    let logits = {
        let mut caches = vec![&mut ca, &mut cb];
        model
            .prefill_batch(&[&pa[..], &pb[..]], &mut caches, &mut pool)
            .unwrap()
    };
    assert_eq!(logits.shape(), &[2, vocab]);

    let mut pool2 = BlockPool::new(TINY.n_layers, TINY.d_model, 4, 32);
    for (bi, p) in [&pa, &pb].iter().enumerate() {
        let mut c = PagedKvCache::new(&pool2);
        let solo = model.forward_chunk_paged(p, &mut c, &mut pool2).unwrap();
        assert_eq!(
            logits.row(bi),
            solo.row(p.len() - 1),
            "batched prefill row {bi} differs from the solo chunk"
        );
        c.release_all(&mut pool2);
    }
}

// ---------------------------------------------------------------------------
// prefix sharing: bitwise streams + refcount/copy-on-write correctness
// ---------------------------------------------------------------------------

#[test]
fn shared_prefix_decode_is_bitwise_and_uses_fewer_blocks() {
    // Two sequences with the SAME prompt, decoded through the scheduler:
    // streams must match solo flat generation exactly, and the pool must
    // hold fewer pages than two unshared sequences would.
    let model = packed_tiny(17);
    let prompt = tiny_prompt(1, 10, 50).data().to_vec();
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 64,
        max_prompt: 64,
        kv_block: 4,
        kv_blocks_total: 0,
        ..SchedConfig::default()
    };

    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, prompt.clone(), 6));
    sched.submit(req(2, prompt.clone(), 6));
    let mut events = sched.step().unwrap();
    assert_eq!(sched.n_active(), 2, "both admitted in one tick");
    let shared_peak = sched.kv_stats();
    assert!(
        shared_peak.shared_blocks > 0,
        "identical prompts admitted together must share pages"
    );
    // 10-position prompt at block 4 = 3 blocks; sharing maps 2 whole
    // blocks, so two sequences hold 3 + 2 = 5 instead of 6.
    assert!(
        shared_peak.used_blocks < 6,
        "sharing must use fewer pages than two unshared prompts ({} >= 6)",
        shared_peak.used_blocks
    );

    events.extend(drain(&mut sched));
    let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
    let want = generate(&model, &solo, 6, None).unwrap();
    for key in [1u64, 2] {
        let (tokens, _, finish) = done_of(&events, key).expect("done");
        assert_eq!(finish, FinishReason::Length);
        assert_eq!(
            &want.tokens[0][..],
            &tokens[..],
            "prefix sharing must not change request {key}'s stream"
        );
    }
    // reclaim-after-evict: nothing leaked
    let s = sched.kv_stats();
    assert_eq!(s.used_blocks, 0, "all pages reclaimed");
    assert_eq!(s.shared_blocks, 0);
    assert!(s.peak_shared_blocks > 0);

    // per-request stats record the mapped prefix
    let shared_toks: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Done { stats, .. } => Some(stats.shared_prefix_tokens),
            _ => None,
        })
        .collect();
    assert_eq!(shared_toks.iter().filter(|&&s| s > 0).count(), 1, "second request shared");
}

#[test]
fn mid_flight_admission_shares_unaligned_prefix_with_cow() {
    // B arrives while A is decoding; their prompts share 9 tokens (not
    // block-aligned at kv_block 4), so B maps A's partial tail page and
    // copy-on-write splits it when B prefills its own suffix.  Streams
    // must still equal solo generation.
    let model = packed_tiny(23);
    let pa = tiny_prompt(1, 12, 60).data().to_vec();
    let mut pb = pa[..9].to_vec();
    pb.push((pa[9] + 1).rem_euclid(TINY.vocab as i32)); // diverge at 9
    pb.extend_from_slice(&pa[..2]);
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 64,
        max_prompt: 64,
        kv_block: 4,
        kv_blocks_total: 0,
        ..SchedConfig::default()
    };

    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, pa.clone(), 10));
    let mut events = sched.step().unwrap();
    // A is mid-decode; B arrives and must share A's committed prefix
    sched.submit(req(2, pb.clone(), 4));
    events.extend(sched.step().unwrap());
    assert!(
        sched.kv_stats().shared_blocks > 0,
        "mid-flight admission with a common prefix must share pages"
    );
    events.extend(drain(&mut sched));

    for (key, prompt, max_new) in [(1u64, &pa, 10usize), (2, &pb, 4)] {
        let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
        let want = generate(&model, &solo, max_new, None).unwrap();
        let (tokens, _, _) = done_of(&events, key).expect("done");
        assert_eq!(&want.tokens[0][..], &tokens[..], "request {key} stream changed");
    }
    let s = sched.kv_stats();
    assert_eq!(s.used_blocks, 0, "no leaked pages after CoW + eviction");
}

// ---------------------------------------------------------------------------
// block budget: admission backoff + reclaim
// ---------------------------------------------------------------------------

#[test]
fn admission_backs_off_when_blocks_exhausted_and_recovers() {
    let model = packed_tiny(29);
    // Budget of 4 pages x 4 positions: one 10-token prompt takes 3
    // pages, so two cannot be admitted together; 10 + (3 - 1) committed
    // positions keep each sequence inside its 3 pages (Length finish).
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 8,
        max_prompt: 16,
        kv_block: 4,
        kv_blocks_total: 4,
        ..SchedConfig::default()
    };
    let pa = tiny_prompt(1, 10, 70).data().to_vec();
    let mut pb = tiny_prompt(1, 10, 71).data().to_vec();
    pb[0] = (pa[0] + 1).rem_euclid(TINY.vocab as i32); // no shareable prefix

    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, pa.clone(), 3));
    sched.submit(req(2, pb.clone(), 3));
    let mut events = sched.step().unwrap();
    assert_eq!(sched.n_active(), 1, "budget admits only one sequence");
    assert_eq!(sched.n_pending(), 1, "the other backs off, not rejected");

    events.extend(drain(&mut sched));
    assert_eq!(sched.n_completed(), 2, "backed-off request admitted after eviction");
    for (key, prompt) in [(1u64, &pa), (2, &pb)] {
        let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
        let want = generate(&model, &solo, 3, None).unwrap();
        let (tokens, _, finish) = done_of(&events, key).expect("done");
        assert_eq!(finish, FinishReason::Length);
        assert_eq!(&want.tokens[0][..], &tokens[..]);
    }
    let s = sched.kv_stats();
    assert_eq!(s.used_blocks, 0);
    assert!(s.resident_blocks <= 4, "never allocated past the budget");
}

#[test]
fn oversized_prompt_on_idle_pool_is_rejected_not_livelocked() {
    let model = packed_tiny(37);
    // 2 pages x 4 positions: a 10-token prompt can NEVER fit, and with
    // nothing running the pool will never free up — reject, don't spin.
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 8,
        max_prompt: 16,
        kv_block: 4,
        kv_blocks_total: 2,
        ..SchedConfig::default()
    };
    let prompt = tiny_prompt(1, 10, 90).data().to_vec();
    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, prompt, 4));
    let events = drain(&mut sched);
    assert!(
        events.iter().any(|e| matches!(e, StepEvent::Rejected { key: 1, .. })),
        "an unsatisfiable prompt must be rejected"
    );
    assert_eq!(sched.kv_stats().used_blocks, 0);
}

#[test]
fn decode_exhaustion_finishes_with_capacity_not_batch_failure() {
    let model = packed_tiny(31);
    // 3 pages x 4 positions: a 10-token prompt fits (3 pages), but the
    // 3rd generated token needs a 4th page that never exists.
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 32,
        max_prompt: 12,
        kv_block: 4,
        kv_blocks_total: 3,
        ..SchedConfig::default()
    };
    let prompt = tiny_prompt(1, 10, 80).data().to_vec();
    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, prompt.clone(), 32));
    let events = drain(&mut sched);
    let (tokens, prompt_len, finish) = done_of(&events, 1).expect("done");
    assert_eq!(finish, FinishReason::Capacity);
    // prompt prefill emits token 1 (position 10 is only WRITTEN at the
    // next step): 2 positions of page 3 support 2 decode steps
    assert_eq!(tokens.len() - prompt_len, 3, "streamed until the pages ran out");
    let s = sched.kv_stats();
    assert_eq!(s.used_blocks, 0, "capacity-finished sequence released its pages");
}
// ---------------------------------------------------------------------------
// helpers (mirrors tests/serve.rs)
// ---------------------------------------------------------------------------

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn done_of(events: &[StepEvent], key: u64) -> Option<(&Vec<i32>, usize, FinishReason)> {
    events.iter().find_map(|e| match e {
        StepEvent::Done { key: k, tokens, prompt_len, finish, .. } if *k == key => {
            Some((tokens, *prompt_len, *finish))
        }
        _ => None,
    })
}
