//! Quantized paged-KV tests: group-wise affine round-trip error must
//! stay within the analytic one-step bound on KV-shaped data, the
//! 16-bit layout must stay bitwise identical to the f32 paged oracle,
//! CoW / truncate invariants must survive sealed pages, and quantized
//! scheduler decodes must be deterministic while shrinking peak
//! resident KV bytes by >= 3x.  Everything runs without artifacts.

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::kernels::dequant::kv_dequant_scalar;
use repro::model::{ParamStore, TINY};
use repro::quant::QuantSpec;
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::{BlockPool, KvLayout, KvSegment, PagedKvCache, SchedConfig, Scheduler};
use repro::tensor::{Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(len: usize, seed: u64) -> Vec<i32> {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(1, len)
        .lm_batch(&corpus, &mut Rng::new(seed ^ 0x77))
        .tokens
        .data()
        .to_vec()
}

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn done_of(events: &[StepEvent], key: u64) -> Option<(&Vec<i32>, FinishReason)> {
    events.iter().find_map(|e| match e {
        StepEvent::Done { key: k, tokens, finish, .. } if *k == key => Some((tokens, *finish)),
        _ => None,
    })
}

/// Dequantize one sealed block's `layer` rows into a Vec.
fn dequant_layer(pool: &BlockPool, id: usize, layer: usize, rows: usize) -> (Vec<f32>, Vec<f32>) {
    match pool.segment(id, layer, rows) {
        KvSegment::Quant { k, v, rows: r } => {
            assert_eq!(r, rows);
            let mut kd = vec![0.0f32; rows * pool.d()];
            let mut vd = vec![0.0f32; rows * pool.d()];
            kv_dequant_scalar(&k, 0, &mut kd);
            kv_dequant_scalar(&v, 0, &mut vd);
            (kd, vd)
        }
        KvSegment::F32(k, v) => (k.to_vec(), v.to_vec()),
    }
}

// ---------------------------------------------------------------------------
// affine round-trip on KV-shaped data: error within the analytic bound
// ---------------------------------------------------------------------------

#[test]
fn affine_roundtrip_error_within_group_bound() {
    // Awkward head dims (24/12, 40/10) alongside the TINY geometry
    // (64/64).  The KV grid includes zero in every group's range
    // (lo = min(min, 0), hi = max(max, 0)) so the u8 zero-point never
    // clamps away one-sided groups; the analytic bound is one step
    // s = (hi - lo) / (2^bits - 1) per value (s/2 rounding + s/2
    // worst-case zero-point slack).
    for &(d, group) in &[(24usize, 12usize), (40, 10), (64, 64)] {
        for &bits in &[4u32, 8] {
            let layers = 2usize;
            let bs = 4usize;
            let layout = KvLayout::Quant { bits, group };
            let mut pool = BlockPool::with_layout(layers, d, bs, 4, layout);
            let id = pool.try_alloc().unwrap();

            // KV-shaped data: per-row varying magnitude, both signs.
            let mut rng = Rng::new(0xC0DE + d as u64 + bits as u64);
            let n = layers * bs * d;
            let plane_k = Tensor::randn(&[n, 1], 1.3, &mut rng).data().to_vec();
            let plane_v = Tensor::randn(&[n, 1], 0.4, &mut rng).data().to_vec();
            for layer in 0..layers {
                let off = layer * bs * d;
                pool.write_rows(
                    id,
                    layer,
                    0,
                    &plane_k[off..off + bs * d],
                    &plane_v[off..off + bs * d],
                );
            }
            pool.seal_block(id);
            assert!(pool.is_sealed(id));

            for layer in 0..layers {
                let (kd, vd) = dequant_layer(&pool, id, layer, bs);
                let off = layer * bs * d;
                for (plane, deq, tag) in
                    [(&plane_k, &kd, "K"), (&plane_v, &vd, "V")]
                {
                    for g0 in (0..bs * d).step_by(group) {
                        let orig = &plane[off + g0..off + g0 + group];
                        let got = &deq[g0..g0 + group];
                        let mx = orig.iter().fold(0.0f32, |a, &x| a.max(x));
                        let mn = orig.iter().fold(0.0f32, |a, &x| a.min(x));
                        let step = (mx - mn) / ((1u32 << bits) - 1) as f32;
                        let err = orig
                            .iter()
                            .zip(got.iter())
                            .map(|(a, b)| (a - b).abs())
                            .fold(0.0f32, f32::max);
                        assert!(
                            err <= step + 1e-5,
                            "{tag} d={d} group={group} bits={bits} layer={layer}: \
                             max err {err} > step {step}"
                        );
                    }
                }
            }
            pool.release(id);
        }
    }
}

// ---------------------------------------------------------------------------
// kv-bits=16 == today's f32 paged path, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn kv16_layout_is_bitwise_identical_to_f32_paged_oracle() {
    // `--kv-bits 16` resolves to KvLayout::F32; pool construction via
    // with_layout + the end-of-tick seal calls must leave decode bitwise
    // identical to the pre-layout paged path (itself the flat oracle).
    let cfg = SchedConfig { kv_bits: 16, ..SchedConfig::default() };
    assert_eq!(cfg.kv_layout(64), KvLayout::F32);

    let model = packed_tiny(31);
    let toks = tiny_prompt(10, 51);

    let mut flat_cache = repro::serve::KvCache::new(TINY.n_layers, TINY.d_model, 16);
    let flat_chunk = model.forward_chunk(&toks, &mut flat_cache).unwrap();

    let mut pool =
        BlockPool::with_layout(TINY.n_layers, TINY.d_model, 3, 16, KvLayout::F32);
    let mut cache = PagedKvCache::new(&pool);
    let paged_chunk = model.forward_chunk_paged(&toks, &mut cache, &mut pool).unwrap();
    assert_eq!(paged_chunk.data(), flat_chunk.data(), "prefill logits differ");

    // Sealing is a no-op under f32 — nothing quantizes, bytes stay full.
    cache.seal_committed(&mut pool);
    for &id in cache.table() {
        assert!(!pool.is_sealed(id), "f32 layout must never seal");
    }
    let s = pool.stats();
    assert_eq!(s.kv_bits, 16);
    assert_eq!(s.block_bytes, s.f32_block_bytes);
    assert_eq!(s.resident_bytes, s.resident_blocks * s.f32_block_bytes);

    let next = [toks[3]];
    let mut refs = vec![&mut flat_cache];
    let flat_step = model.forward_step(&next, &mut refs).unwrap();
    let mut prefs = vec![&mut cache];
    let paged_step = model.forward_step_paged(&next, &mut prefs, &mut pool).unwrap();
    assert_eq!(paged_step.data(), flat_step.data(), "decode step logits differ");
}

// ---------------------------------------------------------------------------
// CoW / truncate invariants under a quantized layout
// ---------------------------------------------------------------------------

#[test]
fn cow_and_truncate_survive_sealed_pages() {
    let (layers, d, bs, group) = (2usize, 16usize, 4usize, 8usize);
    let layout = KvLayout::Quant { bits: 8, group };
    let mut pool = BlockPool::with_layout(layers, d, bs, 16, layout);

    // Parent: 8 committed positions = 2 full pages, sealed.
    let mut parent = PagedKvCache::new(&pool);
    parent.reserve(8, &mut pool).unwrap();
    let mut rng = Rng::new(77);
    for pos in 0..8usize {
        let id = parent.block_at(pos);
        let slot = pos % bs;
        for layer in 0..layers {
            let k = Tensor::randn(&[d, 1], 1.0, &mut rng).data().to_vec();
            let v = Tensor::randn(&[d, 1], 1.0, &mut rng).data().to_vec();
            pool.write_rows(id, layer, slot, &k, &v);
        }
    }
    parent.advance(8);
    parent.seal_committed(&mut pool);
    assert!(pool.is_sealed(parent.block_at(0)) && pool.is_sealed(parent.block_at(4)));
    let before: Vec<_> = (0..2)
        .map(|b| dequant_layer(&pool, parent.block_at(b * bs), 1, bs))
        .collect();

    // Fork at an unaligned boundary (6 of 8): the child shares both
    // pages; writing its own position 6 must CoW the sealed tail page
    // privately and leave the parent's sealed reads bitwise unchanged.
    let mut child = PagedKvCache::fork_prefix(&parent, 6, &mut pool).unwrap();
    assert_eq!(child.block_at(4), parent.block_at(4), "tail page shared pre-write");
    child.reserve(7, &mut pool).unwrap();
    let cid = child.block_at(6);
    assert_ne!(cid, parent.block_at(4), "CoW must split the shared sealed page");
    let junk = vec![9.0f32; d];
    for layer in 0..layers {
        pool.write_rows(cid, layer, 2, &junk, &junk);
    }
    child.advance(1);
    let after: Vec<_> = (0..2)
        .map(|b| dequant_layer(&pool, parent.block_at(b * bs), 1, bs))
        .collect();
    assert_eq!(before, after, "parent's sealed rows changed under child CoW");
    // The child's private copy carries the parent's dequantized prefix
    // rows bitwise (reopen reproduces exactly what sealed reads gave).
    let (ck, cv) = match pool.segment(cid, 1, 2) {
        KvSegment::F32(k, v) => (k.to_vec(), v.to_vec()),
        KvSegment::Quant { .. } => panic!("freshly CoW'd page must be staged"),
    };
    assert_eq!(&ck[..], &before[1].0[..2 * d], "child K prefix drifted");
    assert_eq!(&cv[..], &before[1].1[..2 * d], "child V prefix drifted");

    // Truncate the child back below the fork and regrow: the released
    // page returns to the pool; rebuilt state stays self-consistent.
    child.truncate(4, &mut pool);
    child.reserve(5, &mut pool).unwrap();
    for layer in 0..layers {
        pool.write_rows(child.block_at(4), layer, 0, &junk, &junk);
    }
    child.advance(1);
    let final_parent: Vec<_> = (0..2)
        .map(|b| dequant_layer(&pool, parent.block_at(b * bs), 1, bs))
        .collect();
    assert_eq!(before, final_parent, "parent changed under child truncate/regrow");

    child.release_all(&mut pool);
    parent.release_all(&mut pool);
    assert_eq!(pool.stats().used_blocks, 0, "pages leaked");
}

// ---------------------------------------------------------------------------
// scheduler: quantized decode is deterministic and shrinks peak KV bytes
// ---------------------------------------------------------------------------

fn run_sched(model: &PackedModel, kv_bits: u32, prompts: &[Vec<i32>]) -> (Vec<Vec<i32>>, usize) {
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 128,
        max_prompt: 64,
        kv_block: 4,
        kv_blocks_total: 80,
        kv_bits,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::new(model, cfg);
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(req(i as u64 + 1, p.clone(), 120));
    }
    let events = drain(&mut sched);
    let streams = (0..prompts.len())
        .map(|i| {
            let (tokens, finish) = done_of(&events, i as u64 + 1).expect("done");
            assert_eq!(finish, FinishReason::Length);
            tokens.clone()
        })
        .collect();
    (streams, sched.kv_stats().peak_resident_bytes)
}

#[test]
fn quantized_decode_is_deterministic_and_cuts_peak_bytes_3x() {
    let model = packed_tiny(41);
    let prompts = vec![tiny_prompt(8, 61), tiny_prompt(8, 62)];

    let (f32_streams, f32_peak) = run_sched(&model, 16, &prompts);
    let (q8_a, q8_peak) = run_sched(&model, 8, &prompts);
    let (q8_b, _) = run_sched(&model, 8, &prompts);
    assert_eq!(q8_a, q8_b, "8-bit KV decode must be run-to-run deterministic");

    // Same requests, same concurrency: quantized pages must cut the peak
    // resident KV footprint by at least 3x (staged f32 tail pages are
    // the only full-width storage left).
    assert!(
        q8_peak * 3 < f32_peak,
        "8-bit peak {q8_peak} not < 1/3 of f32 peak {f32_peak}"
    );

    // 4-bit: same invariants, even smaller.
    let (q4_a, q4_peak) = run_sched(&model, 4, &prompts);
    let (q4_b, _) = run_sched(&model, 4, &prompts);
    assert_eq!(q4_a, q4_b, "4-bit KV decode must be run-to-run deterministic");
    assert!(q4_peak < q8_peak, "4-bit peak {q4_peak} not below 8-bit peak {q8_peak}");

    // Quantized attention reads perturbed history, so streams may differ
    // from the f32 oracle — but they must be the same LENGTH (Length
    // finishes) and the f32 run itself is the bitwise baseline other
    // tests pin.  Guard the shape here.
    for (f, q) in f32_streams.iter().zip(q8_a.iter()) {
        assert_eq!(f.len(), q.len());
    }
}

// ---------------------------------------------------------------------------
// kv-quant ppl harness: finite ppl, small delta, shrunken footprint
// ---------------------------------------------------------------------------

#[test]
fn paged_ppl_harness_reports_small_delta_and_byte_ratio() {
    let model = packed_tiny(47);
    let streams: Vec<Vec<i32>> = (0..2).map(|i| tiny_prompt(48, 80 + i)).collect();
    let hd = TINY.d_model / TINY.n_heads;
    let blocks = 48usize.div_ceil(4) + 1;

    let (ppl16, kv16) = repro::eval::perplexity_paged(
        &model, &streams, 8, 4, blocks, KvLayout::F32,
    )
    .unwrap();
    let (ppl8, kv8) = repro::eval::perplexity_paged(
        &model,
        &streams,
        8,
        4,
        blocks,
        KvLayout::Quant { bits: 8, group: hd },
    )
    .unwrap();
    assert!(ppl16.is_finite() && ppl8.is_finite());
    // 8-bit KV is a storage-side perturbation, not a weight change: the
    // ppl delta on the tiny model must stay small relative to baseline.
    let delta = (ppl8 - ppl16).abs();
    assert!(
        delta < 0.05 * ppl16,
        "8-bit KV ppl {ppl8} drifted more than 5% from f32 ppl {ppl16}"
    );
    assert!(
        kv8.peak_resident_bytes * 2 < kv16.peak_resident_bytes,
        "quantized ppl run must report a shrunken KV footprint \
         ({} vs {})",
        kv8.peak_resident_bytes,
        kv16.peak_resident_bytes
    );
}
