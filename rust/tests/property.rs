//! Property-based tests over the coordinator substrates.
//!
//! The offline registry has no `proptest`, so this file carries a minimal
//! in-tree property harness (`for_cases`): deterministic seeded random
//! cases with failure reporting of the offending seed — the same workflow
//! (shrinking aside) as a proptest run with a fixed RNG.

use repro::data::tasks::{ArithTask, ClassifyTask, McTask, Task};
use repro::data::{vocab, ZipfMarkovCorpus};
use repro::quant::{fakequant, nf_fakequant, pack_codes, quantize_ints, unpack_codes, QuantSpec};
use repro::quant::affine::{open_clip, paper_init_clip, round_ties_even, scales_zeros};
use repro::tensor::{svd_topk, Rng, Tensor};

/// Run `f` over `n` seeded cases; panic with the seed on failure.
fn for_cases(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xBEEF ^ (seed * 7919));
        // run in place; assertion failures identify the case via the
        // message below when running with --nocapture + RUST_BACKTRACE
        eprintln!("[property] case seed {seed}");
        f(&mut rng);
    }
}

// ---------------------------------------------------------------------------
// Quantization invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_codes_always_in_range() {
    for_cases(20, |rng| {
        let bits = [2u32, 3, 4][rng.below(3)];
        let group = [32usize, 64][rng.below(2)];
        let gpc = 1 + rng.below(3);
        let d_in = group * gpc;
        let d_out = 8 + rng.below(56);
        let w = Tensor::randn(&[d_in, d_out], rng.uniform(0.01, 2.0), rng);
        let (g, b) = paper_init_clip(d_in, d_out, group);
        let spec = QuantSpec::new(bits, group);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let max = (1u32 << bits) - 1;
        assert!(codes.iter().all(|&c| c <= max));
        // scales positive, zeros in range
        assert!(s.data().iter().all(|&v| v > 0.0));
        assert!(z.data().iter().all(|&v| (0.0..=max as f32).contains(&v)));
    });
}

#[test]
fn prop_fakequant_error_bounded_by_scale() {
    // |w - Q(w)| <= s/2 for every unclipped weight (grid property).
    for_cases(15, |rng| {
        let d_in = 64;
        let d_out = 16;
        let w = Tensor::randn(&[d_in, d_out], 0.3, rng);
        let (g, b) = open_clip(d_in, d_out, 64);
        let spec = QuantSpec::new(3, 64);
        let (s, _) = scales_zeros(&w, &g, &b, spec).unwrap();
        let q = fakequant(&w, &g, &b, spec).unwrap();
        for r in 0..d_in {
            for c in 0..d_out {
                let err = (w.at2(r, c) - q.at2(r, c)).abs();
                // open clip: nothing is clipped, so grid bound holds
                assert!(
                    err <= s.at2(0, c) * 0.5 + 1e-5,
                    "err {err} > s/2 {}",
                    s.at2(0, c) * 0.5
                );
            }
        }
    });
}

/// Slow-but-obvious round-half-to-even reference.  Works at any f32
/// magnitude: values with |x| >= 2^23 are already integral (fract 0), so
/// the tie branch is only reached where floor() is exactly representable
/// and the `(f/2).floor()*2 == f` evenness test is exact.
fn ref_round_ties_even(x: f32) -> f32 {
    if !x.is_finite() {
        return x;
    }
    let f = x.floor();
    let d = x - f;
    if d < 0.5 {
        f
    } else if d > 0.5 {
        f + 1.0
    } else if (f / 2.0).floor() * 2.0 == f {
        f
    } else {
        f + 1.0
    }
}

#[test]
fn prop_round_ties_even_matches_reference() {
    for_cases(25, |rng| {
        // random magnitudes across the whole f32 exponent range
        for _ in 0..200 {
            let exp = rng.uniform(-30.0, 30.0);
            let x = rng.uniform(-1.0, 1.0) * 10f32.powf(exp);
            let got = round_ties_even(x);
            let want = ref_round_ties_even(x);
            // numeric equality (-0.0 == 0.0): the reference does not
            // model the IEEE sign-of-zero rule
            assert_eq!(got, want, "x={x}: {got} vs {want}");
        }
        // exact ties, both signs (k + 0.5 is exactly representable here)
        for _ in 0..100 {
            let k = rng.below(100_000) as f32 - 50_000.0;
            let x = k + 0.5;
            let got = round_ties_even(x);
            assert_eq!(got, ref_round_ties_even(x), "tie at {x}");
            assert_eq!(got % 2.0, 0.0, "tie at {x} must land on an even integer");
        }
        // ties produced by FP division (the case the old exact-compare
        // implementation was fragile around)
        for _ in 0..100 {
            let q = rng.below(2000) as f32 - 1000.0;
            let s = 2f32.powi(rng.below(8) as i32 - 4); // power of two: q/2s + exact halves
            let x = (q + 0.5) * s / s;
            assert_eq!(round_ties_even(x), ref_round_ties_even(x), "x={x}");
        }
        // huge magnitudes: fixed points, no i64 overflow hazards
        for x in [1e12f32, -1e12, 9.2e18, -9.2e18, 1e30, -1e30, f32::MAX, f32::MIN] {
            assert_eq!(round_ties_even(x), x);
        }
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    for_cases(30, |rng| {
        let bits = [2u32, 3, 4, 5, 8][rng.below(5)];
        let n = 1 + rng.below(2000);
        let mask = (1u32 << bits) - 1;
        let codes: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & mask).collect();
        let packed = pack_codes(&codes, bits);
        assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
        assert_eq!(unpack_codes(&packed, bits, n), codes);
    });
}

#[test]
fn prop_nf_fakequant_idempotent() {
    for_cases(10, |rng| {
        let w = Tensor::randn(&[128, 8], rng.uniform(0.05, 1.0), rng);
        let q1 = nf_fakequant(&w, 3, 64).unwrap();
        let q2 = nf_fakequant(&q1, 3, 64).unwrap();
        let d = q1.sub(&q2).unwrap().fro_norm();
        assert!(d < 1e-5, "nf not idempotent: {d}");
    });
}

// ---------------------------------------------------------------------------
// Linalg invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_svd_reconstruction_never_worse_than_zero_rank() {
    for_cases(10, |rng| {
        let m = 16 + rng.below(32);
        let n = 16 + rng.below(32);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let k = 1 + rng.below(6);
        let (u, s, v) = svd_topk(&a, k, 25, rng).unwrap();
        let mut rec = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..k {
                    acc += u.at2(i, l) * s[l] * v.at2(j, l);
                }
                rec.set2(i, j, acc);
            }
        }
        let resid = a.sub(&rec).unwrap().fro_norm();
        assert!(resid <= a.fro_norm() * 1.0001, "rank-{k} residual grew");
        // singular values non-negative, sorted
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    });
}

#[test]
fn prop_matmul_matches_naive() {
    for_cases(10, |rng| {
        let (m, k, n) = (1 + rng.below(20), 1 + rng.below(20), 1 + rng.below(20));
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        let c = a.matmul(&b).unwrap();
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for l in 0..k {
                    s += a.at2(i, l) * b.at2(l, j);
                }
                assert!((c.at2(i, j) - s).abs() < 1e-3);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Data-substrate invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_corpus_tokens_in_vocab() {
    for_cases(10, |rng| {
        let vocab_size = 64 + rng.below(1984);
        let corpus = ZipfMarkovCorpus::new(vocab_size, rng.next_u64());
        let len = 16 + rng.below(240);
        let seq = corpus.sequence(len, rng);
        assert_eq!(seq.len(), len);
        assert!(seq.iter().all(|&t| (0..vocab_size as i32).contains(&t)));
    });
}

#[test]
fn prop_task_samples_well_formed() {
    for_cases(15, |rng| {
        let tasks: Vec<Box<dyn Task>> = vec![
            Box::new(ArithTask::add(512, rng.next_u64())),
            Box::new(ArithTask::sub(512, rng.next_u64())),
            Box::new(ArithTask::mul1(512, rng.next_u64())),
            Box::new(ClassifyTask::new(512, 2 + rng.below(6), rng.next_u64())),
            Box::new(McTask::pattern(512, rng.next_u64() % 8)),
            Box::new(McTask::arith_mc(512, 3)),
        ];
        let seq_len = 64 + rng.below(64);
        for t in &tasks {
            let s = t.sample(seq_len, rng);
            assert_eq!(s.tokens.len(), seq_len);
            assert_eq!(s.mask.len(), seq_len);
            assert_eq!(s.answer_pos.len(), s.answer.len());
            // mask positions == answer positions, all within range, not 0
            for (&p, &a) in s.answer_pos.iter().zip(&s.answer) {
                assert!(p > 0 && p < seq_len);
                assert_eq!(s.tokens[p], a);
                assert!(s.mask[p] > 0.0);
            }
            let mask_on = s.mask.iter().filter(|&&m| m > 0.0).count();
            assert_eq!(mask_on, s.answer_pos.len());
            // answers never PAD/BOS
            assert!(s.answer.iter().all(|&a| a != vocab::PAD && a != vocab::BOS));
        }
    });
}

#[test]
fn prop_arith_answers_match_semantics() {
    for_cases(20, |rng| {
        let t = ArithTask::add(512, rng.next_u64());
        let s = t.sample(128, rng);
        // decode "a + b = c" from tokens and check the arithmetic
        let toks = &s.tokens;
        let plus = toks.iter().position(|&x| x == vocab::PLUS).unwrap();
        let eq = toks.iter().position(|&x| x == vocab::EQ).unwrap();
        let read_num = |range: &[i32]| -> u32 {
            range
                .iter()
                .filter(|&&x| (vocab::DIGIT0..vocab::DIGIT0 + 10).contains(&x))
                .fold(0u32, |acc, &d| acc * 10 + (d - vocab::DIGIT0) as u32)
        };
        // digits of a immediately precede PLUS; of b between PLUS and EQ
        let a_start = (0..plus)
            .rev()
            .take_while(|&i| (vocab::DIGIT0..vocab::DIGIT0 + 10).contains(&toks[i]))
            .last()
            .unwrap();
        let a = read_num(&toks[a_start..plus]);
        let b = read_num(&toks[plus + 1..eq]);
        let c = read_num(&s.answer);
        assert_eq!(a + b, c, "bad sample: {a} + {b} != {c}");
    });
}

// ---------------------------------------------------------------------------
// Store / checkpoint invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_checkpoint_roundtrip_arbitrary_stores() {
    for_cases(10, |rng| {
        let mut ps = repro::model::ParamStore::new();
        let n = 1 + rng.below(20);
        for i in 0..n {
            let rank = 1 + rng.below(3);
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(12)).collect();
            ps.insert(format!("k{i}.sub.{}", rng.below(100)), Tensor::randn(&shape, 1.0, rng));
        }
        let path = std::env::temp_dir().join(format!("apiq_prop_{}.ckpt", rng.next_u64()));
        repro::model::checkpoint::save(&ps, &path).unwrap();
        let back = repro::model::checkpoint::load(&path).unwrap();
        assert_eq!(back.len(), ps.len());
        for (k, v) in ps.iter() {
            assert_eq!(back.get(k).unwrap(), v);
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_view_absorb_identity() {
    for_cases(10, |rng| {
        let mut ps = repro::model::ParamStore::new();
        for b in 0..3 {
            for lin in ["wq", "wo"] {
                ps.insert(format!("blocks.{b}.{lin}"), Tensor::randn(&[4, 4], 1.0, rng));
            }
        }
        let orig = ps.clone();
        for b in 0..3 {
            let prefix = format!("blocks.{b}.");
            let v = ps.view(&prefix);
            ps.absorb(&prefix, &v);
        }
        for (k, t) in orig.iter() {
            assert_eq!(ps.get(k).unwrap(), t);
        }
    });
}
