//! Integration tests over the full stack: HLO artifacts + PJRT runtime +
//! coordinator.  These need `make artifacts` to have produced the tiny
//! artifacts; they self-skip (with a loud message) when missing so unit
//! test runs stay green on a fresh checkout.

use repro::calib::CalibStreams;
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::eval::{nll_from_logits, Evaluator, ModelMode};
use repro::model::{ParamStore, TINY};
use repro::quant::{fakequant, QuantSpec};
use repro::runtime::{Bindings, Runtime};
use repro::tensor::{Rng, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    let rt = Runtime::new("artifacts").ok()?;
    if !rt.has_artifact("logits_fp_tiny") {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(rt)
}

fn tiny_setup(rt: &Runtime) -> (ParamStore, ZipfMarkovCorpus) {
    let params = TINY.init_params(11);
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, 11);
    let _ = rt;
    (params, corpus)
}

#[test]
fn fakequant_artifact_matches_host_quantizer() {
    // THE cross-layer consistency check: the Rust affine quantizer must be
    // bit-compatible with the L1 Pallas kernel lowered into the artifact.
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let w = Tensor::randn(&[256, 256], 0.1, &mut rng);
    let spec = QuantSpec::new(2, 64);
    let gamma = Tensor::full(&[4, 256], 4.0);
    let beta = Tensor::full(&[4, 256], 4.0);
    let host = fakequant(&w, &gamma, &beta, spec).unwrap();

    let bind = Bindings::new()
        .tensor("w", &w)
        .tensor("gamma", &gamma)
        .tensor("beta", &beta)
        .scalar("bits", 2.0);
    let out = rt.run("fakequant_256x256_g64", &bind).unwrap();
    let dev = out.get("q").unwrap();
    let diff = host.sub(dev).unwrap().fro_norm() / host.fro_norm().max(1e-9);
    assert!(diff < 1e-5, "host vs artifact fakequant rel diff {diff}");
}

#[test]
fn fakequant_artifact_matches_host_at_all_bits() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(4);
    let w = Tensor::randn(&[256, 768], 0.2, &mut rng);
    let gamma = Tensor::full(&[4, 768], 4.0);
    let beta = Tensor::full(&[4, 768], 4.0);
    for bits in [2u32, 3, 4] {
        let spec = QuantSpec::new(bits, 64);
        let host = fakequant(&w, &gamma, &beta, spec).unwrap();
        let bind = Bindings::new()
            .tensor("w", &w)
            .tensor("gamma", &gamma)
            .tensor("beta", &beta)
            .scalar("bits", bits as f32);
        let out = rt.run("fakequant_256x768_g64", &bind).unwrap();
        let diff = host.sub(out.get("q").unwrap()).unwrap().fro_norm();
        assert!(diff < 1e-3, "bits={bits}: diff {diff}");
    }
}

#[test]
fn logits_fp_finite_and_causal_shape() {
    let Some(rt) = runtime_or_skip() else { return };
    let (params, corpus) = tiny_setup(&rt);
    let batch = Batcher::new(TINY.batch, TINY.seq_len).lm_batch(&corpus, &mut Rng::new(5));
    let ev = Evaluator::new(&rt, TINY);
    let logits = ev.logits(&ModelMode::Fp, &params, None, &batch).unwrap();
    assert_eq!(logits.shape(), &[TINY.batch, TINY.seq_len, TINY.vocab]);
    assert!(logits.all_finite());
    let (nll, cnt) = nll_from_logits(&logits, &batch, TINY.vocab);
    // untrained model ≈ uniform -> mean nll ≈ ln(V)
    let mean = nll / cnt;
    assert!((mean - (TINY.vocab as f64).ln()).abs() < 0.5, "mean nll {mean}");
}

#[test]
fn quant_identity_path_matches_fp() {
    // bits=16 + open clip + B=0 through logits_q must reproduce logits_fp.
    let Some(rt) = runtime_or_skip() else { return };
    let (params, corpus) = tiny_setup(&rt);
    let mut qp = TINY.init_qparams(QuantSpec::new(16, 64), 16, false, 7);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with("gamma") || key.ends_with("beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        }
    }
    let batch = Batcher::new(TINY.batch, TINY.seq_len).lm_batch(&corpus, &mut Rng::new(6));
    let ev = Evaluator::new(&rt, TINY);
    let l_fp = ev.logits(&ModelMode::Fp, &params, None, &batch).unwrap();
    let mode = ModelMode::Quant { rank: 16, group: 64, bits: 16.0, scale: 1.0, dora: false };
    let l_q = ev.logits(&mode, &params, Some(&qp), &batch).unwrap();
    let diff = l_fp.sub(&l_q).unwrap().abs_max();
    assert!(diff < 0.05, "identity-quant logits differ by {diff}");
}

#[test]
fn pretrain_step_decreases_loss_through_runtime() {
    let Some(rt) = runtime_or_skip() else { return };
    let (mut params, corpus) = tiny_setup(&rt);
    let trainer = repro::train::Pretrainer::new(&rt, TINY, 12);
    let report = trainer.train(&mut params, &corpus, 12, 9).unwrap();
    assert_eq!(report.losses.len(), 12);
    assert!(
        report.losses[11] < report.losses[0],
        "loss did not decrease: {:?}",
        report.losses
    );
    params.check_finite().unwrap();
}

#[test]
fn calib_streams_propagate_and_diverge() {
    // With 2-bit quantization and default init, the q stream must diverge
    // from the fp stream as it passes blocks (the §3.2 error accumulation).
    let Some(rt) = runtime_or_skip() else { return };
    let (params, corpus) = tiny_setup(&rt);
    let batcher = Batcher::new(TINY.calib_batch, TINY.seq_len);
    let batches = vec![batcher.lm_batch(&corpus, &mut Rng::new(10))];
    let mut streams = CalibStreams::init(&rt, TINY, &params, &batches).unwrap();
    let qp = TINY.init_qparams(QuantSpec::new(2, 64), 16, false, 8);
    let mut divergences = Vec::new();
    for b in 0..TINY.n_layers {
        let bp = params.view(&format!("blocks.{b}."));
        let bqp = qp.view(&format!("blocks.{b}."));
        streams.advance_q(&rt, &bp, &bqp, 16, 64, 2.0, 1.0).unwrap();
        streams.advance_fp(&rt, &bp).unwrap();
        let d = streams.x_fp[0].sub(&streams.x_q[0]).unwrap().fro_norm();
        divergences.push(d);
    }
    assert!(divergences[0] > 0.0);
    // error accumulates through depth (documented §3.2 behaviour)
    assert!(
        divergences[TINY.n_layers - 1] > divergences[0],
        "{divergences:?}"
    );
}

#[test]
fn apiq_bw_reduces_activation_error_vs_rtn_init() {
    // Small-budget ApiQ-bw on one env: the calibrated q-stream must track
    // the fp stream better than the uncalibrated one (the paper's core
    // mechanism at integration scale).
    let Some(rt) = runtime_or_skip() else { return };
    let (params, corpus) = tiny_setup(&rt);
    let batcher = Batcher::new(TINY.calib_batch, TINY.seq_len);
    let batches: Vec<_> = (0..2).map(|i| batcher.lm_batch(&corpus, &mut Rng::new(20 + i))).collect();

    let divergence = |qp: &ParamStore| {
        let mut streams = CalibStreams::init(&rt, TINY, &params, &batches).unwrap();
        for b in 0..TINY.n_layers {
            let bp = params.view(&format!("blocks.{b}."));
            let bqp = qp.view(&format!("blocks.{b}."));
            streams.advance_q(&rt, &bp, &bqp, 16, 64, 2.0, 1.0).unwrap();
            streams.advance_fp(&rt, &bp).unwrap();
        }
        streams.x_fp[0].sub(&streams.x_q[0]).unwrap().fro_norm()
    };

    let qp_init = TINY.init_qparams(QuantSpec::new(2, 64), 16, false, 8);
    let err_before = divergence(&qp_init);

    let ctx = repro::quantizers::QuantizeCtx {
        runtime: &rt,
        cfg: TINY,
        params: &params,
        spec: QuantSpec::new(2, 64),
        rank: 16,
        scale: 1.0,
        calib: &batches,
        seed: 8,
        verbose: false,
    };
    use repro::quantizers::Quantizer;
    let apiq = repro::quantizers::ApiQ::bw().with_hyper(repro::quantizers::ApiQHyper {
        epochs: 4,
        ..Default::default()
    });
    let result = apiq.quantize(&ctx).unwrap();
    let err_after = divergence(&result.qparams);
    assert!(
        err_after < err_before,
        "apiq-bw did not reduce stream divergence: {err_before} -> {err_after}"
    );
}

#[test]
fn finetune_step_reduces_task_loss_through_runtime() {
    let Some(rt) = runtime_or_skip() else { return };
    let (params, _) = tiny_setup(&rt);
    let qp0 = TINY.init_qparams(QuantSpec::new(4, 64), 16, false, 9);
    let task = repro::data::tasks::ArithTask::add(TINY.vocab, 4);
    let ft = repro::train::Finetuner::new(&rt, TINY, 16, 64, 30);
    let mut qp = qp0;
    let report = ft
        .train(
            &params,
            &mut qp,
            4.0,
            1.0,
            &repro::train::FinetuneData::Task(&task),
            30,
            13,
        )
        .unwrap();
    let first3: f32 = report.losses[..3].iter().sum::<f32>() / 3.0;
    let last3 = report.tail_mean(3);
    assert!(last3 < first3, "{first3} -> {last3}");
}

#[test]
fn runtime_rejects_bad_bindings() {
    let Some(rt) = runtime_or_skip() else { return };
    // missing binding
    let bind = Bindings::new();
    assert!(rt.run("fakequant_256x256_g64", &bind).is_err());
    // wrong shape
    let w = Tensor::zeros(&[128, 256]);
    let gamma = Tensor::full(&[4, 256], 4.0);
    let beta = Tensor::full(&[4, 256], 4.0);
    let bind = Bindings::new()
        .tensor("w", &w)
        .tensor("gamma", &gamma)
        .tensor("beta", &beta)
        .scalar("bits", 2.0);
    let err = rt.run("fakequant_256x256_g64", &bind);
    assert!(err.is_err());
    // unknown artifact
    assert!(rt.run("nonexistent_artifact", &Bindings::new()).is_err());
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(rt) = runtime_or_skip() else { return };
    let (params, corpus) = tiny_setup(&rt);
    let dir = std::env::temp_dir().join("apiq_it_ckpt");
    let path = dir.join("params.ckpt");
    repro::model::checkpoint::save(&params, &path).unwrap();
    let params2 = repro::model::checkpoint::load(&path).unwrap();
    let batch = Batcher::new(TINY.batch, TINY.seq_len).lm_batch(&corpus, &mut Rng::new(30));
    let ev = Evaluator::new(&rt, TINY);
    let l1 = ev.logits(&ModelMode::Fp, &params, None, &batch).unwrap();
    let l2 = ev.logits(&ModelMode::Fp, &params2, None, &batch).unwrap();
    assert_eq!(l1, l2);
    std::fs::remove_file(&path).ok();
}
