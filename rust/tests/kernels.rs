//! Property + determinism tests for the SIMD compute core.
//!
//! The contract under test (see `rust/src/kernels/mod.rs`):
//!
//! * scalar is the reference oracle — the dispatched kernel must match
//!   it within 1e-5 relative on the fused path vs the dequantized dense
//!   product, and BITWISE against the scalar kernel itself;
//! * output is bitwise identical at 1, 2, and N pool threads;
//! * the GEMV decode path is bitwise identical to the panel path, so
//!   greedy decode streams cannot depend on the kernel choice.
//!
//! Shapes are deliberately awkward: d_out not a multiple of the 8-lane
//! width or the 64-column tile, n_tok 1..4, k not a multiple of the
//! k-block, bits {2, 3, 4, 8}, several group sizes.

use repro::kernels::dequant::{fused_gemv, fused_matmul, unpack_run};
use repro::kernels::gemm::gemm_accum_with;
use repro::kernels::pool::ThreadPool;
use repro::kernels::{active, simd_supported, Kernel};
use repro::quant::affine::open_clip;
use repro::quant::{quantize_ints, PackedLinear, QuantSpec};
use repro::tensor::{Rng, Tensor};

fn packed_case(bits: u32, group: usize, d_in: usize, d_out: usize, seed: u64) -> PackedLinear {
    let mut rng = Rng::new(seed);
    let spec = QuantSpec::new(bits, group);
    let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
    let (g, b) = open_clip(d_in, d_out, group);
    let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
    PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec).unwrap()
}

fn rel_err(got: &Tensor, want: &Tensor) -> f32 {
    got.sub(want).unwrap().fro_norm() / want.fro_norm().max(1e-12)
}

#[test]
fn fused_kernels_match_dense_oracle_across_shapes() {
    let pool = ThreadPool::with_threads(3);
    // (bits, group, d_in, d_out): d_out 37 trips the SIMD tail, 83 trips
    // the 64-col tile tail, d_in 300 with group 20 is no multiple of any
    // k-block, bits 8 exercises the widest codes.
    let cases = [
        (2u32, 64usize, 128usize, 37usize),
        (3, 16, 48, 83),
        (4, 20, 300, 64),
        (8, 32, 96, 130),
    ];
    let mut seed = 100;
    for (bits, group, d_in, d_out) in cases {
        let pl = packed_case(bits, group, d_in, d_out, seed);
        let dense_w = pl.dequantize().unwrap();
        for n_tok in [1usize, 2, 3, 4, 5, 9] {
            seed += 1;
            let x = Tensor::randn(&[n_tok, d_in], 1.0, &mut Rng::new(seed));
            let want = x.matmul(&dense_w).unwrap();
            for kernel in [Kernel::Scalar, active()] {
                let panel = pl.matmul_fused_with(kernel, &pool, &x).unwrap();
                let gemv = pl.matvec_fused_with(kernel, &pool, &x).unwrap();
                let e = rel_err(&panel, &want);
                assert!(
                    e <= 1e-5,
                    "bits={bits} g={group} {d_in}x{d_out} n_tok={n_tok} {}: rel {e}",
                    kernel.name()
                );
                assert_eq!(
                    panel.data(),
                    gemv.data(),
                    "GEMV vs panel must be bitwise identical ({} n_tok={n_tok})",
                    kernel.name()
                );
            }
            // scalar vs dispatched kernel: bitwise, not just 1e-5
            let scalar = pl.matmul_fused_with(Kernel::Scalar, &pool, &x).unwrap();
            let dispatched = pl.matmul_fused_with(active(), &pool, &x).unwrap();
            assert_eq!(
                scalar.data(),
                dispatched.data(),
                "dispatched kernel must reproduce the scalar oracle bitwise"
            );
        }
    }
}

#[test]
fn fused_matmul_bitwise_deterministic_across_thread_counts() {
    // Big enough that even the batch-1 GEMV clears the parallel
    // threshold, so the pools genuinely engage.
    let pl = packed_case(2, 64, 512, 384, 7);
    let x = Tensor::randn(&[6, 512], 1.0, &mut Rng::new(8));
    let xv = Tensor::randn(&[1, 512], 1.0, &mut Rng::new(9));
    let kernel = active();
    let p1 = ThreadPool::with_threads(1);
    let baseline = pl.matmul_fused_with(kernel, &p1, &x).unwrap();
    let gemv_baseline = pl.matvec_fused_with(kernel, &p1, &xv).unwrap();
    for threads in [2usize, 4, 8] {
        let pn = ThreadPool::with_threads(threads);
        for _run in 0..3 {
            let out = pl.matmul_fused_with(kernel, &pn, &x).unwrap();
            assert_eq!(out.data(), baseline.data(), "{threads} threads, panel path");
            let out = pl.matvec_fused_with(kernel, &pn, &xv).unwrap();
            assert_eq!(out.data(), gemv_baseline.data(), "{threads} threads, GEMV path");
        }
    }
}

#[test]
fn dense_gemm_bitwise_deterministic_across_threads_and_kernels() {
    let (m, k, n) = (65, 130, 100); // above threshold, every tail hit
    let mut rng = Rng::new(17);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let p1 = ThreadPool::with_threads(1);
    let mut baseline = vec![0.0f32; m * n];
    gemm_accum_with(Kernel::Scalar, &p1, a.data(), b.data(), &mut baseline, m, k, n);
    for kernel in [Kernel::Scalar, active()] {
        for threads in [1usize, 2, 5] {
            let pool = ThreadPool::with_threads(threads);
            let mut out = vec![0.0f32; m * n];
            gemm_accum_with(kernel, &pool, a.data(), b.data(), &mut out, m, k, n);
            assert_eq!(
                out, baseline,
                "kernel {} at {threads} threads must match the scalar 1-thread oracle bitwise",
                kernel.name()
            );
        }
    }
}

#[test]
fn gemm_propagates_nan_and_inf_through_simd_lanes() {
    // 0 * NaN / 0 * inf must poison the output on every kernel; wide
    // enough that the SIMD main loop (not just the tail) sees them.
    let (m, k, n) = (2, 3, 40);
    let a = Tensor::zeros(&[m, k]);
    let mut b = Tensor::zeros(&[k, n]);
    b.data_mut()[0] = f32::NAN; // lane 0 of the vector loop
    b.data_mut()[n + 13] = f32::INFINITY;
    let pool = ThreadPool::with_threads(2);
    for kernel in [Kernel::Scalar, active()] {
        let mut out = vec![0.0f32; m * n];
        gemm_accum_with(kernel, &pool, a.data(), b.data(), &mut out, m, k, n);
        assert!(out[0].is_nan(), "{}: 0 * NaN must stay NaN", kernel.name());
        assert!(out[13].is_nan(), "{}: 0 * inf must produce NaN", kernel.name());
    }
}

#[test]
fn raw_fused_entry_points_accept_partial_sums() {
    // fused_matmul / fused_gemv accumulate onto out rather than zeroing
    // it — the contract chained callers rely on.
    let pl = packed_case(4, 16, 32, 48, 77);
    let x = Tensor::randn(&[2, 32], 1.0, &mut Rng::new(78));
    let pool = ThreadPool::with_threads(2);
    let base = pl.matmul_fused_with(active(), &pool, &x).unwrap();
    let view = pl.view();
    // starting from 0.5 reorders the sum vs (base + 0.5), so compare
    // with a tolerance here — but panel and GEMV must agree bitwise
    // with each other since they accumulate in the same order.
    let mut panel = vec![0.5f32; 2 * 48];
    fused_matmul(active(), &pool, &view, x.data(), 2, &mut panel);
    for (o, b) in panel.iter().zip(base.data()) {
        assert!((o - b - 0.5).abs() < 1e-4, "{o} vs {b} + 0.5");
    }
    let mut gemv = vec![0.5f32; 2 * 48];
    fused_gemv(active(), &pool, &view, x.data(), 2, &mut gemv);
    assert_eq!(panel, gemv, "prefilled panel and GEMV paths must agree bitwise");
}

#[test]
fn unpack_run_agrees_with_unpack_codes() {
    for bits in [2usize, 3, 4, 8] {
        let mask = (1u32 << bits) - 1;
        let n = 513;
        let mut rng = Rng::new(bits as u64 + 40);
        let codes: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & mask).collect();
        let packed = repro::quant::pack_codes(&codes, bits as u32);
        let reference = repro::quant::unpack_codes(&packed, bits as u32, n);
        for (start, len) in [(0usize, n), (1, 64), (7, 100), (63, 17), (500, 13)] {
            let mut got = vec![0u32; len];
            unpack_run(&packed, start * bits, bits, &mut got);
            assert_eq!(&got, &reference[start..start + len], "bits={bits} start={start}");
        }
    }
}

#[test]
fn dispatcher_reports_consistent_state() {
    // On an AVX2+FMA machine the dispatcher must not silently fall back
    // to scalar (the CI smoke job asserts the same through the CLI).
    if std::env::var("REPRO_KERNEL").is_err() && simd_supported() {
        assert_eq!(active(), Kernel::Avx2, "AVX2 CPU must dispatch the avx2 kernel");
    }
    if !simd_supported() {
        assert_eq!(active(), Kernel::Scalar);
    }
}
