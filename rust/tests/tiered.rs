//! Tiered-KV integration tests: spill -> restore byte identity across
//! every page layout, forced preempt-to-spill decode that stays bitwise
//! identical to a memory-only run at several page sizes, session
//! suspend/resume matching a never-suspended continuation token for
//! token, prefix-store hits across requests with zero re-prefill, and
//! injected `spill_io` faults contained to single sequences.

use std::collections::HashMap;
use std::sync::Arc;

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::model::{ParamStore, TINY};
use repro::obs::FaultPlan;
use repro::quant::QuantSpec;
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::{
    BlockPool, KvLayout, PagedKvCache, RequestStats, SchedConfig, Scheduler, SpillFile, TieredKv,
};
use repro::tensor::{IntTensor, Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(batch: usize, len: usize, seed: u64) -> IntTensor {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(batch, len).lm_batch(&corpus, &mut Rng::new(seed ^ 0x77)).tokens
}

fn req(key: u64, prompt: Vec<i32>, max_new: usize, session: Option<&str>) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: session.map(String::from),
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn gen_tokens(events: &[StepEvent], key: u64) -> Vec<i32> {
    events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Token { key: k, token, .. } if *k == key => Some(*token),
            _ => None,
        })
        .collect()
}

fn done_stats(events: &[StepEvent], key: u64) -> (FinishReason, RequestStats) {
    events
        .iter()
        .find_map(|e| match e {
            StepEvent::Done { key: k, finish, stats, .. } if *k == key => Some((*finish, *stats)),
            _ => None,
        })
        .unwrap_or_else(|| panic!("request {key} never finished"))
}

/// Terminal event per key: `Ok(finish)` for `Done`, `Err(code)` for
/// `Rejected`.  Panics on a key reaching two terminals.
fn terminals(events: &[StepEvent]) -> HashMap<u64, Result<FinishReason, &'static str>> {
    let mut out = HashMap::new();
    for e in events {
        let (k, t) = match e {
            StepEvent::Done { key, finish, .. } => (*key, Ok(*finish)),
            StepEvent::Rejected { key, code, .. } => (*key, Err(*code)),
            StepEvent::Token { .. } => continue,
        };
        assert!(out.insert(k, t).is_none(), "request {k} reached two terminal events");
    }
    out
}

fn tmp(name: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("repro-tiered-{}-{name}.bin", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Attach a fresh unbounded tier to `sched`, spilling to a temp file.
fn attach_tier(sched: &mut Scheduler<'_>, name: &str, prefix: bool) -> String {
    let path = tmp(name);
    let tier = TieredKv::new(&path, sched.pool(), 0, prefix).unwrap();
    sched.attach_tier(tier);
    path
}

// ---------------------------------------------------------------------------
// spill -> restore byte identity, all layouts
// ---------------------------------------------------------------------------

#[test]
fn spill_restore_is_byte_identical_for_f32_and_quant_layouts() {
    for (li, layout) in [
        KvLayout::F32,
        KvLayout::Quant { bits: 8, group: 8 },
        KvLayout::Quant { bits: 4, group: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        let (layers, d, bs) = (2usize, 8usize, 4usize);
        let mut pool = BlockPool::with_layout(layers, d, bs, 8, layout);
        let mut cache = PagedKvCache::new(&pool);
        cache.reserve(7, &mut pool).unwrap();
        for layer in 0..layers {
            let k: Vec<f32> =
                (0..7 * d).map(|i| (i as f32 * 0.9 + layer as f32).sin()).collect();
            let v: Vec<f32> =
                (0..7 * d).map(|i| (i as f32 * 0.4 - layer as f32).cos()).collect();
            cache.write_rows(&mut pool, layer, &k, &v).unwrap();
        }
        cache.advance(7);
        // 7 positions over 4-position pages: one sealed page (under the
        // quant layouts) + one staged partial tail.
        cache.seal_committed(&mut pool);

        let path = tmp(&format!("roundtrip-{li}"));
        let mut spill = SpillFile::create(&path, pool.max_export_bytes(), 0).unwrap();
        let before: Vec<Vec<u8>> =
            cache.table().iter().map(|&id| pool.export_block(id)).collect();
        let slots: Vec<u64> =
            before.iter().map(|rec| spill.write_slot(rec).unwrap()).collect();

        // Restore into a second cache whose pages are first overwritten
        // with garbage (released blocks keep stale bytes, which would
        // make a no-op import pass) — the re-export matching proves the
        // file round-trip is verbatim, staged or sealed, at any width.
        cache.release_all(&mut pool);
        let mut cache2 = PagedKvCache::new(&pool);
        cache2.reserve(7, &mut pool).unwrap();
        let junk = vec![1.25f32; 7 * d];
        for layer in 0..layers {
            cache2.write_rows(&mut pool, layer, &junk, &junk).unwrap();
        }
        cache2.advance(7);
        cache2.seal_committed(&mut pool);
        for (i, (&slot, &id)) in slots.iter().zip(cache2.table()).enumerate() {
            let rec = spill.read_slot(slot).unwrap();
            assert_eq!(rec, before[i], "layout {li}: file altered record {i}");
            pool.import_block(id, &rec).unwrap();
        }
        for (i, &id) in cache2.table().iter().enumerate() {
            assert_eq!(
                pool.export_block(id),
                before[i],
                "layout {li}: restored page {i} not byte-identical"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}

// ---------------------------------------------------------------------------
// forced spill decode == memory-only decode, bitwise, several page sizes
// ---------------------------------------------------------------------------

#[test]
fn forced_spill_decode_is_bitwise_identical_to_memory_only() {
    let model = packed_tiny(31);
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|i| tiny_prompt(1, 5 + i, 131 + i as u64).data().to_vec())
        .collect();
    let max_new = |i: usize| 8 + i;

    for bs in [1usize, 7, 64] {
        // A: memory-only oracle with an auto-sized (ample) budget.
        let ample = SchedConfig {
            max_batch: 3,
            max_new_cap: 32,
            max_prompt: 64,
            kv_block: bs,
            ..Default::default()
        };
        let mut plain = Scheduler::new(&model, ample);
        for (i, p) in prompts.iter().enumerate() {
            plain.submit(req(i as u64, p.clone(), max_new(i), None));
        }
        let ev_a = drain(&mut plain);

        // B: a budget one block past the longest single sequence — any
        // one request fits (so resume always can), but three running
        // concurrently MUST preempt-to-spill and resume from disk.  At
        // kv_block 64 this is 2 blocks for three 1-block sequences: the
        // third backs off at admission, then Hook-A-preempts an active
        // victim the next tick.
        let worst = 7 + max_new(2); // longest prompt + its new tokens
        let tight = SchedConfig {
            kv_blocks_total: worst.div_ceil(bs) + 1,
            ..ample
        };
        let mut tiered = Scheduler::new(&model, tight);
        attach_tier(&mut tiered, &format!("bitwise-{bs}"), false);
        for (i, p) in prompts.iter().enumerate() {
            tiered.submit(req(i as u64, p.clone(), max_new(i), None));
        }
        let ev_b = drain(&mut tiered);

        let stats = tiered.tier_stats().expect("tier attached");
        assert!(
            stats.preemptions > 0,
            "kv_block {bs}: budget never forced a spill — the scenario is vacuous"
        );
        assert_eq!(stats.resumes, stats.preemptions, "every spilled sequence resumed");
        assert_eq!(stats.restore_failures, 0);
        assert_eq!(stats.spilled_blocks, 0, "all slots freed after the run");

        for key in 0..3u64 {
            let (fa, _) = done_stats(&ev_a, key);
            let (fb, _) = done_stats(&ev_b, key);
            assert!(matches!(fa, FinishReason::Length));
            assert!(
                matches!(fb, FinishReason::Length),
                "kv_block {bs}: request {key} finished {fb:?} under the tier, not length"
            );
            let a = gen_tokens(&ev_a, key);
            let b = gen_tokens(&ev_b, key);
            assert!(!a.is_empty(), "request {key} produced no tokens");
            assert_eq!(
                a, b,
                "kv_block {bs}: spill/restore changed request {key}'s token stream"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// session suspend/resume == never-suspended continuation
// ---------------------------------------------------------------------------

#[test]
fn session_resume_continues_bitwise_with_zero_reprefill() {
    let model = packed_tiny(47);
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 32,
        max_prompt: 64,
        kv_block: 4,
        ..Default::default()
    };
    let prompt = tiny_prompt(1, 6, 211).data().to_vec();

    // Oracle: one request generating the full budget in one sitting.
    let mut plain = Scheduler::new(&model, cfg);
    plain.submit(req(0, prompt.clone(), 12, None));
    let gen_all = gen_tokens(&drain(&mut plain), 0);
    assert_eq!(gen_all.len(), 12);

    // Session: half the budget, park, then continue under the same id
    // with the prompt extended by everything generated so far.
    let mut sched = Scheduler::new(&model, cfg);
    attach_tier(&mut sched, "session", false);
    sched.submit(req(1, prompt.clone(), 6, Some("alice")));
    let ev1 = drain(&mut sched);
    let gen_a = gen_tokens(&ev1, 1);
    assert_eq!(gen_a.len(), 6);
    let stats = sched.tier_stats().unwrap();
    assert_eq!(stats.sessions_stored, 1, "finished session must park on the tier");
    assert!(stats.spilled_blocks > 0, "parked session holds spill slots");

    let mut prompt2 = prompt.clone();
    prompt2.extend(gen_a.iter().copied());
    sched.submit(req(2, prompt2.clone(), 6, Some("alice")));
    let ev2 = drain(&mut sched);
    let gen_b = gen_tokens(&ev2, 2);
    assert_eq!(gen_b.len(), 6);

    let (finish, rstats) = done_stats(&ev2, 2);
    assert!(matches!(finish, FinishReason::Length));
    assert_eq!(
        rstats.shared_prefix_tokens,
        prompt2.len() - 1,
        "resume must restore every reusable position (zero re-prefill)"
    );
    let stats = sched.tier_stats().unwrap();
    assert_eq!(stats.session_resumes, 1);
    assert_eq!(stats.restore_failures, 0);

    let mut joined = gen_a;
    joined.extend(gen_b);
    assert_eq!(joined, gen_all, "suspend/resume changed the token stream");
}

// ---------------------------------------------------------------------------
// prefix store: hit across requests, zero re-prefill of stored pages
// ---------------------------------------------------------------------------

#[test]
fn prefix_store_serves_whole_pages_across_requests() {
    let model = packed_tiny(53);
    // f32 layout: a promoted page is byte-identical to the donor's, so
    // the second stream must match the first bitwise.  (Quantized
    // layouts promote SEALED pages where a fresh prefill would stage
    // f32 rows — bit equality intentionally only holds at kv_bits 16;
    // see README "Tiered KV".)
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 32,
        max_prompt: 64,
        kv_block: 4,
        ..Default::default()
    };
    // 9 prompt positions over 4-position pages: two whole pages (8
    // positions) are publishable; the 9th always prefills fresh.
    let prompt = tiny_prompt(1, 9, 307).data().to_vec();

    let mut sched = Scheduler::new(&model, cfg);
    attach_tier(&mut sched, "prefix", true);
    sched.submit(req(0, prompt.clone(), 6, None));
    let ev1 = drain(&mut sched);
    let (_, s1) = done_stats(&ev1, 0);
    assert_eq!(s1.shared_prefix_tokens, 0, "first request has no donor");
    let stats = sched.tier_stats().unwrap();
    assert_eq!(stats.prefix_pages, 2, "two whole prompt pages published");

    // Second request, same prompt, after the first fully evicted — the
    // only donor is the persistent store.
    sched.submit(req(1, prompt.clone(), 6, None));
    let ev2 = drain(&mut sched);
    let (_, s2) = done_stats(&ev2, 1);
    assert_eq!(
        s2.shared_prefix_tokens, 8,
        "stored pages must map in place of re-prefilling"
    );
    assert_eq!(gen_tokens(&ev2, 1), gen_tokens(&ev1, 0), "promoted pages changed the stream");

    let stats = sched.tier_stats().unwrap();
    assert!(stats.prefix_hits >= 1, "store lookup must count a hit");
    assert!(stats.promotes >= 1, "promotion must be counted");
    assert_eq!(stats.restore_failures, 0);
    // Prefix records are read-shared forever: promotion leaves them live.
    assert_eq!(stats.prefix_pages, 2);
}

// ---------------------------------------------------------------------------
// injected spill_io fault: contained to the affected sequence
// ---------------------------------------------------------------------------

#[test]
fn spill_io_fault_fails_only_the_restored_sequence() {
    let model = packed_tiny(67);
    let bs = 4usize;
    let cfg = SchedConfig {
        max_batch: 3,
        max_new_cap: 32,
        max_prompt: 64,
        kv_block: bs,
        // Roughly one sequence's worth of pages — forces preemption.
        kv_blocks_total: (7 + 10).div_ceil(bs) + 2,
        ..Default::default()
    };
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|i| tiny_prompt(1, 5 + i, 401 + i as u64).data().to_vec())
        .collect();

    let mut sched = Scheduler::new(&model, cfg);
    attach_tier(&mut sched, "fault", false);
    // Every spill READ fails; writes are untouched, so sequences still
    // preempt to disk and then fail to come back.
    sched.set_fault(Arc::new(FaultPlan::parse("spill_io:1.0:7").unwrap()));
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(req(i as u64, p.clone(), 8 + i, None));
    }
    let events = drain(&mut sched);

    let stats = sched.tier_stats().unwrap();
    assert!(stats.preemptions > 0, "budget never forced a spill");
    assert!(stats.restore_failures > 0, "armed fault never fired on a restore");

    // Exactly one terminal event per request: restore victims answer an
    // `internal` error, everyone else completes normally.
    let term = terminals(&events);
    assert_eq!(term.len(), 3, "every request reaches a terminal event");
    let mut failed = 0;
    for (key, t) in &term {
        match t {
            Ok(FinishReason::Length) => {}
            Ok(f) => panic!("request {key} finished {f:?} — fault must not leak into survivors"),
            Err(code) => {
                assert_eq!(*code, "internal", "request {key}: wrong error taxonomy");
                failed += 1;
            }
        }
    }
    assert_eq!(
        failed as u64, stats.restore_failures,
        "each failed restore maps to exactly one internal finish"
    );
    assert!(failed < 3, "at least the never-preempted sequence survives");
}
