//! Fault-tolerance tests: overload control (bounded admission queue,
//! reject-then-retry), request deadlines (admission rejection + mid-decode
//! finish with page reclamation), panic isolation (injected tick panic →
//! quarantine of exactly one sequence, survivor streams bitwise
//! unchanged), graceful drain over the wire, and CRC32 rejection of
//! corrupted/truncated serving payloads.  Everything runs without
//! artifacts or PJRT; the fault-injection harness is deterministic, so
//! every assertion here is exact, not sampled.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::model::{checkpoint, ParamStore, TINY};
use repro::obs::{FaultPlan, SeqPanic};
use repro::quant::QuantSpec;
use repro::serve::json::Json;
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::{SchedConfig, Scheduler, ServeOptions};
use repro::tensor::{Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute
/// (mirrors tests/serve.rs).
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(len: usize, seed: u64) -> Vec<i32> {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(1, len)
        .lm_batch(&corpus, &mut Rng::new(seed ^ 0x77))
        .tokens
        .data()
        .to_vec()
}

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain_sched(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn done_of(events: &[StepEvent], key: u64) -> Option<(&Vec<i32>, usize, FinishReason)> {
    events.iter().find_map(|e| match e {
        StepEvent::Done { key: k, tokens, prompt_len, finish, .. } if *k == key => {
            Some((tokens, *prompt_len, *finish))
        }
        _ => None,
    })
}

// ---------------------------------------------------------------------------
// overload control
// ---------------------------------------------------------------------------

#[test]
fn overload_rejects_then_admits_after_drain() {
    let model = packed_tiny(101);
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 16,
        max_prompt: 16,
        max_pending: 2,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::new(&model, cfg);
    let p = tiny_prompt(4, 61);

    assert!(sched.try_submit(req(1, p.clone(), 4)).is_ok());
    assert!(sched.try_submit(req(2, p.clone(), 4)).is_ok());
    // The queue is at its bound: the request is handed back untouched so
    // the server can answer `overloaded` instead of queueing unboundedly.
    let bounced = sched
        .try_submit(req(3, p.clone(), 4))
        .expect_err("submission past --max-pending must bounce");
    assert_eq!(bounced.key, 3);
    assert_eq!(sched.n_pending(), 2, "a bounced request must not enter the queue");

    let events = drain_sched(&mut sched);
    assert!(done_of(&events, 1).is_some() && done_of(&events, 2).is_some());

    // The classic reject-then-retry cycle: resubmitting the same request
    // after the queue drained succeeds and completes normally.
    assert!(sched.try_submit(bounced).is_ok());
    let events = drain_sched(&mut sched);
    let (tokens, _, finish) = done_of(&events, 3).expect("retried request completes");
    assert_eq!(finish, FinishReason::Length);
    assert_eq!(tokens.len(), p.len() + 4);
    assert_eq!(sched.kv_stats().used_blocks, 0, "all pages reclaimed");
}

// ---------------------------------------------------------------------------
// deadlines
// ---------------------------------------------------------------------------

#[test]
fn deadline_rejects_pending_and_finishes_mid_decode() {
    let model = packed_tiny(103);
    let cfg = SchedConfig {
        max_batch: 2,
        max_new_cap: 512,
        max_prompt: 16,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::new(&model, cfg);
    let p = tiny_prompt(5, 63);

    // Already expired at submission: rejected by the admission sweep
    // with the `deadline` error code, never admitted.
    let mut r = req(1, p.clone(), 4);
    r.deadline = Some(Instant::now());
    sched.submit(r);
    let events = sched.step().unwrap();
    assert!(
        events.iter().any(|e| matches!(
            e,
            StepEvent::Rejected { key: 1, code, .. } if *code == "deadline"
        )),
        "expired pending request must be rejected with code=deadline"
    );
    assert!(!sched.has_work());

    // Mid-decode expiry: the budget covers the first steps, then runs
    // out long before max_new — the sequence finishes with `Deadline`,
    // keeps what it streamed, and releases every KV page.
    let mut r = req(2, p.clone(), 512);
    r.deadline = Some(Instant::now() + Duration::from_millis(150));
    sched.submit(r);
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        // Make wall-clock progress dominate token progress so the
        // deadline reliably fires before 512 tokens are emitted.
        std::thread::sleep(Duration::from_millis(20));
        guard += 1;
        assert!(guard < 600, "deadline never fired");
    }
    let (tokens, _, finish) = done_of(&events, 2).expect("deadline finish still reports done");
    assert_eq!(finish, FinishReason::Deadline);
    assert!(
        tokens.len() < p.len() + 512,
        "the stream must have been cut short by the deadline"
    );
    assert!(tokens.len() > p.len(), "some tokens streamed before expiry");
    assert_eq!(
        sched.kv_stats().used_blocks,
        0,
        "a deadline finish must release the sequence's KV pages"
    );
}

// ---------------------------------------------------------------------------
// panic isolation
// ---------------------------------------------------------------------------

#[test]
fn tick_panic_quarantines_one_sequence_streams_bitwise() {
    let model = packed_tiny(107);
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 64,
        max_prompt: 16,
        ..SchedConfig::default()
    };
    let pa = tiny_prompt(6, 71);
    let pb = tiny_prompt(6, 72);

    // Fault-free baseline streams for both requests.
    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, pa.clone(), 10));
    sched.submit(req(2, pb.clone(), 10));
    let base = drain_sched(&mut sched);
    let base1 = done_of(&base, 1).expect("baseline r1").0.clone();
    let base2 = done_of(&base, 2).expect("baseline r2").0.clone();

    // Same workload with the 3rd per-sequence tick checkpoint armed to
    // panic (one-shot).  Recovery mirrors the serve engine: catch the
    // unwind, attribute it via the SeqPanic payload, quarantine exactly
    // that sequence, keep stepping.
    let mut sched = Scheduler::new(&model, cfg);
    sched.set_fault(Arc::new(FaultPlan::parse("tick_panic:@3:1").unwrap()));
    sched.submit(req(1, pa, 10));
    sched.submit(req(2, pb, 10));
    let mut events = Vec::new();
    let mut panics = 0;
    let mut guard = 0;
    while sched.has_work() {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.step())) {
            Ok(step) => events.extend(step.expect("step itself must not error")),
            Err(payload) => {
                let sp = payload
                    .downcast_ref::<SeqPanic>()
                    .expect("tick_panic must carry a SeqPanic payload");
                panics += 1;
                events.extend(sched.quarantine(Some(sp.key)));
            }
        }
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge after quarantine");
    }
    assert_eq!(panics, 1, "a one-shot '@3' point fires exactly once");

    let quarantined: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Rejected { key, code, .. } if *code == "internal" => Some(*key),
            _ => None,
        })
        .collect();
    assert_eq!(quarantined.len(), 1, "exactly one sequence is quarantined");
    let victim = quarantined[0];
    let survivor = if victim == 1 { 2 } else { 1 };
    assert!(
        done_of(&events, victim).is_none(),
        "the quarantined sequence must not also report done"
    );

    let want = if survivor == 1 { &base1 } else { &base2 };
    let (tokens, _, finish) = done_of(&events, survivor).expect("survivor completes");
    assert_eq!(finish, FinishReason::Length);
    assert_eq!(
        &tokens[..],
        &want[..],
        "the surviving stream must be bitwise identical to the fault-free run"
    );
    assert_eq!(
        sched.kv_stats().used_blocks,
        0,
        "the quarantine rebuild must reclaim the victim's pages"
    );
}

// ---------------------------------------------------------------------------
// graceful drain over the wire
// ---------------------------------------------------------------------------

fn read_frame(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "connection closed mid-stream");
    Json::parse(line.trim()).unwrap()
}

#[test]
fn server_drain_completes_in_flight_and_refuses_new() {
    let model = Arc::new(packed_tiny(113));
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        sched: SchedConfig {
            max_batch: 2,
            max_new_cap: 64,
            max_prompt: 64,
            ..SchedConfig::default()
        },
        ..ServeOptions::default()
    };
    let server = repro::serve::server::spawn(model, opts).unwrap();
    let addr = server.addr.to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    writer
        .write_all(b"{\"id\":\"d1\",\"prompt\":[5,9,2,14],\"max_new\":12}\n")
        .unwrap();
    // Wait for the first token so the request is provably in flight
    // before the drain begins.
    let first = read_frame(&mut reader);
    assert_eq!(first.get("event").and_then(Json::as_str), Some("token"));

    writer.write_all(b"{\"cmd\":\"drain\"}\n").unwrap();
    // The in-flight stream must run to completion; the drain ack arrives
    // somewhere among the remaining token frames.
    let mut saw_drain = false;
    let mut done: Option<Json> = None;
    while !(saw_drain && done.is_some()) {
        let j = read_frame(&mut reader);
        match j.get("event").and_then(Json::as_str) {
            Some("drain") => {
                assert_eq!(j.get("status").and_then(Json::as_str), Some("draining"));
                saw_drain = true;
            }
            Some("done") => done = Some(j),
            Some("token") => {}
            other => panic!("unexpected frame during drain: {other:?}"),
        }
    }
    let done = done.unwrap();
    assert_eq!(done.get("id").and_then(Json::as_str), Some("d1"));
    assert_eq!(
        done.get("finish").and_then(Json::as_str),
        Some("length"),
        "draining must finish in-flight work normally, not cancel it"
    );

    // New work is refused once draining (or, if the engine already
    // exited, answered with the engine-stopped frame) — either way the
    // client sees the `unavailable` error code, never a hang.
    writer
        .write_all(b"{\"id\":\"d2\",\"prompt\":[1,2,3],\"max_new\":4}\n")
        .unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(j.get("event").and_then(Json::as_str), Some("error"));
    assert_eq!(j.get("code").and_then(Json::as_str), Some("unavailable"));

    // A completed drain stops the engine: wait() must return instead of
    // blocking forever.
    server.wait();
}

// ---------------------------------------------------------------------------
// checkpoint integrity (CRC32 trailers)
// ---------------------------------------------------------------------------

#[test]
fn corrupted_and_truncated_payloads_are_rejected() {
    let model = packed_tiny(109);
    let dir = std::env::temp_dir().join("apiq_robustness_test");
    std::fs::create_dir_all(&dir).unwrap();

    // Packed serving payload.
    let path = dir.join("packed_crc.apq");
    checkpoint::save_packed(&model, &path).unwrap();
    checkpoint::load_packed(&path).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // A single flipped bit deep in the tensor data must fail the load
    // (the CRC32 trailer catches silent corruption the record parser
    // would stream straight into the serving weights).
    let mut bad = clean.clone();
    let at = clean.len() * 3 / 4;
    bad[at] ^= 0x01;
    std::fs::write(&path, &bad).unwrap();
    assert!(
        checkpoint::load_packed(&path).is_err(),
        "bit-flipped packed payload must be rejected"
    );

    // Dropping the 4-byte trailer reads as truncation.
    std::fs::write(&path, &clean[..clean.len() - 4]).unwrap();
    let err = checkpoint::load_packed(&path).expect_err("truncated payload must be rejected");
    let msg = err.to_string();
    assert!(
        msg.contains("truncated") || msg.contains("CRC32"),
        "unexpected truncation error: {msg}"
    );
    std::fs::remove_file(&path).ok();

    // Adapter sidecar: same trailer, same rejection.
    let mut set = model.default_adapter.as_deref().expect("packed_tiny has adapters").clone();
    set.name = "crc".to_string();
    let apath = dir.join("adapter_crc.apq");
    checkpoint::save_adapter(&set, model.cfg.name, &apath).unwrap();
    checkpoint::load_adapter(&apath, &model.cfg).unwrap();
    let clean = std::fs::read(&apath).unwrap();
    std::fs::write(&apath, &clean[..clean.len() - 2]).unwrap();
    let err = checkpoint::load_adapter(&apath, &model.cfg)
        .expect_err("truncated adapter sidecar must be rejected");
    let msg = err.to_string();
    assert!(
        msg.contains("truncated") || msg.contains("CRC32"),
        "unexpected adapter truncation error: {msg}"
    );
    std::fs::remove_file(&apath).ok();
}
