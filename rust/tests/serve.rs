//! Serving subsystem tests: KV-cached decode vs full-prefix recompute
//! (bit-identical token streams), seeded sampling reproducibility, the
//! continuous-batching scheduler under scripted arrivals, packed
//! checkpoint roundtrips, and the TCP line-protocol server end to end.
//! Everything runs without artifacts or PJRT.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::{generate_greedy, PackedModel};
use repro::model::{checkpoint, ParamStore, TINY};
use repro::quant::QuantSpec;
use repro::serve::decode::{generate, generate_recompute};
use repro::serve::json::Json;
use repro::serve::loadgen::{run_load, LoadOptions};
use repro::serve::scheduler::{FinishReason, GenRequest, StepEvent};
use repro::serve::{KvCache, SamplingParams, SchedConfig, Scheduler, ServeOptions};
use repro::tensor::{IntTensor, Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn dense_tiny(seed: u64) -> PackedModel {
    let params = TINY.init_params(seed);
    PackedModel::build(TINY, &params, None, QuantSpec::new(16, 64), 1.0).unwrap()
}

fn tiny_prompt(batch: usize, len: usize, seed: u64) -> IntTensor {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(batch, len).lm_batch(&corpus, &mut Rng::new(seed ^ 0x77)).tokens
}

// ---------------------------------------------------------------------------
// cached decode == full recompute
// ---------------------------------------------------------------------------

#[test]
fn cached_greedy_matches_recompute_packed() {
    let model = packed_tiny(3);
    let prompt = tiny_prompt(3, 8, 15);
    let cached = generate(&model, &prompt, 12, None).unwrap();
    let full = generate_recompute(&model, &prompt, 12, None).unwrap();
    assert_eq!(
        cached.tokens, full.tokens,
        "KV-cached greedy decode must be bit-identical to full-prefix recompute"
    );
}

#[test]
fn cached_greedy_matches_recompute_dense() {
    let model = dense_tiny(9);
    let prompt = tiny_prompt(2, 6, 21);
    let cached = generate(&model, &prompt, 10, None).unwrap();
    let full = generate_recompute(&model, &prompt, 10, None).unwrap();
    assert_eq!(cached.tokens, full.tokens);
}

#[test]
fn cached_logits_match_full_forward_bitwise() {
    // Stronger than token equality: prefill logits + stepwise logits must
    // equal the full-forward logits at the matching positions.
    let model = packed_tiny(5);
    let prompt = tiny_prompt(1, 10, 31);
    let toks = prompt.data().to_vec();
    let full = model.logits(&prompt).unwrap(); // (1, 10, vocab)
    let vocab = model.cfg.vocab;

    let mut cache = KvCache::new(model.cfg.n_layers, model.cfg.d_model, 16);
    let chunk = model.forward_chunk(&toks, &mut cache).unwrap(); // (10, vocab)
    assert_eq!(chunk.data(), &full.data()[..10 * vocab], "prefill logits differ");

    // feeding the next token through forward_step must match a fresh
    // full forward over the extended sequence's last position
    let next = [toks[3]];
    let mut refs: Vec<&mut KvCache> = vec![&mut cache];
    let step = model.forward_step(&next, &mut refs).unwrap(); // (1, vocab)
    let mut ext = toks.clone();
    ext.push(toks[3]);
    let full2 = model
        .logits(&IntTensor::new(vec![1, 11], ext).unwrap())
        .unwrap();
    assert_eq!(
        step.data(),
        &full2.data()[10 * vocab..11 * vocab],
        "incremental step logits differ from full recompute"
    );
}

#[test]
fn generate_greedy_is_cached_and_deterministic() {
    // the public entry point now routes through the KV cache; behavior
    // must stay deterministic and in-vocab (PR 1's contract)
    let model = packed_tiny(13);
    let prompt = tiny_prompt(3, 8, 16);
    let a = generate_greedy(&model, &prompt, 6).unwrap();
    let b = generate_greedy(&model, &prompt, 6).unwrap();
    assert_eq!(a.tokens, b.tokens);
    for row in &a.tokens {
        assert_eq!(row.len(), 8 + 6);
        assert!(row.iter().all(|&t| (0..TINY.vocab as i32).contains(&t)));
    }
}

// ---------------------------------------------------------------------------
// seeded sampling
// ---------------------------------------------------------------------------

#[test]
fn seeded_sampling_reproducible_and_matches_recompute() {
    let model = packed_tiny(7);
    let prompt = tiny_prompt(2, 6, 19);
    let p = SamplingParams { temperature: 0.9, top_k: 50, top_p: 0.95, seed: 123 };
    let a = generate(&model, &prompt, 10, Some(&p)).unwrap();
    let b = generate(&model, &prompt, 10, Some(&p)).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must replay the same stream");

    let full = generate_recompute(&model, &prompt, 10, Some(&p)).unwrap();
    assert_eq!(
        a.tokens, full.tokens,
        "cached and recompute sampling share rng streams and logits"
    );

    let p2 = SamplingParams { seed: 124, ..p };
    let c = generate(&model, &prompt, 10, Some(&p2)).unwrap();
    assert_ne!(a.tokens, c.tokens, "a different seed should diverge");
}

#[test]
fn zero_temperature_sampling_equals_greedy() {
    let model = packed_tiny(11);
    let prompt = tiny_prompt(2, 5, 23);
    let p = SamplingParams { temperature: 0.0, ..Default::default() };
    let sampled = generate(&model, &prompt, 8, Some(&p)).unwrap();
    let greedy = generate(&model, &prompt, 8, None).unwrap();
    assert_eq!(sampled.tokens, greedy.tokens);
}

// ---------------------------------------------------------------------------
// continuous-batching scheduler
// ---------------------------------------------------------------------------

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

/// Run the scheduler to completion, returning the flat event log.
fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn tokens_of(events: &[StepEvent], key: u64) -> Vec<i32> {
    events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Token { key: k, token, .. } if *k == key => Some(*token),
            _ => None,
        })
        .collect()
}

fn done_of(events: &[StepEvent], key: u64) -> Option<(&Vec<i32>, usize, FinishReason)> {
    events.iter().find_map(|e| match e {
        StepEvent::Done { key: k, tokens, prompt_len, finish, .. } if *k == key => {
            Some((tokens, *prompt_len, *finish))
        }
        _ => None,
    })
}

#[test]
fn scheduler_admits_mid_flight_and_matches_standalone() {
    let model = packed_tiny(17);
    let cfg =
        SchedConfig { max_batch: 2, max_new_cap: 64, max_prompt: 64, ..SchedConfig::default() };
    let pa = tiny_prompt(1, 6, 40).data().to_vec();
    let pb = tiny_prompt(1, 5, 41).data().to_vec();
    let pc = tiny_prompt(1, 4, 42).data().to_vec();

    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(1, pa.clone(), 4)); // finishes first
    sched.submit(req(2, pb.clone(), 12)); // still running when C arrives
    let mut events = sched.step().unwrap();
    assert_eq!(sched.n_active(), 2, "both requests admitted in step 1");

    // C arrives mid-flight; batch is full so it queues...
    sched.submit(req(3, pc.clone(), 3));
    events.extend(sched.step().unwrap());
    assert_eq!(sched.n_pending(), 1, "batch full: C waits");

    // ...and the rest of the run completes everything
    events.extend(drain(&mut sched));
    assert_eq!(sched.n_completed(), 3);

    // C started streaming before B finished (continuous batching)
    let c_first = events
        .iter()
        .position(|e| matches!(e, StepEvent::Token { key: 3, .. }))
        .expect("C streamed tokens");
    let b_done = events
        .iter()
        .position(|e| matches!(e, StepEvent::Done { key: 2, .. }))
        .expect("B finished");
    assert!(
        c_first < b_done,
        "request admitted mid-flight must start decoding before earlier requests finish"
    );

    // every request's stream matches a standalone cached generation,
    // regardless of batch composition over its lifetime
    for (key, prompt, max_new) in [(1u64, &pa, 4usize), (2, &pb, 12), (3, &pc, 3)] {
        let streamed = tokens_of(&events, key);
        assert_eq!(streamed.len(), max_new);
        let (tokens, prompt_len, finish) = done_of(&events, key).expect("done event");
        assert_eq!(prompt_len, prompt.len());
        assert_eq!(&tokens[..prompt_len], &prompt[..]);
        assert_eq!(&tokens[prompt_len..], &streamed[..], "done tokens == streamed tokens");
        assert_eq!(finish, FinishReason::Length);

        let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
        let want = generate(&model, &solo, max_new, None).unwrap();
        assert_eq!(
            &want.tokens[0][..],
            &tokens[..],
            "batch composition must not change request {key}'s stream"
        );
    }
}

#[test]
fn scheduler_rejects_and_cancels() {
    let model = packed_tiny(19);
    let cfg = SchedConfig { max_batch: 4, max_new_cap: 8, max_prompt: 6, ..SchedConfig::default() };
    let mut sched = Scheduler::new(&model, cfg);

    sched.submit(req(1, vec![], 4)); // empty prompt
    sched.submit(req(2, vec![1; 10], 4)); // too long
    sched.submit(req(3, tiny_prompt(1, 4, 50).data().to_vec(), 99)); // max_new over cap
    sched.submit(req(4, tiny_prompt(1, 4, 50).data().to_vec(), 8)); // exactly at cap
    let events = drain(&mut sched);

    assert!(events.iter().any(|e| matches!(e, StepEvent::Rejected { key: 1, .. })));
    assert!(events.iter().any(|e| matches!(e, StepEvent::Rejected { key: 2, .. })));
    // Over-cap max_new is an explicit rejection (documented contract),
    // not a silent clamp.
    assert!(events.iter().any(|e| matches!(e, StepEvent::Rejected { key: 3, .. })));
    let (_, _, finish) = done_of(&events, 4).expect("request 4 finishes");
    assert_eq!(finish, FinishReason::Length);
    assert_eq!(tokens_of(&events, 4).len(), 8, "max_new == cap is admitted");

    // cancellation mid-stream
    let mut sched = Scheduler::new(&model, cfg);
    sched.submit(req(7, tiny_prompt(1, 4, 51).data().to_vec(), 8));
    let mut events = sched.step().unwrap();
    assert_eq!(sched.n_active(), 1);
    sched.cancel(7);
    events.extend(drain(&mut sched));
    let (_, _, finish) = done_of(&events, 7).expect("cancelled request still reports done");
    assert_eq!(finish, FinishReason::Cancelled);
    assert!(tokens_of(&events, 7).len() < 8);
}

#[test]
fn scheduler_stop_token_ends_stream_early() {
    let model = packed_tiny(23);
    let prompt = tiny_prompt(1, 5, 52).data().to_vec();
    // learn what the model will emit first, then use it as the stop token
    let solo = IntTensor::new(vec![1, prompt.len()], prompt.clone()).unwrap();
    let first = generate(&model, &solo, 1, None).unwrap().tokens[0][prompt.len()];

    let cfg =
        SchedConfig { max_batch: 2, max_new_cap: 16, max_prompt: 16, ..SchedConfig::default() };
    let mut sched = Scheduler::new(&model, cfg);
    let mut r = req(1, prompt, 10);
    r.stop = Some(first);
    sched.submit(r);
    let events = drain(&mut sched);
    let (_, _, finish) = done_of(&events, 1).expect("done");
    assert_eq!(finish, FinishReason::Stop);
    assert_eq!(tokens_of(&events, 1), vec![first]);
}

// ---------------------------------------------------------------------------
// packed checkpoint roundtrip
// ---------------------------------------------------------------------------

#[test]
fn packed_checkpoint_roundtrips_bitwise() {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(29);
    // DoRA adapters exercise the col_scale record
    let qp = TINY.init_qparams(spec, 4, true, 30);
    let model = PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap();

    let dir = std::env::temp_dir().join("apiq_serve_test");
    let path = dir.join("tiny_packed.apq");
    checkpoint::save_packed(&model, &path).unwrap();
    let loaded = checkpoint::load_packed(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.cfg.name, "tiny");
    assert_eq!(loaded.spec, spec);
    assert_eq!(loaded.resident_bytes(), model.resident_bytes());
    assert!((loaded.effective_bits() - model.effective_bits()).abs() < 1e-12);
    assert!(loaded.has_adapters());

    let prompt = tiny_prompt(2, 7, 60);
    let l1 = model.logits(&prompt).unwrap();
    let l2 = loaded.logits(&prompt).unwrap();
    assert_eq!(l1, l2, "serving from the packed payload must be bit-identical");

    let g1 = generate(&model, &prompt, 5, None).unwrap();
    let g2 = generate(&loaded, &prompt, 5, None).unwrap();
    assert_eq!(g1.tokens, g2.tokens);
}

// ---------------------------------------------------------------------------
// TCP server end to end
// ---------------------------------------------------------------------------

#[test]
fn server_streams_concurrent_requests() {
    let model = Arc::new(packed_tiny(37));
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        sched: SchedConfig {
            max_batch: 4,
            max_new_cap: 64,
            max_prompt: 64,
            ..SchedConfig::default()
        },
        allow_remote_shutdown: true,
        adapters: Vec::new(),
        ..ServeOptions::default()
    };
    let server = repro::serve::server::spawn(model, opts).unwrap();
    let addr = server.addr.to_string();

    let report = run_load(&LoadOptions {
        addr: addr.clone(),
        clients: 4,
        requests_per_client: 2,
        prompt_len: 6,
        max_new: 12,
        vocab: TINY.vocab,
        common_prefix: 0,
        temperature: 0.0,
        seed: 77,
        shutdown_after: false,
        transcript: None,
        adapter_mix: Vec::new(),
        churn_adapter: None,
        sample_ms: 2, // exercise the mid-run stats sampler
        deadline_ms: 0,
        request_timeout_ms: 0,
        max_retries: 0,
    })
    .unwrap();
    assert_eq!(report.completed, 8, "all streams must complete");
    assert_eq!(report.total_tokens, 8 * 12);
    // The sampler races a short run, so the series may be empty, but
    // whatever it caught must be internally consistent.
    for s in &report.samples {
        assert!(s.active <= 4, "sampled batch {} exceeds max_batch", s.active);
        assert!(s.kv_resident_blocks <= s.kv_blocks_total);
    }
    assert!(report.batch_peak() <= 4);
    assert!(report.ttft.max_s > 0.0 && report.total.p50_s > 0.0);
    assert!(
        report.peak_concurrent_streams >= 2,
        "continuous batching should interleave streams (peak {})",
        report.peak_concurrent_streams
    );

    // protocol-level determinism: the same greedy request twice returns
    // identical token streams
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut read_done_tokens = |id: &str| -> Vec<i64> {
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(line.trim()).unwrap();
            assert_eq!(j.get("id").and_then(Json::as_str), Some(id));
            if j.get("event").and_then(Json::as_str) == Some("done") {
                return j
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .map(|v| v.as_i64().unwrap())
                    .collect();
            }
        }
    };
    writer
        .write_all(b"{\"id\":\"x1\",\"prompt\":[5,9,2,14],\"max_new\":6}\n")
        .unwrap();
    let t1 = read_done_tokens("x1");
    writer
        .write_all(b"{\"id\":\"x2\",\"prompt\":[5,9,2,14],\"max_new\":6}\n")
        .unwrap();
    let t2 = read_done_tokens("x2");
    assert_eq!(t1, t2, "greedy serving must be deterministic");
    assert_eq!(t1.len(), 6);

    // malformed input gets an error frame, connection stays usable
    writer.write_all(b"this is not json\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("event").and_then(Json::as_str), Some("error"));

    // the stats command returns a KV memory frame on the same connection
    writer.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("event").and_then(Json::as_str), Some("stats"));
    let kv = j.get("kv").expect("stats frame has kv accounting");
    assert!(kv.get("block_size").and_then(Json::as_i64).unwrap() >= 1);
    assert!(
        kv.get("peak_resident_blocks").and_then(Json::as_i64).unwrap() > 0,
        "the load above must have touched KV pages"
    );
    assert_eq!(
        kv.get("used_blocks").and_then(Json::as_i64),
        Some(0),
        "all pages reclaimed after the load drained"
    );

    drop(writer);
    drop(reader);
    server.shutdown();
}

#[test]
fn server_shares_identical_prompt_prefixes() {
    // A tiny 4-position page forces multi-block tables; identical
    // prompts across concurrent clients must map shared pages, visible
    // in the stats frame's peak_shared_blocks.
    let model = Arc::new(packed_tiny(41));
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        sched: SchedConfig {
            max_batch: 4,
            max_new_cap: 64,
            max_prompt: 64,
            kv_block: 4,
            kv_blocks_total: 0,
            ..SchedConfig::default()
        },
        allow_remote_shutdown: true,
        adapters: Vec::new(),
        ..ServeOptions::default()
    };
    let server = repro::serve::server::spawn(model, opts).unwrap();
    let addr = server.addr.to_string();

    // 32 generated tokens keep every request alive well past the
    // client connect/submit skew, so admissions reliably overlap live
    // donors (same overlap margin the peak_concurrent_streams >= 2
    // assertion above relies on).
    let report = run_load(&LoadOptions {
        addr: addr.clone(),
        clients: 3,
        requests_per_client: 2,
        prompt_len: 10,
        max_new: 32,
        vocab: TINY.vocab,
        common_prefix: 10, // every prompt identical
        temperature: 0.0,
        seed: 99,
        shutdown_after: false,
        transcript: None,
        adapter_mix: Vec::new(),
        churn_adapter: None,
        sample_ms: 0,
        deadline_ms: 0,
        request_timeout_ms: 0,
        max_retries: 0,
    })
    .unwrap();
    assert_eq!(report.completed, 6);
    let kv = report.kv.expect("server speaks the stats command");
    assert_eq!(kv.block_size, 4);
    assert!(
        kv.peak_shared_blocks > 0,
        "identical prompts must share prompt-prefix pages (peak_shared {})",
        kv.peak_shared_blocks
    );
    assert_eq!(kv.shared_blocks, 0, "sharing ends once requests drain");
    server.shutdown();
}
