//! Multi-adapter serving tests: several LoRA/DoRA adapter sets batched
//! over ONE shared 2-bit base.  Pins the refactor's core contracts:
//! every sequence in a mixed-adapter batch is bitwise identical to a
//! solo run of the same request, the registry's load -> route -> unload
//! lifecycle defers unloads while sequences are in flight, DoRA and
//! plain LoRA mix in one decode tick, adapter-routed requests fall back
//! to plain decode under a speculating scheduler, and the server routes
//! `"adapter"` requests end to end with per-adapter stats.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::{
    Adapter, AdapterSet, PackedModel, ADAPTER_SLOTS, SLOT_WDOWN, SLOT_WO, SLOT_WQ,
};
use repro::model::{checkpoint, ModelConfig, ParamStore, TINY};
use repro::quant::QuantSpec;
use repro::serve::json::Json;
use repro::serve::scheduler::{GenRequest, StepEvent};
use repro::serve::{KvCache, SamplingParams, SchedConfig, Scheduler, ServeOptions};
use repro::tensor::{Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so the BAKED-IN adapters
/// contribute — the baseline route then exercises the default set while
/// explicit routes override it.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

/// A registry adapter set built directly in serving form: LoRA on wq and
/// wo of every block; with `dora`, a DoRA adapter (non-trivial
/// `col_scale`) on wdown of every other block.
fn test_set(name: &str, cfg: &ModelConfig, seed: u64, dora: bool) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let r = 4;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let mut arr: [Option<Adapter>; ADAPTER_SLOTS] = Default::default();
        for slot in [SLOT_WQ, SLOT_WO] {
            arr[slot] = Some(Adapter {
                a: Tensor::randn(&[cfg.d_model, r], 0.05, &mut rng),
                b_t: Tensor::randn(&[r, cfg.d_model], 0.05, &mut rng),
                scale: 2.0 / r as f32,
                col_scale: None,
            });
        }
        if dora && li % 2 == 0 {
            arr[SLOT_WDOWN] = Some(Adapter {
                a: Tensor::randn(&[cfg.d_ffn, r], 0.05, &mut rng),
                b_t: Tensor::randn(&[r, cfg.d_model], 0.05, &mut rng),
                scale: 2.0 / r as f32,
                col_scale: Some((0..cfg.d_model).map(|i| 1.0 + i as f32 * 1e-3).collect()),
            });
        }
        layers.push(arr);
    }
    AdapterSet { name: name.to_string(), layers }
}

fn tiny_prompt(len: usize, seed: u64) -> Vec<i32> {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(1, len)
        .lm_batch(&corpus, &mut Rng::new(seed ^ 0x77))
        .tokens
        .data()
        .to_vec()
}

fn req(key: u64, prompt: Vec<i32>, max_new: usize, adapter: Option<&str>) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: adapter.map(String::from),
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn tokens_of(events: &[StepEvent], key: u64) -> Vec<i32> {
    events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Token { key: k, token, .. } if *k == key => Some(*token),
            _ => None,
        })
        .collect()
}

/// Build a scheduler with the three named sets registered, run the given
/// requests to completion, and return the event log.
fn run_with_sets(
    model: &PackedModel,
    sets: &[AdapterSet],
    reqs: Vec<GenRequest>,
    kv_block: usize,
) -> Vec<StepEvent> {
    let cfg = SchedConfig {
        max_batch: 8,
        max_new_cap: 64,
        max_prompt: 64,
        kv_block,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::new(model, cfg);
    for s in sets {
        sched.adapters_mut().load(s.clone()).unwrap();
    }
    for r in reqs {
        sched.submit(r);
    }
    drain(&mut sched)
}

// ---------------------------------------------------------------------------
// mixed-adapter batch == solo runs, bitwise
// ---------------------------------------------------------------------------

#[test]
fn mixed_adapter_batch_matches_solo_runs_bitwise() {
    let model = packed_tiny(71);
    let sets = vec![
        test_set("task_a", &TINY, 101, false),
        test_set("task_b", &TINY, 102, true), // DoRA in the same batch
        test_set("task_c", &TINY, 103, false),
    ];
    // route -> (key, adapter): three adapters plus the baseline (model
    // default) path, all admitted in ONE tick.
    let routes: [(u64, Option<&str>); 4] =
        [(1, Some("task_a")), (2, Some("task_b")), (3, Some("task_c")), (4, None)];

    for kv_block in [1usize, 7, 64] {
        for seeded in [false, true] {
            let sampling = |key: u64| {
                seeded.then_some(SamplingParams {
                    temperature: 0.9,
                    top_k: 40,
                    top_p: 0.95,
                    seed: 1000 + key,
                })
            };
            let mixed: Vec<GenRequest> = routes
                .iter()
                .map(|&(key, ad)| {
                    let mut r = req(key, tiny_prompt(6, 200 + key), 10, ad);
                    r.sampling = sampling(key);
                    r
                })
                .collect();
            let mixed_events = run_with_sets(&model, &sets, mixed, kv_block);

            for &(key, ad) in &routes {
                let mut solo = req(key, tiny_prompt(6, 200 + key), 10, ad);
                solo.sampling = sampling(key);
                let solo_events = run_with_sets(&model, &sets, vec![solo], kv_block);
                let got = tokens_of(&mixed_events, key);
                let want = tokens_of(&solo_events, key);
                assert_eq!(got.len(), 10, "request {key} must stream to completion");
                assert_eq!(
                    got, want,
                    "kv_block {kv_block}, seeded {seeded}: request {key} (adapter {ad:?}) \
                     must be bitwise identical between the mixed batch and a solo run"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// DoRA + plain LoRA in one decode tick (decode-layer, logits-level)
// ---------------------------------------------------------------------------

#[test]
fn dora_and_lora_mix_in_one_decode_tick() {
    let model = packed_tiny(73);
    let lora = test_set("lora", &TINY, 111, false);
    let dora = test_set("dora", &TINY, 112, true);
    let sets: [Option<&AdapterSet>; 3] = [Some(&lora), Some(&dora), None];
    let prompts: Vec<Vec<i32>> = (0..3).map(|i| tiny_prompt(5, 300 + i)).collect();

    // Prefill each sequence solo (chunk prefill takes one sequence), then
    // step the three sequences TOGETHER with per-sequence adapters.
    let mut caches: Vec<KvCache> =
        (0..3).map(|_| KvCache::new(TINY.n_layers, TINY.d_model, 16)).collect();
    let mut last: Vec<i32> = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let logits = model.forward_chunk_with(p, &mut caches[i], sets[i]).unwrap();
        let row = &logits.data()[(p.len() - 1) * TINY.vocab..p.len() * TINY.vocab];
        last.push(argmax_i32(row));
    }
    let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
    let mixed = model.forward_step_with(&last, &mut refs, &sets).unwrap();

    // Reference: the same steps, one sequence at a time.
    for i in 0..3 {
        let mut cache = KvCache::new(TINY.n_layers, TINY.d_model, 16);
        model.forward_chunk_with(&prompts[i], &mut cache, sets[i]).unwrap();
        let mut refs: Vec<&mut KvCache> = vec![&mut cache];
        let solo = model.forward_step_with(&last[i..=i], &mut refs, &sets[i..=i]).unwrap();
        assert_eq!(
            &mixed.data()[i * TINY.vocab..(i + 1) * TINY.vocab],
            solo.data(),
            "sequence {i}: one mixed DoRA/LoRA/baseline tick must match the solo step bitwise"
        );
    }
}

fn argmax_i32(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as i32
}

// ---------------------------------------------------------------------------
// registry lifecycle: load -> route -> deferred unload
// ---------------------------------------------------------------------------

#[test]
fn registry_defers_unload_until_in_flight_sequences_drain() {
    let model = packed_tiny(79);
    let cfg =
        SchedConfig { max_batch: 4, max_new_cap: 32, max_prompt: 32, ..SchedConfig::default() };
    let mut sched = Scheduler::new(&model, cfg);
    sched.adapters_mut().load(test_set("task", &TINY, 121, false)).unwrap();
    assert_eq!(sched.adapters().len(), 1);

    // route a request through the adapter and get it in flight
    sched.submit(req(1, tiny_prompt(5, 400), 8, Some("task")));
    let mut events = sched.step().unwrap();
    assert_eq!(sched.n_active(), 1);

    // unknown adapters are rejected at admission
    sched.submit(req(9, tiny_prompt(5, 401), 4, Some("nope")));
    events.extend(sched.step().unwrap());
    let rej = events
        .iter()
        .find_map(|e| match e {
            StepEvent::Rejected { key: 9, reason, .. } => Some(reason.clone()),
            _ => None,
        })
        .expect("unknown adapter must reject");
    assert!(rej.contains("unknown adapter"), "reason: {rej}");

    // unload with a sequence in flight -> deferred, entry drains
    assert!(!sched.adapters_mut().unload("task").unwrap(), "unload must defer");
    let stats = sched.adapters().stats();
    assert!(stats[0].draining && stats[0].refs == 1, "entry drains with 1 ref");

    // a draining adapter refuses new routes...
    sched.submit(req(2, tiny_prompt(5, 402), 4, Some("task")));
    events.extend(sched.step().unwrap());
    let rej = events
        .iter()
        .find_map(|e| match e {
            StepEvent::Rejected { key: 2, reason, .. } => Some(reason.clone()),
            _ => None,
        })
        .expect("draining adapter must reject new routes");
    assert!(rej.contains("draining"), "reason: {rej}");
    // ...and refuses a reload under the same name
    assert!(sched.adapters_mut().load(test_set("task", &TINY, 122, false)).is_err());

    // the in-flight sequence still streams to completion on the adapter
    events.extend(drain(&mut sched));
    assert_eq!(tokens_of(&events, 1).len(), 8);
    assert!(
        matches!(
            events.iter().find(|e| matches!(e, StepEvent::Done { key: 1, .. })),
            Some(StepEvent::Done { .. })
        ),
        "routed request must finish normally"
    );
    // last release completes the deferred unload
    assert_eq!(sched.adapters().len(), 0, "deferred unload completes at drain");
    // the name is free again
    sched.adapters_mut().load(test_set("task", &TINY, 123, false)).unwrap();
}

#[test]
fn registry_attributes_tokens_per_adapter() {
    let model = packed_tiny(83);
    let cfg =
        SchedConfig { max_batch: 4, max_new_cap: 32, max_prompt: 32, ..SchedConfig::default() };
    let mut sched = Scheduler::new(&model, cfg);
    sched.adapters_mut().load(test_set("a", &TINY, 131, false)).unwrap();
    sched.submit(req(1, tiny_prompt(5, 500), 6, Some("a")));
    sched.submit(req(2, tiny_prompt(5, 501), 4, None)); // baseline
    drain(&mut sched);
    let stats = sched.adapters().stats();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].tokens, 6, "adapter-routed tokens counted on the adapter");
    assert_eq!(stats[0].refs, 0, "refs released at completion");
    assert!(stats[0].delta_overhead > 0.0 && stats[0].delta_overhead < 0.5);
    assert_eq!(sched.adapters().baseline_tokens(), 4, "baseline tokens counted separately");
}

// ---------------------------------------------------------------------------
// speculative scheduler: adapter routes fall back to plain decode
// ---------------------------------------------------------------------------

#[test]
fn speculating_scheduler_plain_decodes_adapter_routes() {
    let model = packed_tiny(89);
    let set = test_set("task", &TINY, 141, false);

    // Reference: non-speculating scheduler, routed request solo.
    let plain = run_with_sets(
        &model,
        std::slice::from_ref(&set),
        vec![req(1, tiny_prompt(6, 600), 10, Some("task"))],
        32,
    );
    let want = tokens_of(&plain, 1);
    assert_eq!(want.len(), 10);

    // Speculating scheduler: routed + baseline requests in one batch.
    let draft = Arc::new(model.prefix_cut(2).unwrap());
    let cfg = SchedConfig {
        max_batch: 4,
        max_new_cap: 64,
        max_prompt: 64,
        speculate: 3,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::with_draft(&model, cfg, draft);
    sched.adapters_mut().load(set.clone()).unwrap();
    sched.submit(req(1, tiny_prompt(6, 600), 10, Some("task")));
    sched.submit(req(2, tiny_prompt(6, 601), 10, None));
    let events = drain(&mut sched);

    // The adapter route took the plain path (no draft state -> zero
    // proposals for it) and its stream is unchanged bit for bit.
    assert_eq!(tokens_of(&events, 1), want, "spec fallback must not change routed bits");
    assert_eq!(tokens_of(&events, 2).len(), 10);
    let routed_stats = events
        .iter()
        .find_map(|e| match e {
            StepEvent::Done { key: 1, stats, .. } => Some(*stats),
            _ => None,
        })
        .expect("routed request done");
    assert_eq!(
        routed_stats.spec_proposed, 0,
        "adapter-routed sequences must not enter the draft/verify cycle"
    );
}

// ---------------------------------------------------------------------------
// server end to end: boot preload, runtime load/unload, routing, stats
// ---------------------------------------------------------------------------

#[test]
fn server_routes_adapters_end_to_end() {
    let model = packed_tiny(97);
    let dir = std::env::temp_dir().join("apiq_adapters_test");
    std::fs::create_dir_all(&dir).unwrap();
    let boot_path = dir.join("boot.apq");
    let rt_path = dir.join("runtime.apq");
    checkpoint::save_adapter(&test_set("ignored", &TINY, 151, false), "tiny", &boot_path)
        .unwrap();
    checkpoint::save_adapter(&test_set("ignored", &TINY, 152, true), "tiny", &rt_path).unwrap();

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        sched: SchedConfig {
            max_batch: 4,
            max_new_cap: 64,
            max_prompt: 64,
            ..SchedConfig::default()
        },
        allow_remote_shutdown: true,
        // boot preload: the CLI's repeatable `--adapter NAME=PATH`
        adapters: vec![("boot".to_string(), boot_path.to_string_lossy().into_owned())],
        ..ServeOptions::default()
    };
    let server = repro::serve::server::spawn(Arc::new(model), opts).unwrap();
    let addr = server.addr.to_string();

    fn read_frame(reader: &mut BufReader<TcpStream>) -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    }
    fn read_done_tokens(reader: &mut BufReader<TcpStream>, id: &str) -> Vec<i64> {
        loop {
            let j = read_frame(reader);
            assert_eq!(j.get("id").and_then(Json::as_str), Some(id));
            if j.get("event").and_then(Json::as_str) == Some("done") {
                return j
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .filter_map(Json::as_i64)
                    .collect();
            }
        }
    }

    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // route through the boot-preloaded adapter
    writer
        .write_all(b"{\"id\":\"a1\",\"prompt\":[5,9,2,14],\"max_new\":6,\"adapter\":\"boot\"}\n")
        .unwrap();
    let routed = read_done_tokens(&mut reader, "a1");
    assert_eq!(routed.len(), 4 + 6);

    // the same prompt unrouted takes the baked-in default path — with
    // live adapters in the registry set, the two streams may differ, but
    // both must be deterministic
    writer
        .write_all(b"{\"id\":\"b1\",\"prompt\":[5,9,2,14],\"max_new\":6}\n")
        .unwrap();
    let base1 = read_done_tokens(&mut reader, "b1");
    writer
        .write_all(b"{\"id\":\"b2\",\"prompt\":[5,9,2,14],\"max_new\":6}\n")
        .unwrap();
    let base2 = read_done_tokens(&mut reader, "b2");
    assert_eq!(base1, base2, "baseline route must stay deterministic");

    // unknown adapter -> error frame, connection stays usable
    writer
        .write_all(b"{\"id\":\"u1\",\"prompt\":[1,2,3],\"max_new\":2,\"adapter\":\"nope\"}\n")
        .unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(j.get("event").and_then(Json::as_str), Some("error"));
    assert!(
        j.get("message").and_then(Json::as_str).unwrap().contains("unknown adapter"),
        "error frame must name the unknown adapter"
    );

    // runtime load (DoRA sidecar), route, then unload
    let load_cmd = format!(
        "{{\"cmd\":\"adapter\",\"op\":\"load\",\"name\":\"rt\",\"path\":{}}}\n",
        Json::from(rt_path.to_string_lossy().as_ref()).render()
    );
    writer.write_all(load_cmd.as_bytes()).unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(j.get("event").and_then(Json::as_str), Some("adapter"));
    assert_eq!(j.get("status").and_then(Json::as_str), Some("loaded"));

    writer
        .write_all(b"{\"id\":\"a2\",\"prompt\":[3,1,4],\"max_new\":5,\"adapter\":\"rt\"}\n")
        .unwrap();
    assert_eq!(read_done_tokens(&mut reader, "a2").len(), 3 + 5);

    writer
        .write_all(b"{\"cmd\":\"adapter\",\"op\":\"unload\",\"name\":\"rt\"}\n")
        .unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(j.get("event").and_then(Json::as_str), Some("adapter"));
    assert_eq!(
        j.get("status").and_then(Json::as_str),
        Some("unloaded"),
        "no in-flight refs: unload completes immediately"
    );

    // stats frame carries the registry + per-adapter token counts
    writer.write_all(b"{\"cmd\":\"stats\"}\n").unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(j.get("event").and_then(Json::as_str), Some("stats"));
    let adapters = j.get("adapters").and_then(Json::as_arr).expect("adapters array");
    assert_eq!(adapters.len(), 1, "only the boot adapter remains registered");
    let boot = &adapters[0];
    assert_eq!(boot.get("name").and_then(Json::as_str), Some("boot"));
    assert_eq!(boot.get("tokens").and_then(Json::as_i64), Some(6));
    assert!(boot.get("delta_overhead").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(
        j.get("baseline_tokens").and_then(Json::as_i64).unwrap() >= 12,
        "both baseline requests counted"
    );

    drop(writer);
    drop(reader);
    server.shutdown();
    std::fs::remove_file(&boot_path).ok();
    std::fs::remove_file(&rt_path).ok();
}
