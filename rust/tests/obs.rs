//! Observability tests: trace-ring overflow, the metrics registry under
//! concurrent increments from pool lanes, Prometheus exposition validity
//! (no duplicate families or series), trace-journal JSON roundtrips, and
//! the bitwise A/B invariant — token streams are identical with full
//! telemetry (tracing + kernel profiling) attached.

use std::collections::HashSet;
use std::sync::Arc;

use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::PackedModel;
use repro::kernels::pool::ThreadPool;
use repro::model::{ParamStore, TINY};
use repro::obs::{profile, prom, KernelTickDelta, Registry, Telemetry, TickRecord};
use repro::quant::QuantSpec;
use repro::serve::json::Json;
use repro::serve::scheduler::{GenRequest, StepEvent};
use repro::serve::{SchedConfig, Scheduler};
use repro::tensor::{IntTensor, Rng, Tensor};

/// Open-clip qparams with live (random) LoRA B so adapters contribute.
fn open_qparams_with_lora(spec: QuantSpec, rank: usize, seed: u64) -> ParamStore {
    let mut qp = TINY.init_qparams(spec, rank, false, seed);
    let mut rng = Rng::new(seed ^ 0x10FA);
    for key in qp.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qp.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        } else if key.ends_with(".lora_b") {
            let shape = qp.get(&key).unwrap().shape().to_vec();
            qp.insert(key, Tensor::randn(&shape, 0.05, &mut rng));
        }
    }
    qp
}

fn packed_tiny(seed: u64) -> PackedModel {
    let spec = QuantSpec::new(2, 64);
    let params = TINY.init_params(seed);
    let qp = open_qparams_with_lora(spec, 4, seed ^ 0xAD);
    PackedModel::build(TINY, &params, Some(&qp), spec, 1.0).unwrap()
}

fn tiny_prompt(batch: usize, len: usize, seed: u64) -> IntTensor {
    let corpus = ZipfMarkovCorpus::new(TINY.vocab, seed);
    Batcher::new(batch, len).lm_batch(&corpus, &mut Rng::new(seed ^ 0x77)).tokens
}

fn req(key: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
    GenRequest {
        key,
        id: format!("r{key}"),
        prompt,
        max_new,
        sampling: None,
        stop: None,
        adapter: None,
        queued_at: std::time::Instant::now(),
        deadline: None,
        session: None,
    }
}

fn drain(sched: &mut Scheduler<'_>) -> Vec<StepEvent> {
    let mut events = Vec::new();
    let mut guard = 0;
    while sched.has_work() {
        events.extend(sched.step().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to converge");
    }
    events
}

fn gen_tokens(events: &[StepEvent], key: u64) -> Vec<i32> {
    events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Token { key: k, token, .. } if *k == key => Some(*token),
            _ => None,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// trace ring
// ---------------------------------------------------------------------------

#[test]
fn trace_ring_overflow_keeps_newest_and_counts_total() {
    let tele = Telemetry::new(8);
    for i in 0..20usize {
        tele.record_tick(TickRecord { batch: i, ..TickRecord::default() });
    }
    let (total, ticks) = tele.last_ticks(100);
    assert_eq!(total, 20, "total keeps counting past capacity");
    assert_eq!(ticks.len(), 8, "ring holds only the newest `cap` records");
    assert_eq!(ticks.first().unwrap().seq, 12, "oldest surviving record");
    assert_eq!(ticks.last().unwrap().seq, 19);
    assert_eq!(ticks.last().unwrap().batch, 19, "payload rides with its seq");
    // a smaller window still comes back oldest-first
    let (_, tail) = tele.last_ticks(2);
    assert_eq!(tail.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![18, 19]);
    // records are stamped with monotone non-decreasing engine time
    for w in ticks.windows(2) {
        assert!(w[1].at_secs >= w[0].at_secs);
    }
}

// ---------------------------------------------------------------------------
// registry under concurrent increments
// ---------------------------------------------------------------------------

#[test]
fn registry_counts_survive_concurrent_pool_increments() {
    let reg = Registry::default();
    let c = reg.counter("test_ops_total", &[], "ops");
    let h = reg.histogram("test_op_seconds", &[], "latency", &[0.5]);
    let pool = ThreadPool::with_threads(4);
    pool.parallel_for(1000, &|i| {
        c.inc();
        h.observe(if i % 2 == 0 { 0.25 } else { 1.0 });
    });
    assert_eq!(c.get(), 1000, "no lost counter increments under the pool");
    assert_eq!(h.count(), 1000, "no lost histogram observations");
    // 500 * 0.25 + 500 * 1.0, recovered from the nano-unit accumulator
    assert!((h.sum() - 625.0).abs() < 1e-6, "sum drifted: {}", h.sum());
    // re-registering the same (name, labels) hands back the same handle
    let c2 = reg.counter("test_ops_total", &[], "ops");
    c2.inc();
    assert_eq!(c.get(), 1001);
}

// ---------------------------------------------------------------------------
// Prometheus exposition
// ---------------------------------------------------------------------------

#[test]
fn prometheus_render_is_valid_and_duplicate_free() {
    let tele = Telemetry::new(16);
    tele.metrics.ticks_total.inc();
    tele.metrics.tokens_emitted_total.add(7);
    tele.metrics.kv_blocks_resident.set(5);
    tele.metrics.tick_seconds.observe(0.002);
    for h in &tele.metrics.tick_phase_seconds {
        h.observe(1e-4);
    }
    let text = prom::render(&tele);
    for family in [
        "tick_phase_seconds",
        "kv_blocks_resident",
        "requests_finished_total",
        "spec_accepted_total",
        "kernel_time_seconds_total",
        "build_info",
    ] {
        assert!(text.contains(family), "missing family '{family}' in:\n{text}");
    }
    let mut meta = HashSet::new();
    let mut series = HashSet::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            // "# HELP name text" / "# TYPE name kind" — unique per (kw, name)
            let mut parts = rest.splitn(3, ' ');
            let kw = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            assert!(["HELP", "TYPE"].contains(&kw), "bad comment line: {line}");
            assert!(!name.is_empty(), "comment without a metric name: {line}");
            assert!(meta.insert((kw.to_string(), name.to_string())), "duplicate {kw} for {name}");
        } else {
            let (key, val) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample: {line}"));
            assert!(val.parse::<f64>().is_ok(), "non-numeric sample value: {line}");
            assert!(series.insert(key.to_string()), "duplicate series: {key}");
        }
    }
    assert!(meta.len() >= 10, "suspiciously few families: {}", meta.len());
}

// ---------------------------------------------------------------------------
// trace journal roundtrip
// ---------------------------------------------------------------------------

#[test]
fn tick_record_roundtrips_through_journal_json() {
    let rec = TickRecord {
        seq: 42,
        at_secs: 1.25, // exact in the journal's µs rounding
        phase_ns: [100, 2000, 0, 30_000, 400_000, 5_000_000, 60, 700],
        batch: 3,
        pending: 2,
        admitted: 1,
        finished: 1,
        tokens: 9,
        kv_resident: 17,
        kv_delta: -4,
        spec_proposed: 8,
        spec_accepted: 6,
        kernels: vec![
            KernelTickDelta { kind: "dense_gemm".into(), calls: 12, ns: 34_567, flops: 1 << 20 },
            KernelTickDelta { kind: "matvec_fused".into(), calls: 3, ns: 890, flops: 4096 },
        ],
    };
    let line = rec.to_json().render();
    let parsed = Json::parse(&line).expect("journal line is valid JSON");
    let back = TickRecord::from_json(&parsed).expect("journal line parses as a tick");
    assert_eq!(back, rec, "journal roundtrip must be lossless");

    // kernels key is omitted entirely when the tick recorded none
    let quiet = TickRecord { seq: 1, ..TickRecord::default() };
    let qline = quiet.to_json().render();
    assert!(!qline.contains("kernels"), "empty kernel delta must be omitted: {qline}");
    let qback = TickRecord::from_json(&Json::parse(&qline).unwrap()).unwrap();
    assert_eq!(qback, quiet);
}

// ---------------------------------------------------------------------------
// bitwise A/B: telemetry on vs off
// ---------------------------------------------------------------------------

#[test]
fn token_streams_bitwise_identical_with_telemetry_attached() {
    let model = packed_tiny(61);
    let cfg = SchedConfig { max_batch: 3, max_new_cap: 32, max_prompt: 32, ..Default::default() };
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|i| tiny_prompt(1, 5 + i, 91 + i as u64).data().to_vec())
        .collect();

    // A: default scheduler, nothing attached, profiling not forced on.
    let mut plain = Scheduler::new(&model, cfg);
    for (i, p) in prompts.iter().enumerate() {
        plain.submit(req(i as u64, p.clone(), 8 + i));
    }
    let ev_a = drain(&mut plain);

    // B: shared telemetry + kernel profiling enabled.
    profile::enable();
    let tele = Telemetry::new(64);
    let mut traced = Scheduler::new(&model, cfg);
    traced.attach_obs(Arc::clone(&tele));
    for (i, p) in prompts.iter().enumerate() {
        traced.submit(req(i as u64, p.clone(), 8 + i));
    }
    let ev_b = drain(&mut traced);

    let mut emitted = 0u64;
    for key in 0..3u64 {
        let a = gen_tokens(&ev_a, key);
        let b = gen_tokens(&ev_b, key);
        assert!(!a.is_empty(), "request {key} produced no tokens");
        assert_eq!(a, b, "telemetry changed the token stream for request {key}");
        emitted += b.len() as u64;
    }

    // and the telemetry actually observed the run
    let (total, ticks) = tele.last_ticks(64);
    assert!(total > 0, "no ticks recorded");
    assert_eq!(tele.metrics.ticks_total.get(), total, "counter and ring disagree");
    let tick_tokens: u64 = ticks.iter().map(|r| r.tokens as u64).sum();
    assert_eq!(tick_tokens, emitted, "per-tick token deltas must sum to the stream length");
    assert_eq!(tele.metrics.tokens_emitted_total.get(), emitted);
    assert_eq!(tele.metrics.requests_admitted_total.get(), 3);
    let finished: u64 = tele.metrics.requests_finished.iter().map(|(_, c)| c.get()).sum();
    assert_eq!(finished, 3, "every request must land in exactly one finish-reason counter");
    assert!(
        profile::snapshot().iter().any(|k| k.calls > 0),
        "profiling enabled but no kernel calls recorded"
    );
    assert!(ticks.iter().all(|r| r.batch <= 3), "batch never exceeds max_batch");
}
