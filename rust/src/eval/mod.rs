//! Evaluation: perplexity over the synthetic corpus and accuracy over the
//! downstream task suites, computed host-side from artifact logits.

pub mod ppl;
pub mod scoring;

pub use ppl::{nll_from_logits, paged_stream_nll, perplexity_paged, Evaluator, ModelMode};
pub use scoring::{accuracy_from_logits, mc_accuracy_from_logits};
