//! Perplexity evaluation (the Table 2 / Table 3 / Table 6 metric).
//!
//! The artifacts return raw logits (B, T, V); the shifted masked NLL is
//! computed here, matching `model.next_token_loss` exactly: the mask at
//! target position t weights the prediction of tokens[t] from t-1.

use crate::data::Batch;
use crate::error::Result;
use crate::infer::PackedModel;
use crate::model::{ModelConfig, ParamStore};
use crate::quant::QuantSpec;
use crate::runtime::{Bindings, Runtime};
use crate::serve::{BlockPool, KvLayout, KvStats, PagedKvCache};
use crate::tensor::Tensor;

/// Which model path evaluates the batch.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelMode {
    /// Full-precision artifact (`logits_fp_<size>`).
    Fp,
    /// Quantized + adapter artifact (`logits_q_<size>_r<r>_g<g>[_dora]`)
    /// with runtime bits/scale.
    Quant { rank: usize, group: usize, bits: f32, scale: f32, dora: bool },
    /// Native host engine, full precision — no artifacts required.
    NativeFp,
    /// Native host engine over packed weights + adapters — no artifacts
    /// required.  `bits > 8` (e.g. 16 for weight-override baselines)
    /// serves the stored weights densely.
    NativeQuant { bits: u32, group: usize, scale: f32 },
}

impl ModelMode {
    /// Artifact file stem for artifact-backed modes; native modes carry a
    /// descriptive placeholder (they never touch the artifacts directory).
    pub fn artifact_name(&self, size: &str) -> String {
        match self {
            ModelMode::Fp => format!("logits_fp_{size}"),
            ModelMode::Quant { rank, group, dora, .. } => {
                let suffix = if *dora { "_dora" } else { "" };
                format!("logits_q_{size}_r{rank}_g{group}{suffix}")
            }
            ModelMode::NativeFp => format!("native_fp_{size}"),
            ModelMode::NativeQuant { .. } => format!("native_q_{size}"),
        }
    }

    /// Does this mode run on the native host engine (artifact-free)?
    pub fn is_native(&self) -> bool {
        matches!(self, ModelMode::NativeFp | ModelMode::NativeQuant { .. })
    }
}

/// (sum_nll, sum_mask) for one batch of logits.
pub fn nll_from_logits(logits: &Tensor, batch: &Batch, vocab: usize) -> (f64, f64) {
    let dims = logits.shape();
    let (b, t) = (dims[0], dims[1]);
    debug_assert_eq!(dims[2], vocab);
    let toks = batch.tokens.data();
    let mask = batch.mask.data();
    let data = logits.data();
    let mut sum_nll = 0.0f64;
    let mut sum_m = 0.0f64;
    for bi in 0..b {
        for ti in 1..t {
            let m = mask[bi * t + ti] as f64;
            if m == 0.0 {
                continue;
            }
            // predicting tokens[bi, ti] from logits at position ti-1
            let row = &data[(bi * t + ti - 1) * vocab..(bi * t + ti) * vocab];
            let tgt = toks[bi * t + ti] as usize;
            // stable log-softmax
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let lse: f32 = row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
            sum_nll += m * (lse - row[tgt]) as f64;
            sum_m += m;
        }
    }
    (sum_nll, sum_m)
}

/// Drives logits artifacts over batches and aggregates metrics.
pub struct Evaluator<'r> {
    pub runtime: &'r Runtime,
    pub cfg: ModelConfig,
}

impl<'r> Evaluator<'r> {
    pub fn new(runtime: &'r Runtime, cfg: ModelConfig) -> Self {
        Evaluator { runtime, cfg }
    }

    /// Build the native host model for a native mode.  Packing is
    /// O(model size); callers looping over batches should build once and
    /// call `PackedModel::logits` directly (as `perplexity` does) rather
    /// than going through `Evaluator::logits` per batch.
    pub fn native_model(
        &self,
        mode: &ModelMode,
        params: &ParamStore,
        qparams: Option<&ParamStore>,
    ) -> Result<PackedModel> {
        match mode {
            ModelMode::NativeFp => {
                PackedModel::build(self.cfg, params, None, QuantSpec::new(16, 64), 1.0)
            }
            ModelMode::NativeQuant { bits, group, scale } => {
                let qp = qparams.ok_or_else(|| {
                    crate::error::Error::config(
                        "ModelMode::NativeQuant requires qparams (gamma/beta/lora); \
                         use ModelMode::NativeFp for the full-precision reference",
                    )
                })?;
                PackedModel::build(
                    self.cfg,
                    params,
                    Some(qp),
                    QuantSpec::new(*bits, *group),
                    *scale,
                )
            }
            _ => unreachable!("native_model called on an artifact mode"),
        }
    }

    /// Raw logits for one batch.
    pub fn logits(
        &self,
        mode: &ModelMode,
        params: &ParamStore,
        qparams: Option<&ParamStore>,
        batch: &Batch,
    ) -> Result<Tensor> {
        if mode.is_native() {
            return self.native_model(mode, params, qparams)?.logits(&batch.tokens);
        }
        let name = mode.artifact_name(self.cfg.name);
        let mut b = Bindings::new().group("params", params).int("tokens", &batch.tokens);
        if let ModelMode::Quant { bits, scale, .. } = mode {
            let qp = qparams.expect("quant mode needs qparams");
            b = b.group("qparams", qp).scalar("bits", *bits).scalar("scale", *scale);
        }
        let mut out = self.runtime.run(&name, &b)?;
        out.take("logits")
    }

    /// Perplexity over a set of batches: exp(total_nll / total_tokens).
    /// Native modes build the host model once and reuse it per batch.
    pub fn perplexity(
        &self,
        mode: &ModelMode,
        params: &ParamStore,
        qparams: Option<&ParamStore>,
        batches: &[Batch],
    ) -> Result<f64> {
        let native = if mode.is_native() {
            Some(self.native_model(mode, params, qparams)?)
        } else {
            None
        };
        let mut nll = 0.0f64;
        let mut cnt = 0.0f64;
        for batch in batches {
            let logits = match &native {
                Some(m) => m.logits(&batch.tokens)?,
                None => self.logits(mode, params, qparams, batch)?,
            };
            let (n, c) = nll_from_logits(&logits, batch, self.cfg.vocab);
            nll += n;
            cnt += c;
        }
        if cnt == 0.0 {
            return Ok(f64::NAN);
        }
        Ok((nll / cnt).exp())
    }
}

/// Teacher-forced NLL of one token stream through the PAGED decode path
/// under the pool's storage layout, chunk by chunk.  Fully-committed
/// pages are sealed at every chunk boundary — the scheduler's
/// end-of-tick policy — so under a quantized layout each chunk attends
/// over dequantized sealed history exactly like the server would.
/// Returns `(sum_nll, predictions)` so callers can aggregate
/// `exp(nll / n)` across streams.
pub fn paged_stream_nll(
    model: &PackedModel,
    tokens: &[i32],
    chunk: usize,
    pool: &mut BlockPool,
) -> Result<(f64, f64)> {
    let chunk = chunk.max(1);
    let vocab = model.cfg.vocab;
    let mut cache = PagedKvCache::new(pool);
    let mut nll = 0.0f64;
    let mut cnt = 0.0f64;
    let mut pos = 0usize;
    while pos < tokens.len() {
        let take = chunk.min(tokens.len() - pos);
        let logits = model.forward_chunk_paged(&tokens[pos..pos + take], &mut cache, pool)?;
        let data = logits.data();
        for i in 0..take {
            // logits row i sits at absolute position pos+i and predicts
            // the NEXT token; the final position has no target.
            let Some(&next) = tokens.get(pos + i + 1) else { break };
            let row = &data[i * vocab..(i + 1) * vocab];
            let tgt = (next.max(0) as usize).min(vocab - 1);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let lse: f32 = row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
            nll += (lse - row[tgt]) as f64;
            cnt += 1.0;
        }
        cache.seal_committed(pool);
        pos += take;
    }
    cache.release_all(pool);
    Ok((nll, cnt))
}

/// Perplexity across token streams via [`paged_stream_nll`] — the
/// `kv_quant` harness entry.  Builds one pool with `layout`, feeds every
/// stream through it (streams evaluated sequentially; the pool's peaks
/// accumulate), and returns `exp(mean nll)` plus the final [`KvStats`]
/// so callers can report peak resident KV bytes per layout.
pub fn perplexity_paged(
    model: &PackedModel,
    streams: &[Vec<i32>],
    chunk: usize,
    block_size: usize,
    blocks_total: usize,
    layout: KvLayout,
) -> Result<(f64, KvStats)> {
    let mut pool = BlockPool::with_layout(
        model.cfg.n_layers,
        model.cfg.d_model,
        block_size.max(1),
        blocks_total,
        layout,
    );
    let mut nll = 0.0f64;
    let mut cnt = 0.0f64;
    for toks in streams {
        let (n, c) = paged_stream_nll(model, toks, chunk, &mut pool)?;
        nll += n;
        cnt += c;
    }
    let stats = pool.stats();
    if cnt == 0.0 {
        return Ok((f64::NAN, stats));
    }
    Ok(((nll / cnt).exp(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Batcher, ZipfMarkovCorpus};
    use crate::tensor::{IntTensor, Rng};

    fn tiny_batch() -> Batch {
        let c = ZipfMarkovCorpus::new(64, 1); // vocab must exceed WORD0
        Batcher::new(2, 4).lm_batch(&c, &mut Rng::new(2))
    }

    #[test]
    fn uniform_logits_give_log_vocab() {
        let b = tiny_batch();
        let v = 64usize;
        let logits = Tensor::zeros(&[2, 4, v]);
        let (nll, cnt) = nll_from_logits(&logits, &b, v);
        assert!(cnt > 0.0);
        let mean = nll / cnt;
        assert!((mean - (v as f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn perfect_logits_give_zero_nll() {
        // one-hot logits with huge margin at the target
        let b = tiny_batch();
        let v = 64usize;
        let mut logits = Tensor::zeros(&[2, 4, v]);
        let toks = b.tokens.data().to_vec();
        for bi in 0..2 {
            for ti in 1..4 {
                let tgt = toks[bi * 4 + ti] as usize;
                let base = (bi * 4 + ti - 1) * v;
                logits.data_mut()[base + tgt] = 100.0;
            }
        }
        let (nll, cnt) = nll_from_logits(&logits, &b, v);
        assert!(nll / cnt < 1e-5);
    }

    #[test]
    fn mask_excludes_positions() {
        let v = 64usize;
        let toks = IntTensor::new(vec![1, 4], vec![1, 2, 3, 4]).unwrap();
        let mask_full = Tensor::new(vec![1, 4], vec![1.0; 4]).unwrap();
        let mask_half = Tensor::new(vec![1, 4], vec![0.0, 0.0, 1.0, 1.0]).unwrap();
        let logits = Tensor::zeros(&[1, 4, v]);
        let bf = Batch { tokens: toks.clone(), mask: mask_full, samples: vec![] };
        let bh = Batch { tokens: toks, mask: mask_half, samples: vec![] };
        let (_, c_full) = nll_from_logits(&logits, &bf, v);
        let (_, c_half) = nll_from_logits(&logits, &bh, v);
        assert_eq!(c_full, 3.0); // t=1..3
        assert_eq!(c_half, 2.0);
    }
}
