//! Task accuracy scoring from artifact logits.
//!
//! * Generative tasks (arithmetic, classification): exact match of the
//!   argmax prediction on every answer position (teacher-forced greedy
//!   decoding — the standard proxy when no sampling loop exists).
//! * Multiple choice: restrict the answer position's logits to the
//!   candidate tokens and take the argmax (the paper's commonsense
//!   suites are scored analogously by sequence likelihood).

use crate::data::Batch;
use crate::tensor::Tensor;

/// Exact-match accuracy on the answer span of each sample in a batch.
/// Returns (n_correct, n_samples).
pub fn accuracy_from_logits(logits: &Tensor, batch: &Batch, vocab: usize) -> (usize, usize) {
    let dims = logits.shape();
    let t = dims[1];
    debug_assert_eq!(dims[2], vocab);
    let data = logits.data();
    let mut correct = 0usize;
    let mut total = 0usize;
    for (bi, s) in batch.samples.iter().enumerate() {
        if s.answer_pos.is_empty() {
            continue;
        }
        total += 1;
        let mut ok = true;
        for (k, &pos) in s.answer_pos.iter().enumerate() {
            if pos == 0 || pos >= t {
                ok = false;
                break;
            }
            // prediction of tokens[pos] comes from logits at pos-1
            let row = &data[(bi * t + pos - 1) * vocab..(bi * t + pos) * vocab];
            let pred = argmax(row);
            if pred as i32 != s.answer[k] {
                ok = false;
                break;
            }
        }
        if ok {
            correct += 1;
        }
    }
    (correct, total)
}

/// Multiple-choice accuracy: answer position logits restricted to choices.
pub fn mc_accuracy_from_logits(logits: &Tensor, batch: &Batch, vocab: usize) -> (usize, usize) {
    let dims = logits.shape();
    let t = dims[1];
    let data = logits.data();
    let mut correct = 0usize;
    let mut total = 0usize;
    for (bi, s) in batch.samples.iter().enumerate() {
        if s.choices.is_empty() || s.answer_pos.is_empty() {
            continue;
        }
        total += 1;
        let pos = s.answer_pos[0];
        if pos == 0 || pos >= t {
            continue;
        }
        let row = &data[(bi * t + pos - 1) * vocab..(bi * t + pos) * vocab];
        let best = s
            .choices
            .iter()
            .max_by(|&&a, &&b| row[a as usize].partial_cmp(&row[b as usize]).unwrap())
            .copied()
            .unwrap();
        if best == s.answer[0] {
            correct += 1;
        }
    }
    (correct, total)
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskSample;
    use crate::tensor::IntTensor;

    fn sample_batch(vocab: usize) -> (Tensor, Batch) {
        // one sample, answer token 5 at position 2
        let tokens = IntTensor::new(vec![1, 4], vec![1, 3, 5, 2]).unwrap();
        let mask = Tensor::new(vec![1, 4], vec![0.0, 0.0, 1.0, 0.0]).unwrap();
        let s = TaskSample {
            tokens: vec![1, 3, 5, 2],
            mask: vec![0.0, 0.0, 1.0, 0.0],
            answer_pos: vec![2],
            answer: vec![5],
            choices: vec![5, 6, 7, 8],
        };
        let mut logits = Tensor::zeros(&[1, 4, vocab]);
        // position 1 predicts position 2: put mass on token 5
        logits.data_mut()[vocab + 5] = 10.0;
        (logits, Batch { tokens, mask, samples: vec![s] })
    }

    #[test]
    fn generative_correct() {
        let (logits, b) = sample_batch(16);
        assert_eq!(accuracy_from_logits(&logits, &b, 16), (1, 1));
    }

    #[test]
    fn generative_wrong_when_argmax_elsewhere() {
        let (mut logits, b) = sample_batch(16);
        logits.data_mut()[16 + 9] = 20.0; // stronger wrong token
        assert_eq!(accuracy_from_logits(&logits, &b, 16), (0, 1));
    }

    #[test]
    fn mc_restricts_to_choices() {
        let (mut logits, b) = sample_batch(16);
        // a non-choice token dominates, but among choices 5 still wins
        logits.data_mut()[16 + 2] = 50.0;
        assert_eq!(accuracy_from_logits(&logits, &b, 16), (0, 1));
        assert_eq!(mc_accuracy_from_logits(&logits, &b, 16), (1, 1));
    }
}
