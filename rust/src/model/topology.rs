//! Block topology: the seven linear layers of a Llama-style block and the
//! paper's calibration order (§4.1: "the optimization should start with
//! the key, query, and value projection layers, followed by the output
//! projection layer, then the gate and up projection layer, and finally
//! the down projection layer").

/// The linear layers of one transformer block, in forward order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    Wgate,
    Wup,
    Wdown,
}

impl LinearKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::Wgate => "wgate",
            LinearKind::Wup => "wup",
            LinearKind::Wdown => "wdown",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        LINEAR_NAMES.into_iter().find(|l| l.as_str() == s)
    }

    /// Is this an attention-side linear (for the Table 1 position split)?
    pub fn is_attention(&self) -> bool {
        matches!(
            self,
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv | LinearKind::Wo
        )
    }

    /// Which collected activation feeds this linear
    /// (key into the `block_inputs_*` artifact outputs).
    pub fn input_activation(&self) -> &'static str {
        match self {
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv => "attn_in",
            LinearKind::Wo => "o_in",
            LinearKind::Wgate | LinearKind::Wup => "ffn_in",
            LinearKind::Wdown => "down_in",
        }
    }
}

/// All linears in forward order.
pub const LINEAR_NAMES: [LinearKind; 7] = [
    LinearKind::Wq,
    LinearKind::Wk,
    LinearKind::Wv,
    LinearKind::Wo,
    LinearKind::Wgate,
    LinearKind::Wup,
    LinearKind::Wdown,
];

/// The paper's sequential calibration stages within a block.
pub const CALIB_STAGES: [&[LinearKind]; 4] = [
    &[LinearKind::Wq, LinearKind::Wk, LinearKind::Wv],
    &[LinearKind::Wo],
    &[LinearKind::Wgate, LinearKind::Wup],
    &[LinearKind::Wdown],
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_cover_all_linears_once() {
        let mut seen = Vec::new();
        for stage in CALIB_STAGES {
            for l in stage.iter() {
                assert!(!seen.contains(l));
                seen.push(*l);
            }
        }
        assert_eq!(seen.len(), LINEAR_NAMES.len());
    }

    #[test]
    fn names_roundtrip() {
        for l in LINEAR_NAMES {
            assert_eq!(LinearKind::from_str(l.as_str()), Some(l));
        }
        assert_eq!(LinearKind::from_str("nope"), None);
    }

    #[test]
    fn attention_split() {
        let attn: Vec<_> = LINEAR_NAMES.iter().filter(|l| l.is_attention()).collect();
        assert_eq!(attn.len(), 4);
    }
}
