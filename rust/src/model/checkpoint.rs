//! Versioned binary checkpoints for `ParamStore`s.
//!
//! Format (little-endian):
//!   magic  "APIQCKPT"  (8 bytes)
//!   version u32
//!   n_entries u32
//!   per entry:
//!     key_len u32, key bytes (utf-8)
//!     rank u32, dims u64 * rank
//!     f32 payload
//!
//! Simple, dependency-free, and byte-exact across runs — checkpoints are
//! part of the experiment pipeline (pretrain -> quantize -> finetune each
//! run as separate CLI invocations).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::model::ParamStore;
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"APIQCKPT";
const VERSION: u32 = 1;

/// Canonical path of a pretrained checkpoint — the single source of truth
/// for the naming scheme shared by `repro pretrain` (save), `Env::prepare`
/// (cache), and `repro generate` (load).
pub fn pretrained_path(size: &str, steps: usize, seed: u64) -> PathBuf {
    Path::new("checkpoints").join(format!("pretrained_{size}_{steps}_{seed}.ckpt"))
}

/// Write a store to `path` (creates parent dirs).
pub fn save(store: &ParamStore, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(store.len() as u32).to_le_bytes())?;
    for (k, t) in store.iter() {
        w.write_all(&(k.len() as u32).to_le_bytes())?;
        w.write_all(k.as_bytes())?;
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk write of the f32 payload
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
        };
        w.write_all(bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a store from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).map_err(|e| Error::io(format!("{}: {e}", path.display())))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::io(format!("{}: not an APIQ checkpoint", path.display())));
    }
    let ver = read_u32(&mut r)?;
    if ver != VERSION {
        return Err(Error::io(format!("unsupported checkpoint version {ver}")));
    }
    let n = read_u32(&mut r)? as usize;
    let mut store = ParamStore::new();
    for _ in 0..n {
        let klen = read_u32(&mut r)? as usize;
        let mut kbuf = vec![0u8; klen];
        r.read_exact(&mut kbuf)?;
        let key = String::from_utf8(kbuf)
            .map_err(|e| Error::io(format!("bad key utf8: {e}")))?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut db = [0u8; 8];
            r.read_exact(&mut db)?;
            shape.push(u64::from_le_bytes(db) as usize);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
        };
        r.read_exact(bytes)?;
        store.insert(key, Tensor::new(shape, data)?);
    }
    Ok(store)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut ps = ParamStore::new();
        ps.insert("a.b", Tensor::randn(&[3, 5], 1.0, &mut rng));
        ps.insert("scalarish", Tensor::scalar(7.5));
        ps.insert("vec", Tensor::from_vec(vec![1.0, 2.0, 3.0]));
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        let path = dir.join("test.ckpt");
        save(&ps, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.get("a.b").unwrap(), ps.get("a.b").unwrap());
        assert_eq!(back.get("scalarish").unwrap().item(), 7.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/definitely/not/here.ckpt").is_err());
    }
}
