//! Versioned binary checkpoints for `ParamStore`s and packed models.
//!
//! `ParamStore` format (little-endian):
//!   magic  "APIQCKPT"  (8 bytes)
//!   version u32
//!   n_entries u32
//!   per entry:
//!     key_len u32, key bytes (utf-8)
//!     rank u32, dims u64 * rank
//!     f32 payload
//!
//! `PackedModel` format ("APIQPACK", see [`save_packed`]) serializes the
//! *serving* form — sub-byte packed codes, u8 zero-points, f32 scales,
//! adapter tensors — so `repro serve` boots from the 2-bit payload
//! directly instead of re-quantizing an f32 checkpoint at startup.
//!
//! Simple, dependency-free, and byte-exact across runs — checkpoints are
//! part of the experiment pipeline (pretrain -> quantize -> finetune ->
//! pack-ckpt -> serve each run as separate CLI invocations).
//!
//! APIQPACK and APIQADPT (v2) carry an integrity trailer: a CRC32
//! (IEEE, std-only table implementation below) over every byte after the
//! 8-byte magic, appended as 4 LE bytes.  Loaders verify it after
//! parsing, so a flipped bit or a truncated copy fails with a clear
//! config error instead of booting the server on silently corrupt
//! weights.  The f32 ParamStore format ("APIQCKPT") is unchanged — it
//! feeds the training pipeline, not the serving boot path.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::infer::{
    Adapter, AdapterSet, LayerWeight, PackedBlock, PackedLayer, PackedModel, RopeCache,
    ADAPTER_SLOTS,
};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::{PackedLinear, QuantSpec};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"APIQCKPT";
const VERSION: u32 = 1;

const PACK_MAGIC: &[u8; 8] = b"APIQPACK";
/// v2 = v1 layout + CRC32 trailer.
const PACK_VERSION: u32 = 2;

const ADAPT_MAGIC: &[u8; 8] = b"APIQADPT";
/// v2 = v1 layout + CRC32 trailer.
const ADAPT_VERSION: u32 = 2;

/// Canonical path of a pretrained checkpoint — the single source of truth
/// for the naming scheme shared by `repro pretrain` (save), `Env::prepare`
/// (cache), and `repro generate` (load).
pub fn pretrained_path(size: &str, steps: usize, seed: u64) -> PathBuf {
    Path::new("checkpoints").join(format!("pretrained_{size}_{steps}_{seed}.ckpt"))
}

/// Write a store to `path` (creates parent dirs).
pub fn save(store: &ParamStore, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(store.len() as u32).to_le_bytes())?;
    for (k, t) in store.iter() {
        w.write_all(&(k.len() as u32).to_le_bytes())?;
        w.write_all(k.as_bytes())?;
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk write of the f32 payload
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
        };
        w.write_all(bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a store from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).map_err(|e| Error::io(format!("{}: {e}", path.display())))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::io(format!("{}: not an APIQ checkpoint", path.display())));
    }
    let ver = read_u32(&mut r)?;
    if ver != VERSION {
        return Err(Error::io(format!("unsupported checkpoint version {ver}")));
    }
    let n = read_u32(&mut r)? as usize;
    let mut store = ParamStore::new();
    for _ in 0..n {
        let klen = read_u32(&mut r)? as usize;
        let mut kbuf = vec![0u8; klen];
        r.read_exact(&mut kbuf)?;
        let key = String::from_utf8(kbuf)
            .map_err(|e| Error::io(format!("bad key utf8: {e}")))?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut db = [0u8; 8];
            r.read_exact(&mut db)?;
            shape.push(u64::from_le_bytes(db) as usize);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
        };
        r.read_exact(bytes)?;
        store.insert(key, Tensor::new(shape, data)?);
    }
    Ok(store)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320) — std-only
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// One-shot CRC32 over a byte slice (same table and init/finish as the
/// checkpoint trailers).  Shared with the KV spill file so both on-disk
/// formats agree on what "corrupt" means.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Incremental CRC32 state.
#[derive(Clone, Copy)]
struct Crc32(u32);

impl Crc32 {
    fn new() -> Self {
        Crc32(!0)
    }

    fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    fn finish(self) -> u32 {
        !self.0
    }
}

/// `Write` adapter that checksums everything written through it.  The
/// trailer itself is written to the inner writer by [`finish`], so it is
/// not part of the checksummed stream.
struct Crc32Writer<W: Write> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> Crc32Writer<W> {
    fn new(inner: W) -> Self {
        Crc32Writer { inner, crc: Crc32::new() }
    }

    /// Append the 4-byte LE CRC trailer and flush the inner writer.
    fn finish(mut self) -> Result<()> {
        let sum = self.crc.finish();
        self.inner.write_all(&sum.to_le_bytes())?;
        self.inner.flush()?;
        Ok(())
    }
}

impl<W: Write> Write for Crc32Writer<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// `Read` adapter that checksums everything read through it; call
/// [`verify_trailer`] after the payload to check the stored CRC.
struct Crc32Reader<R: Read> {
    inner: R,
    crc: Crc32,
}

impl<R: Read> Crc32Reader<R> {
    fn new(inner: R) -> Self {
        Crc32Reader { inner, crc: Crc32::new() }
    }

    /// Read the 4-byte trailer from the raw stream (not checksummed) and
    /// compare it against the running CRC of everything read so far.
    fn verify_trailer(mut self, what: &str) -> Result<()> {
        let want = self.crc.finish();
        let mut b = [0u8; 4];
        self.inner
            .read_exact(&mut b)
            .map_err(|_| Error::config(format!("{what}: truncated (missing CRC32 trailer)")))?;
        let got = u32::from_le_bytes(b);
        if got != want {
            return Err(Error::config(format!(
                "{what}: CRC32 mismatch (stored {got:#010x}, computed {want:#010x}) — \
                 file is corrupt or truncated"
            )));
        }
        Ok(())
    }
}

impl<R: Read> Read for Crc32Reader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Packed-model checkpoints ("APIQPACK"): the 2-bit serving payload
// ---------------------------------------------------------------------------

/// Canonical path of a packed serving checkpoint (`repro pack-ckpt` save,
/// `repro serve --packed` / `repro generate --packed` load).
pub fn packed_path(size: &str, method: &str, bits: u32, group: usize) -> PathBuf {
    Path::new("checkpoints").join(format!("packed_{size}_{method}_{bits}b_g{group}.apq"))
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u32v(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_bytes(w: &mut impl Write, bytes: &[u8]) -> Result<()> {
    write_u64(w, bytes.len() as u64)?;
    w.write_all(bytes)?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    write_u64(w, data.len() as u64)?;
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn write_tensor(w: &mut impl Write, t: &Tensor) -> Result<()> {
    write_u32v(w, t.rank() as u32)?;
    for &d in t.shape() {
        write_u64(w, d as u64)?;
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Upper bound on any single payload in a packed checkpoint; a corrupt
/// length field fails fast instead of attempting a giant allocation.
/// 2^28 f32 elements = 1 GB, ~60x the `base` config's largest tensor.
const PACK_MAX_ELEMS: u64 = 1 << 28;

fn read_len(r: &mut impl Read, what: &str) -> Result<usize> {
    let n = read_u64(r)?;
    if n > PACK_MAX_ELEMS {
        return Err(Error::io(format!("packed checkpoint: implausible {what} length {n}")));
    }
    Ok(n as usize)
}

fn read_bytes(r: &mut impl Read, what: &str) -> Result<Vec<u8>> {
    let n = read_len(r, what)?;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_f32s(r: &mut impl Read, what: &str) -> Result<Vec<f32>> {
    let n = read_len(r, what)?;
    let mut data = vec![0f32; n];
    let bytes: &mut [u8] =
        unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4) };
    r.read_exact(bytes)?;
    Ok(data)
}

fn read_tensor(r: &mut impl Read) -> Result<Tensor> {
    let rank = read_u32(r)? as usize;
    if rank > 8 {
        return Err(Error::io(format!("packed checkpoint: implausible tensor rank {rank}")));
    }
    let mut shape = Vec::with_capacity(rank);
    let mut count = 1u64;
    for _ in 0..rank {
        let d = read_u64(r)?;
        count = count.saturating_mul(d.max(1));
        shape.push(d as usize);
    }
    if count > PACK_MAX_ELEMS {
        return Err(Error::io("packed checkpoint: implausible tensor size".to_string()));
    }
    let n: usize = shape.iter().product();
    let mut data = vec![0f32; n];
    let bytes: &mut [u8] =
        unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4) };
    r.read_exact(bytes)?;
    Tensor::new(shape, data)
}

/// Adapter record: tag 0 = none, 1 = LoRA (a, b_t, scale), 2 = DoRA
/// (+ col_scale). Shared between the APIQPACK per-layer slot and the
/// APIQADPT sidecar so the two formats stay byte-compatible per record.
fn write_adapter_opt(w: &mut impl Write, adapter: Option<&Adapter>) -> Result<()> {
    match adapter {
        None => w.write_all(&[0u8])?,
        Some(ad) => {
            w.write_all(&[if ad.col_scale.is_some() { 2u8 } else { 1u8 }])?;
            write_tensor(w, &ad.a)?;
            write_tensor(w, &ad.b_t)?;
            w.write_all(&ad.scale.to_le_bytes())?;
            if let Some(cs) = &ad.col_scale {
                write_f32s(w, cs)?;
            }
        }
    }
    Ok(())
}

fn read_adapter_opt(r: &mut impl Read) -> Result<Option<Adapter>> {
    match read_u8(r)? {
        0 => Ok(None),
        tag @ (1 | 2) => {
            let a = read_tensor(r)?;
            let b_t = read_tensor(r)?;
            let scale = read_f32(r)?;
            let col_scale = if tag == 2 { Some(read_f32s(r, "col_scale")?) } else { None };
            Ok(Some(Adapter { a, b_t, scale, col_scale }))
        }
        tag => Err(Error::io(format!("checkpoint: unknown adapter tag {tag}"))),
    }
}

fn write_layer(w: &mut impl Write, layer: &PackedLayer, adapter: Option<&Adapter>) -> Result<()> {
    match &layer.weight {
        LayerWeight::Dense(t) => {
            w.write_all(&[0u8])?;
            write_tensor(w, t)?;
        }
        LayerWeight::Packed(pl) => {
            w.write_all(&[1u8])?;
            write_u64(w, pl.d_in as u64)?;
            write_u64(w, pl.d_out as u64)?;
            write_u32v(w, pl.spec.bits)?;
            write_u64(w, pl.spec.group as u64)?;
            write_bytes(w, &pl.packed)?;
            write_tensor(w, &pl.scales)?;
            write_bytes(w, &pl.zeros)?;
        }
    }
    write_adapter_opt(w, adapter)
}

fn read_layer(r: &mut impl Read) -> Result<(PackedLayer, Option<Adapter>)> {
    let weight = match read_u8(r)? {
        0 => LayerWeight::Dense(read_tensor(r)?),
        1 => {
            let d_in = read_len(r, "d_in")?;
            let d_out = read_len(r, "d_out")?;
            let bits = read_u32(r)?;
            let group = read_len(r, "group")?;
            let spec = QuantSpec::new(bits, group);
            let packed = read_bytes(r, "packed codes")?;
            let scales = read_tensor(r)?;
            let zeros = read_bytes(r, "zero-points")?;
            if !(1..=8).contains(&bits) || group == 0 || d_in % group != 0 {
                return Err(Error::io(format!(
                    "packed checkpoint: bad layer spec ({bits} bits, group {group}, d_in {d_in})"
                )));
            }
            let n_groups = d_in / group;
            let want_bytes = (d_in * d_out * bits as usize).div_ceil(8);
            if packed.len() != want_bytes
                || scales.shape() != [n_groups, d_out]
                || zeros.len() != n_groups * d_out
            {
                return Err(Error::io(
                    "packed checkpoint: layer payload shape mismatch".to_string(),
                ));
            }
            LayerWeight::Packed(PackedLinear { d_in, d_out, spec, packed, scales, zeros })
        }
        tag => return Err(Error::io(format!("packed checkpoint: unknown weight tag {tag}"))),
    };
    let adapter = read_adapter_opt(r)?;
    Ok((PackedLayer { weight }, adapter))
}

fn block_layers(blk: &PackedBlock) -> [&PackedLayer; 7] {
    [&blk.wq, &blk.wk, &blk.wv, &blk.wo, &blk.wgate, &blk.wup, &blk.wdown]
}

/// Serialize a [`PackedModel`] — the exact serving form, packed codes and
/// all — to `path` (creates parent dirs).
pub fn save_packed(model: &PackedModel, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(PACK_MAGIC)?;
    // Everything after the magic is checksummed; finish() appends the CRC.
    let mut w = Crc32Writer::new(w);
    write_u32v(&mut w, PACK_VERSION)?;
    write_bytes(&mut w, model.cfg.name.as_bytes())?;
    write_u32v(&mut w, model.spec.bits)?;
    write_u64(&mut w, model.spec.group as u64)?;
    write_tensor(&mut w, &model.embed)?;
    write_tensor(&mut w, &model.final_norm)?;
    write_tensor(&mut w, &model.lm_head)?;
    write_u32v(&mut w, model.blocks.len() as u32)?;
    let set = model.default_adapter.as_deref();
    for (b, blk) in model.blocks.iter().enumerate() {
        write_tensor(&mut w, &blk.attn_norm)?;
        write_tensor(&mut w, &blk.ffn_norm)?;
        // block_layers order (wq..wdown) matches the adapter SLOT_* order,
        // so slot index == position — the v1 byte layout is unchanged.
        for (slot, layer) in block_layers(blk).into_iter().enumerate() {
            write_layer(&mut w, layer, set.and_then(|s| s.get(b, slot)))?;
        }
    }
    w.finish()
}

/// Load a [`PackedModel`] saved by [`save_packed`]: `repro serve` boots
/// straight from the 2-bit payload, no f32 weights or re-quantization.
pub fn load_packed(path: impl AsRef<Path>) -> Result<PackedModel> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).map_err(|e| Error::io(format!("{}: {e}", path.display())))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != PACK_MAGIC {
        return Err(Error::io(format!("{}: not a packed-model checkpoint", path.display())));
    }
    let mut r = Crc32Reader::new(r);
    let ver = read_u32(&mut r)?;
    if ver != PACK_VERSION {
        return Err(Error::io(format!(
            "unsupported packed checkpoint version {ver} (v{PACK_VERSION} adds a CRC32 \
             trailer; re-run pack-ckpt)"
        )));
    }
    let name_bytes = read_bytes(&mut r, "config name")?;
    let name = String::from_utf8(name_bytes)
        .map_err(|e| Error::io(format!("bad config name utf8: {e}")))?;
    let cfg = ModelConfig::by_name(&name)?;
    let bits = read_u32(&mut r)?;
    let group = read_len(&mut r, "group")?;
    let spec = QuantSpec::new(bits, group);
    let embed = read_tensor(&mut r)?;
    let final_norm = read_tensor(&mut r)?;
    let lm_head = read_tensor(&mut r)?;
    let n_blocks = read_u32(&mut r)? as usize;
    if n_blocks != cfg.n_layers {
        return Err(Error::io(format!(
            "packed checkpoint: {n_blocks} blocks but config '{name}' has {}",
            cfg.n_layers
        )));
    }
    if embed.shape() != [cfg.vocab, cfg.d_model]
        || lm_head.shape() != [cfg.d_model, cfg.vocab]
        || final_norm.len() != cfg.d_model
    {
        return Err(Error::io(
            "packed checkpoint: embed/lm_head/final_norm shape mismatch".to_string(),
        ));
    }
    let mut blocks = Vec::with_capacity(n_blocks);
    let mut ad_layers: Vec<[Option<Adapter>; ADAPTER_SLOTS]> = Vec::with_capacity(n_blocks);
    let mut any_adapter = false;
    for b in 0..n_blocks {
        let attn_norm = read_tensor(&mut r)?;
        let ffn_norm = read_tensor(&mut r)?;
        if attn_norm.len() != cfg.d_model || ffn_norm.len() != cfg.d_model {
            return Err(Error::io(format!(
                "packed checkpoint: block {b} norm length != d_model {}",
                cfg.d_model
            )));
        }
        let (wq, aq) = read_layer(&mut r)?;
        let (wk, ak) = read_layer(&mut r)?;
        let (wv, av) = read_layer(&mut r)?;
        let (wo, ao) = read_layer(&mut r)?;
        let (wgate, agate) = read_layer(&mut r)?;
        let (wup, aup) = read_layer(&mut r)?;
        let (wdown, adown) = read_layer(&mut r)?;
        let block = PackedBlock { attn_norm, ffn_norm, wq, wk, wv, wo, wgate, wup, wdown };
        let adapters = [aq, ak, av, ao, agate, aup, adown];
        let slots = [
            (&block.wq, (cfg.d_model, cfg.d_model)),
            (&block.wk, (cfg.d_model, cfg.d_model)),
            (&block.wv, (cfg.d_model, cfg.d_model)),
            (&block.wo, (cfg.d_model, cfg.d_model)),
            (&block.wgate, (cfg.d_model, cfg.d_ffn)),
            (&block.wup, (cfg.d_model, cfg.d_ffn)),
            (&block.wdown, (cfg.d_ffn, cfg.d_model)),
        ];
        for ((lay, (want_in, want_out)), ad) in slots.into_iter().zip(adapters.iter()) {
            let ad = ad.as_ref();
            let (d_in, d_out) = match &lay.weight {
                LayerWeight::Packed(pl) => (pl.d_in, pl.d_out),
                LayerWeight::Dense(t) if t.rank() == 2 => (t.rows(), t.cols()),
                LayerWeight::Dense(_) => (0, 0),
            };
            if (d_in, d_out) != (want_in, want_out) {
                return Err(Error::io(format!(
                    "packed checkpoint: block {b} linear is {d_in}x{d_out}, \
                     config '{name}' wants {want_in}x{want_out}"
                )));
            }
            if let Some(ad) = ad {
                check_adapter_shape(ad, want_in, want_out)
                    .map_err(|_| Error::io(format!(
                        "packed checkpoint: block {b} adapter shape mismatch"
                    )))?;
            }
        }
        any_adapter = any_adapter || adapters.iter().any(|a| a.is_some());
        ad_layers.push(adapters);
        blocks.push(block);
    }
    r.verify_trailer("packed checkpoint")?;
    let default_adapter = if any_adapter {
        Some(Arc::new(AdapterSet { name: "builtin".to_string(), layers: ad_layers }))
    } else {
        None
    };
    Ok(PackedModel {
        cfg,
        spec,
        embed,
        final_norm,
        lm_head,
        blocks,
        default_adapter,
        rope: RopeCache::new(),
    })
}

fn check_adapter_shape(ad: &Adapter, want_in: usize, want_out: usize) -> Result<()> {
    let rank_ok = ad.a.rank() == 2
        && ad.b_t.rank() == 2
        && ad.a.rows() == want_in
        && ad.b_t.cols() == want_out
        && ad.a.cols() == ad.b_t.rows();
    let cs_ok = ad.col_scale.as_ref().map(|c| c.len() == want_out).unwrap_or(true);
    if !rank_ok || !cs_ok {
        return Err(Error::io("adapter shape mismatch".to_string()));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Adapter-only sidecars ("APIQADPT"): one AdapterSet over a shared base
// ---------------------------------------------------------------------------

/// Canonical path of an adapter sidecar produced by `repro pack-adapter`.
pub fn adapter_path(size: &str, method: &str, rank: usize, seed: u64) -> PathBuf {
    Path::new("checkpoints").join(format!("adapter_{size}_{method}_r{rank}_s{seed}.apq"))
}

/// Serialize an [`AdapterSet`] alone — no base weights — so N task adapters
/// can ship as small sidecars over one shared APIQPACK base. Layout:
/// magic "APIQADPT", version u32, base config name, set name, n_blocks u32,
/// then [`ADAPTER_SLOTS`] adapter records per block in wq..wdown slot order
/// (the same record encoding APIQPACK embeds per layer).
pub fn save_adapter(set: &AdapterSet, cfg_name: &str, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(ADAPT_MAGIC)?;
    // Everything after the magic is checksummed; finish() appends the CRC.
    let mut w = Crc32Writer::new(w);
    write_u32v(&mut w, ADAPT_VERSION)?;
    write_bytes(&mut w, cfg_name.as_bytes())?;
    write_bytes(&mut w, set.name.as_bytes())?;
    write_u32v(&mut w, set.layers.len() as u32)?;
    for block in &set.layers {
        for ad in block {
            write_adapter_opt(&mut w, ad.as_ref())?;
        }
    }
    w.finish()
}

/// Load an adapter sidecar saved by [`save_adapter`], validating every
/// record against `cfg` (config-name match, block count, per-linear shapes).
pub fn load_adapter(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<AdapterSet> {
    let path = path.as_ref();
    let mut r = BufReader::new(
        File::open(path).map_err(|e| Error::io(format!("{}: {e}", path.display())))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != ADAPT_MAGIC {
        return Err(Error::io(format!("{}: not an adapter sidecar", path.display())));
    }
    let mut r = Crc32Reader::new(r);
    let ver = read_u32(&mut r)?;
    if ver != ADAPT_VERSION {
        return Err(Error::io(format!(
            "unsupported adapter sidecar version {ver} (v{ADAPT_VERSION} adds a CRC32 \
             trailer; re-run pack-adapter)"
        )));
    }
    let base_bytes = read_bytes(&mut r, "config name")?;
    let base = String::from_utf8(base_bytes)
        .map_err(|e| Error::io(format!("bad config name utf8: {e}")))?;
    if base != cfg.name {
        return Err(Error::io(format!(
            "adapter sidecar targets config '{base}' but model is '{}'",
            cfg.name
        )));
    }
    let name_bytes = read_bytes(&mut r, "adapter name")?;
    let name = String::from_utf8(name_bytes)
        .map_err(|e| Error::io(format!("bad adapter name utf8: {e}")))?;
    let n_blocks = read_u32(&mut r)? as usize;
    if n_blocks != cfg.n_layers {
        return Err(Error::io(format!(
            "adapter sidecar: {n_blocks} blocks but config '{}' has {}",
            cfg.name, cfg.n_layers
        )));
    }
    let shapes: [(usize, usize); ADAPTER_SLOTS] = [
        (cfg.d_model, cfg.d_model),
        (cfg.d_model, cfg.d_model),
        (cfg.d_model, cfg.d_model),
        (cfg.d_model, cfg.d_model),
        (cfg.d_model, cfg.d_ffn),
        (cfg.d_model, cfg.d_ffn),
        (cfg.d_ffn, cfg.d_model),
    ];
    let mut layers = Vec::with_capacity(n_blocks);
    for b in 0..n_blocks {
        let mut block: [Option<Adapter>; ADAPTER_SLOTS] = Default::default();
        for (slot, rec) in block.iter_mut().enumerate() {
            let ad = read_adapter_opt(&mut r)?;
            if let Some(ad) = &ad {
                let (want_in, want_out) = shapes[slot];
                check_adapter_shape(ad, want_in, want_out).map_err(|_| {
                    Error::io(format!(
                        "adapter sidecar: block {b} slot {slot} shape mismatch \
                         (config '{}')",
                        cfg.name
                    ))
                })?;
            }
            *rec = ad;
        }
        layers.push(block);
    }
    r.verify_trailer("adapter sidecar")?;
    Ok(AdapterSet { name, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut ps = ParamStore::new();
        ps.insert("a.b", Tensor::randn(&[3, 5], 1.0, &mut rng));
        ps.insert("scalarish", Tensor::scalar(7.5));
        ps.insert("vec", Tensor::from_vec(vec![1.0, 2.0, 3.0]));
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        let path = dir.join("test.ckpt");
        save(&ps, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.get("a.b").unwrap(), ps.get("a.b").unwrap());
        assert_eq!(back.get("scalarish").unwrap().item(), 7.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/definitely/not/here.ckpt").is_err());
    }

    #[test]
    fn packed_loader_rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("not_packed.apq");
        // a valid ParamStore checkpoint is NOT a packed-model checkpoint
        let mut rng = Rng::new(2);
        let mut ps = ParamStore::new();
        ps.insert("x", Tensor::randn(&[2, 2], 1.0, &mut rng));
        save(&ps, &path).unwrap();
        assert!(load_packed(&path).is_err());
        std::fs::remove_file(&path).ok();
        assert!(load_packed("/definitely/not/here.apq").is_err());
    }

    #[test]
    fn packed_path_is_stable() {
        let p = packed_path("tiny", "rtn", 2, 64);
        assert_eq!(p, Path::new("checkpoints").join("packed_tiny_rtn_2b_g64.apq"));
    }

    #[test]
    fn adapter_path_is_stable() {
        let p = adapter_path("tiny", "qlora", 4, 9);
        assert_eq!(p, Path::new("checkpoints").join("adapter_tiny_qlora_r4_s9.apq"));
    }

    fn test_set(cfg: &ModelConfig, rng: &mut Rng) -> AdapterSet {
        let mut layers: Vec<[Option<Adapter>; ADAPTER_SLOTS]> = Vec::new();
        for b in 0..cfg.n_layers {
            let mut block: [Option<Adapter>; ADAPTER_SLOTS] = Default::default();
            // plain LoRA on wq every block, DoRA on wdown every other block —
            // exercises both record tags and both linear shapes
            block[0] = Some(Adapter {
                a: Tensor::randn(&[cfg.d_model, 4], 0.1, rng),
                b_t: Tensor::randn(&[4, cfg.d_model], 0.1, rng),
                scale: 0.5,
                col_scale: None,
            });
            if b % 2 == 0 {
                block[6] = Some(Adapter {
                    a: Tensor::randn(&[cfg.d_ffn, 4], 0.1, rng),
                    b_t: Tensor::randn(&[4, cfg.d_model], 0.1, rng),
                    scale: 1.25,
                    col_scale: Some((0..cfg.d_model).map(|i| 1.0 + i as f32 * 1e-3).collect()),
                });
            }
            layers.push(block);
        }
        AdapterSet { name: "taskA".to_string(), layers }
    }

    #[test]
    fn adapter_sidecar_roundtrips() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(7);
        let set = test_set(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        let path = dir.join("sidecar.apq");
        save_adapter(&set, cfg.name, &path).unwrap();
        let back = load_adapter(&path, &cfg).unwrap();
        assert_eq!(back.name, "taskA");
        assert_eq!(back.layers.len(), set.layers.len());
        for (bb, sb) in back.layers.iter().zip(set.layers.iter()) {
            for (ba, sa) in bb.iter().zip(sb.iter()) {
                match (ba, sa) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(x.a, y.a);
                        assert_eq!(x.b_t, y.b_t);
                        assert_eq!(x.scale, y.scale);
                        assert_eq!(x.col_scale, y.col_scale);
                    }
                    _ => panic!("slot presence mismatch"),
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn adapter_sidecar_rejects_mismatches() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(8);
        let set = test_set(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();

        // wrong base config name
        let path = dir.join("sidecar_wrong_base.apq");
        save_adapter(&set, "base", &path).unwrap();
        assert!(load_adapter(&path, &cfg).is_err());
        std::fs::remove_file(&path).ok();

        // wrong magic (a ParamStore checkpoint is not a sidecar)
        let path = dir.join("sidecar_wrong_magic.apq");
        let mut ps = ParamStore::new();
        ps.insert("x", Tensor::randn(&[2, 2], 1.0, &mut rng));
        save(&ps, &path).unwrap();
        assert!(load_adapter(&path, &cfg).is_err());
        std::fs::remove_file(&path).ok();

        // adapter shaped for tiny rejected against small
        let path = dir.join("sidecar_wrong_shape.apq");
        let small = ModelConfig::by_name("small").unwrap();
        save_adapter(&set, small.name, &path).unwrap();
        assert!(load_adapter(&path, &small).is_err());
        std::fs::remove_file(&path).ok();

        assert!(load_adapter("/definitely/not/here.apq", &cfg).is_err());
    }

    #[test]
    fn crc32_known_answer() {
        // IEEE CRC32 check value: crc32("123456789") = 0xCBF43926.
        let mut c = Crc32::new();
        c.update(b"123456789");
        assert_eq!(c.finish(), 0xcbf4_3926);
        // Split updates match a single pass.
        let mut s = Crc32::new();
        s.update(b"1234");
        s.update(b"56789");
        assert_eq!(s.finish(), 0xcbf4_3926);
    }

    #[test]
    fn adapter_sidecar_rejects_corruption_and_truncation() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(9);
        let set = test_set(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("apiq_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sidecar_crc.apq");
        save_adapter(&set, cfg.name, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        assert!(load_adapter(&path, &cfg).is_ok(), "clean file loads");

        // Flip one payload byte mid-file: parse may still succeed but the
        // CRC must not.
        let mut corrupt = clean.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0x01;
        std::fs::write(&path, &corrupt).unwrap();
        assert!(load_adapter(&path, &cfg).is_err(), "bit flip rejected");

        // Drop the trailer: truncation is rejected too.
        std::fs::write(&path, &clean[..clean.len() - 4]).unwrap();
        let err = load_adapter(&path, &cfg).unwrap_err().to_string();
        assert!(err.contains("truncated") || err.contains("CRC32"), "got: {err}");

        std::fs::remove_file(&path).ok();
    }
}
