//! Named parameter store: the host-side source of truth for every tensor
//! the artifacts consume (model weights, quant params, optimizer moments).
//!
//! Ordered map (BTreeMap) so iteration order matches the artifact
//! manifests' sorted-key flattening.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Flat-name -> Tensor map with helpers for prefix views and merging.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { map: BTreeMap::new() }
    }

    pub fn insert(&mut self, key: impl Into<String>, t: Tensor) {
        self.map.insert(key.into(), t);
    }

    pub fn get(&self, key: &str) -> Option<&Tensor> {
        self.map.get(key)
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Tensor> {
        self.map.get_mut(key)
    }

    pub fn require(&self, key: &str) -> Result<&Tensor> {
        self.map
            .get(key)
            .ok_or_else(|| Error::manifest(format!("missing param '{key}'")))
    }

    pub fn remove(&mut self, key: &str) -> Option<Tensor> {
        self.map.remove(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    /// All (key, tensor) pairs under a prefix, with the prefix stripped.
    /// Used to slice one block's params out of the full store:
    /// `view("blocks.3.")` yields keys like `wq`, `wq.gamma`, ...
    pub fn view(&self, prefix: &str) -> ParamStore {
        let mut out = ParamStore::new();
        for (k, v) in &self.map {
            if let Some(rest) = k.strip_prefix(prefix) {
                out.insert(rest.to_string(), v.clone());
            }
        }
        out
    }

    /// Write back a prefix view produced by `view`.
    pub fn absorb(&mut self, prefix: &str, sub: &ParamStore) {
        for (k, v) in sub.iter() {
            self.map.insert(format!("{prefix}{k}"), v.clone());
        }
    }

    /// Merge another store (other wins on conflicts).
    pub fn merge(&mut self, other: ParamStore) {
        for (k, v) in other.map {
            self.map.insert(k, v);
        }
    }

    /// Zero-filled clone (optimizer moment init).
    pub fn zeros_like(&self) -> ParamStore {
        let mut out = ParamStore::new();
        for (k, v) in &self.map {
            out.insert(k.clone(), Tensor::zeros(v.shape()));
        }
        out
    }

    /// Keep only entries whose key passes the filter.
    pub fn filtered(&self, pred: impl Fn(&str) -> bool) -> ParamStore {
        let mut out = ParamStore::new();
        for (k, v) in &self.map {
            if pred(k) {
                out.insert(k.clone(), v.clone());
            }
        }
        out
    }

    /// Total number of f32 elements (for memory accounting).
    pub fn n_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Check all tensors are finite; returns the first offending key.
    pub fn check_finite(&self) -> Result<()> {
        for (k, v) in &self.map {
            if !v.all_finite() {
                return Err(Error::numeric(format!("non-finite values in '{k}'")));
            }
        }
        Ok(())
    }
}

impl FromIterator<(String, Tensor)> for ParamStore {
    fn from_iter<I: IntoIterator<Item = (String, Tensor)>>(iter: I) -> Self {
        ParamStore { map: iter.into_iter().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        let mut ps = ParamStore::new();
        ps.insert("blocks.0.wq", Tensor::full(&[2, 2], 1.0));
        ps.insert("blocks.0.wq.gamma", Tensor::full(&[1, 2], 4.0));
        ps.insert("blocks.1.wq", Tensor::full(&[2, 2], 2.0));
        ps.insert("embed", Tensor::full(&[4, 2], 0.5));
        ps
    }

    #[test]
    fn view_strips_prefix() {
        let v = store().view("blocks.0.");
        assert_eq!(v.len(), 2);
        assert!(v.contains("wq"));
        assert!(v.contains("wq.gamma"));
    }

    #[test]
    fn absorb_roundtrip() {
        let mut ps = store();
        let mut v = ps.view("blocks.0.");
        v.get_mut("wq").unwrap().data_mut()[0] = 9.0;
        ps.absorb("blocks.0.", &v);
        assert_eq!(ps.get("blocks.0.wq").unwrap().data()[0], 9.0);
        assert_eq!(ps.get("blocks.1.wq").unwrap().data()[0], 2.0);
    }

    #[test]
    fn zeros_like_preserves_shapes() {
        let z = store().zeros_like();
        assert_eq!(z.get("embed").unwrap().shape(), &[4, 2]);
        assert_eq!(z.get("embed").unwrap().fro_norm(), 0.0);
    }

    #[test]
    fn require_errors_on_missing() {
        assert!(store().require("nope").is_err());
    }

    #[test]
    fn check_finite_catches_nan() {
        let mut ps = store();
        ps.get_mut("embed").unwrap().data_mut()[0] = f32::NAN;
        assert!(ps.check_finite().is_err());
    }

    #[test]
    fn keys_sorted() {
        let ps = store();
        let keys: Vec<_> = ps.keys().cloned().collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
