//! Model-side substrate: configs mirroring `python/compile/model.py`,
//! the named parameter store, block topology (calibration order), adapter
//! state, and versioned binary checkpoints.

pub mod checkpoint;
pub mod store;
pub mod topology;

pub use store::ParamStore;
pub use topology::{LinearKind, CALIB_STAGES, LINEAR_NAMES};

use crate::error::{Error, Result};
use crate::quant::QuantSpec;
use crate::tensor::{Rng, Tensor};

/// Mirror of the Python `ModelConfig` — MUST stay in sync with
/// `python/compile/model.py::SIZES` (the AOT artifacts bake these shapes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub calib_batch: usize,
}

pub const TINY: ModelConfig = ModelConfig {
    name: "tiny", vocab: 512, d_model: 256, n_layers: 4, n_heads: 4,
    d_ffn: 768, seq_len: 128, batch: 8, calib_batch: 8,
};
pub const SMALL: ModelConfig = ModelConfig {
    name: "small", vocab: 2048, d_model: 512, n_layers: 8, n_heads: 8,
    d_ffn: 1408, seq_len: 256, batch: 4, calib_batch: 4,
};
pub const BASE: ModelConfig = ModelConfig {
    name: "base", vocab: 4096, d_model: 768, n_layers: 12, n_heads: 12,
    d_ffn: 2176, seq_len: 256, batch: 2, calib_batch: 2,
};

impl ModelConfig {
    pub fn by_name(name: &str) -> Result<ModelConfig> {
        match name {
            "tiny" => Ok(TINY),
            "small" => Ok(SMALL),
            "base" => Ok(BASE),
            _ => Err(Error::config(format!("unknown model size '{name}'"))),
        }
    }

    /// (d_in, d_out) of a named linear layer.
    pub fn linear_shape(&self, lin: LinearKind) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ffn);
        match lin {
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv | LinearKind::Wo => (d, d),
            LinearKind::Wgate | LinearKind::Wup => (d, f),
            LinearKind::Wdown => (f, d),
        }
    }

    /// Total fp parameter count.
    pub fn n_params(&self) -> usize {
        let mut n = self.vocab * self.d_model * 2 + self.d_model; // embed, head, final_norm
        for lin in LINEAR_NAMES {
            let (a, b) = self.linear_shape(lin);
            n += a * b * self.n_layers;
        }
        n += 2 * self.d_model * self.n_layers; // norms
        n
    }

    /// Initialize full-precision parameters (Rust owns init; artifacts
    /// only consume buffers).  GPT-2-style scaled normal init.
    pub fn init_params(&self, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut ps = ParamStore::new();
        let std = 0.02f32;
        let resid_std = std / (2.0 * self.n_layers as f32).sqrt();
        ps.insert("embed", Tensor::randn(&[self.vocab, self.d_model], std, &mut rng));
        ps.insert("final_norm", Tensor::full(&[self.d_model], 1.0));
        ps.insert("lm_head", Tensor::randn(&[self.d_model, self.vocab], std, &mut rng));
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            ps.insert(format!("{p}attn_norm"), Tensor::full(&[self.d_model], 1.0));
            ps.insert(format!("{p}ffn_norm"), Tensor::full(&[self.d_model], 1.0));
            for lin in LINEAR_NAMES {
                let (a, b) = self.linear_shape(lin);
                // residual-path projections get the depth-scaled init
                let s = match lin {
                    LinearKind::Wo | LinearKind::Wdown => resid_std,
                    _ => std,
                };
                ps.insert(format!("{p}{}", lin.as_str()), Tensor::randn(&[a, b], s, &mut rng));
            }
        }
        ps
    }

    /// Initialize quant/adapter params for all linears:
    /// gamma=beta=4 (paper §4.3), A ~ Kaiming, B = 0, mag = 1 (dora).
    pub fn init_qparams(&self, spec: QuantSpec, rank: usize, dora: bool, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut ps = ParamStore::new();
        for i in 0..self.n_layers {
            for lin in LINEAR_NAMES {
                let (d_in, d_out) = self.linear_shape(lin);
                let g = d_in / spec.group;
                let p = format!("blocks.{i}.{}.", lin.as_str());
                ps.insert(format!("{p}gamma"), Tensor::full(&[g, d_out], 4.0));
                ps.insert(format!("{p}beta"), Tensor::full(&[g, d_out], 4.0));
                ps.insert(format!("{p}lora_a"), Tensor::kaiming(&[d_in, rank], &mut rng));
                ps.insert(format!("{p}lora_b"), Tensor::zeros(&[d_out, rank]));
                if dora {
                    ps.insert(format!("{p}mag"), Tensor::full(&[d_out], 1.0));
                }
            }
        }
        ps
    }

    /// Flat key of a linear weight.
    pub fn weight_key(&self, block: usize, lin: LinearKind) -> String {
        format!("blocks.{block}.{}", lin.as_str())
    }

    /// Flat key prefix of a linear's qparams.
    pub fn qparam_prefix(&self, block: usize, lin: LinearKind) -> String {
        format!("blocks.{block}.{}.", lin.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_paper_scale_axis() {
        assert!(TINY.n_params() < SMALL.n_params());
        assert!(SMALL.n_params() < BASE.n_params());
        assert!(BASE.n_params() > 85_000_000 && BASE.n_params() < 115_000_000);
    }

    #[test]
    fn init_params_complete() {
        let ps = TINY.init_params(1);
        assert_eq!(ps.len(), 3 + TINY.n_layers * (2 + LINEAR_NAMES.len()));
        assert_eq!(ps.get("embed").unwrap().shape(), &[512, 256]);
        assert_eq!(ps.get("blocks.3.wdown").unwrap().shape(), &[768, 256]);
    }

    #[test]
    fn init_qparams_shapes() {
        let spec = QuantSpec::new(2, 64);
        let qp = TINY.init_qparams(spec, 16, false, 2);
        assert_eq!(qp.get("blocks.0.wq.gamma").unwrap().shape(), &[4, 256]);
        assert_eq!(qp.get("blocks.0.wgate.lora_a").unwrap().shape(), &[256, 16]);
        assert_eq!(qp.get("blocks.0.wdown.lora_b").unwrap().shape(), &[256, 16]);
        assert!(qp.get("blocks.0.wq.mag").is_none());
        let qd = TINY.init_qparams(spec, 16, true, 2);
        assert_eq!(qd.get("blocks.0.wq.mag").unwrap().shape(), &[256]);
    }

    #[test]
    fn init_is_deterministic() {
        let a = TINY.init_params(42);
        let b = TINY.init_params(42);
        assert_eq!(a.get("embed").unwrap(), b.get("embed").unwrap());
    }

    #[test]
    fn lora_b_zero_init() {
        let qp = TINY.init_qparams(QuantSpec::new(2, 64), 8, false, 3);
        assert_eq!(qp.get("blocks.1.wo.lora_b").unwrap().fro_norm(), 0.0);
        assert!(qp.get("blocks.1.wo.lora_a").unwrap().fro_norm() > 0.0);
    }
}
