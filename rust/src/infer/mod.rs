//! Native packed-weight inference engine — serving without artifacts.
//!
//! The ROADMAP's serving scenario: run a quantized model host-side with
//! no XLA/PJRT toolchain and no `artifacts/` directory.  The engine
//! mirrors `python/compile/model.py` exactly (RMSNorm eps 1e-5,
//! interleaved RoPE, causal softmax attention, SwiGLU, untied head) but
//! consumes *storage-form* weights: every linear is either a
//! `PackedLinear` (sub-byte codes + group metadata, multiplied through
//! the fused dequantize-on-the-fly GEMM `PackedLinear::matmul_fused`) or
//! a dense f32 fallback (for baselines that ship dequantized weights,
//! and for full-precision reference runs).  LoRA adapters ride along as
//! `y += scale * (x·A)·Bᵀ`; DoRA's column rescale `mag/‖Q + s·A·Bᵀ‖_col`
//! is precomputed at build time so the serving path stays two GEMMs.
//!
//! Entry points:
//!   * [`PackedModel::build`] / [`PackedModel::from_quant_result`]
//!   * [`PackedModel::logits`] — batched forward, (B, T) -> (B, T, V)
//!   * [`generate_greedy`] — batched greedy decoding with a tokens/sec
//!     and resident-bytes report (`repro generate`, `repro bench-infer`)
//!
//! The KV-cached incremental forward (`PackedModel::forward_chunk` /
//! `forward_step` over flat slabs, their `_paged` twins plus the
//! batched `prefill_batch` over paged block tables), sampling, and the
//! continuous-batching token server live in `crate::serve`, built on
//! this engine.  The shared `RopeCache` below is sized by the serving
//! path's KV capacity and indexed by absolute position, so flat, paged,
//! and full-forward paths all read the same sin/cos bits.

use std::sync::{Arc, RwLock, RwLockReadGuard};

use crate::error::{Error, Result};
use crate::model::{LinearKind, ModelConfig, ParamStore};
use crate::quant::affine::quantize_ints;
use crate::quant::{PackedLinear, QuantSpec};
use crate::quantizers::QuantResult;
use crate::tensor::{IntTensor, Tensor};

/// LoRA/DoRA adapter state for one linear, serving-form.
#[derive(Clone)]
pub struct Adapter {
    /// (d_in, r)
    pub a: Tensor,
    /// Bᵀ, stored pre-transposed: (r, d_out).
    pub b_t: Tensor,
    /// LoRA scale (alpha / r).
    pub scale: f32,
    /// DoRA per-output-column rescale `mag_c / ‖Q + scale·A·Bᵀ‖_col`,
    /// precomputed at build time; `None` for plain LoRA.
    pub col_scale: Option<Vec<f32>>,
}

impl Adapter {
    /// Low-rank rank r (columns of A).
    pub fn rank(&self) -> usize {
        if self.a.shape().len() == 2 {
            self.a.shape()[1]
        } else {
            0
        }
    }

    /// f32 bytes resident for this adapter's tensors.
    pub fn resident_bytes(&self) -> usize {
        (self.a.len() + self.b_t.len()) * 4
            + self.col_scale.as_ref().map(|c| c.len() * 4).unwrap_or(0)
    }

    /// Add this adapter's contribution to a projection output `y`
    /// (n, d_out) computed from input rows `x` (n, d_in):
    /// `y += scale·(x·A)·Bᵀ`, then DoRA's per-output-column rescale.
    /// The operation order is load-bearing — base GEMM, elementwise
    /// low-rank add, column rescale — because the baked-in adapter path
    /// this refactor replaced computed it exactly this way, and the
    /// serving tests pin bitwise identity against it.
    pub fn apply(&self, x: &Tensor, y: &mut Tensor) -> Result<()> {
        let low = x.matmul(&self.a)?.matmul(&self.b_t)?; // (n, d_out)
        for (yv, lv) in y.data_mut().iter_mut().zip(low.data()) {
            *yv += self.scale * lv;
        }
        if let Some(cs) = &self.col_scale {
            for row in y.data_mut().chunks_mut(cs.len()) {
                for (v, &c) in row.iter_mut().zip(cs.iter()) {
                    *v *= c;
                }
            }
        }
        Ok(())
    }
}

/// Number of adapted linears per block; slot order is fixed as
/// wq, wk, wv, wo, wgate, wup, wdown (shared with `model::checkpoint`).
pub const ADAPTER_SLOTS: usize = 7;
pub const SLOT_WQ: usize = 0;
pub const SLOT_WK: usize = 1;
pub const SLOT_WV: usize = 2;
pub const SLOT_WO: usize = 3;
pub const SLOT_WGATE: usize = 4;
pub const SLOT_WUP: usize = 5;
pub const SLOT_WDOWN: usize = 6;

/// A named set of LoRA/DoRA adapters over one frozen base: at most one
/// [`Adapter`] per (block, linear) pair.  Adapters no longer live inside
/// [`PackedLayer`] — every forward path resolves a set per call (or per
/// sequence, in the batched decode paths), so one packed 2-bit base can
/// serve many adapters at once.
#[derive(Clone)]
pub struct AdapterSet {
    pub name: String,
    /// `layers[block][slot]`, slot order wq, wk, wv, wo, wgate, wup, wdown.
    pub layers: Vec<[Option<Adapter>; ADAPTER_SLOTS]>,
}

impl AdapterSet {
    /// The adapter for `(block, slot)`, if that linear is adapted.
    pub fn get(&self, block: usize, slot: usize) -> Option<&Adapter> {
        self.layers.get(block).and_then(|arr| arr[slot].as_ref())
    }

    /// True when no linear in any block carries an adapter.
    pub fn is_empty(&self) -> bool {
        self.layers
            .iter()
            .all(|arr| arr.iter().all(|a| a.is_none()))
    }

    /// Largest low-rank r across the set (0 when empty).
    pub fn rank(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|arr| arr.iter().flatten())
            .map(|a| a.rank())
            .max()
            .unwrap_or(0)
    }

    /// Number of adapted (block, linear) pairs.
    pub fn n_adapted(&self) -> usize {
        self.layers
            .iter()
            .map(|arr| arr.iter().filter(|a| a.is_some()).count())
            .sum()
    }

    /// f32 bytes resident for every adapter tensor in the set.
    pub fn resident_bytes(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|arr| arr.iter().flatten())
            .map(|a| a.resident_bytes())
            .sum()
    }

    /// The set restricted to the first `n` blocks — pairs with
    /// [`PackedModel::prefix_cut`] so a self-draft keeps the adapters of
    /// the layers it retains.
    pub fn prefix_cut(&self, n: usize) -> AdapterSet {
        AdapterSet {
            name: self.name.clone(),
            layers: self.layers[..n.min(self.layers.len())].to_vec(),
        }
    }
}

/// Storage form of one linear's base weight.
#[derive(Clone)]
pub enum LayerWeight {
    /// Sub-byte packed codes (the 2/3/4-bit serving path).
    Packed(PackedLinear),
    /// Dense f32 (fp reference, or baselines that ship dequantized Q).
    Dense(Tensor),
}

/// One servable linear: the frozen base weight.  Adapters are resolved
/// per call from an [`AdapterSet`] so the same packed payload serves any
/// number of `(base, adapter)` pairings.
#[derive(Clone)]
pub struct PackedLayer {
    pub weight: LayerWeight,
}

impl PackedLayer {
    /// y = x @ W' for x (n, d_in), where W' includes `adapter` (if any)
    /// and, for DoRA, the column rescale.  Packed weights go through
    /// `matvec_fused`, which runs the GEMV-specialized kernel for
    /// decode-shaped inputs (`n <= 4`) and falls back to the panel path
    /// for wider ones — output is bitwise identical either way (see
    /// `kernels`), so cached decode still reproduces the full forward
    /// exactly.
    pub fn forward(&self, x: &Tensor, adapter: Option<&Adapter>) -> Result<Tensor> {
        let mut y = match &self.weight {
            LayerWeight::Packed(pl) => pl.matvec_fused(x)?,
            LayerWeight::Dense(w) => x.matmul(w)?,
        };
        if let Some(ad) = adapter {
            ad.apply(x, &mut y)?;
        }
        Ok(y)
    }

    /// Bytes resident for this layer's base weights.
    pub fn resident_bytes(&self) -> usize {
        match &self.weight {
            LayerWeight::Packed(pl) => pl.storage_bytes(),
            LayerWeight::Dense(t) => t.len() * 4,
        }
    }

    fn weight_elems(&self) -> usize {
        match &self.weight {
            LayerWeight::Packed(pl) => pl.d_in * pl.d_out,
            LayerWeight::Dense(t) => t.len(),
        }
    }
}

/// One transformer block in serving form.
#[derive(Clone)]
pub struct PackedBlock {
    pub attn_norm: Tensor,
    pub ffn_norm: Tensor,
    pub wq: PackedLayer,
    pub wk: PackedLayer,
    pub wv: PackedLayer,
    pub wo: PackedLayer,
    pub wgate: PackedLayer,
    pub wup: PackedLayer,
    pub wdown: PackedLayer,
}

/// A whole model in serving form.
pub struct PackedModel {
    pub cfg: ModelConfig,
    pub spec: QuantSpec,
    pub embed: Tensor,
    pub final_norm: Tensor,
    pub lm_head: Tensor,
    pub blocks: Vec<PackedBlock>,
    /// The adapter set baked into the checkpoint/build (qparams LoRA/DoRA
    /// tensors), applied whenever a caller does not route another set —
    /// the pre-registry single-pairing behaviour, preserved bit for bit.
    pub default_adapter: Option<Arc<AdapterSet>>,
    /// Shared precomputed RoPE sin/cos rows (grown once to the longest
    /// sequence seen); all forward paths index it by absolute position.
    pub(crate) rope: RopeCache,
}

// ---------------------------------------------------------------------------
// Numerics shared by the forward pass (mirror python/compile/model.py)
// ---------------------------------------------------------------------------

const RMSNORM_EPS: f32 = 1e-5;

/// Row-wise RMSNorm in place: x <- x * rsqrt(mean(x^2) + eps) * w.
/// `pub(crate)` so the incremental decode path in `serve` applies the
/// exact same normalization arithmetic.
pub(crate) fn rmsnorm_rows(data: &mut [f32], d: usize, w: &[f32]) {
    for row in data.chunks_mut(d) {
        let var = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + RMSNORM_EPS).sqrt();
        for (v, &wj) in row.iter_mut().zip(w.iter()) {
            *v *= inv * wj;
        }
    }
}

/// RoPE cos/sin tables for `t` consecutive positions at
/// `half = head_dim/2` freqs.  Row `ti` holds position `offset + ti`:
/// each entry is computed from the absolute position with the exact same
/// arithmetic regardless of `offset`, so the incremental decode path
/// (one position at a time) reproduces the full-prefix tables bit for
/// bit.
pub(crate) struct RopeTables {
    pub(crate) cos: Vec<f32>,
    pub(crate) sin: Vec<f32>,
    pub(crate) half: usize,
}

impl RopeTables {
    /// Tables for absolute positions [offset, offset + t).
    pub(crate) fn with_offset(offset: usize, t: usize, head_dim: usize) -> Self {
        let half = head_dim / 2;
        let mut cos = Vec::with_capacity(t * half);
        let mut sin = Vec::with_capacity(t * half);
        for ti in 0..t {
            let pos = offset + ti;
            for j in 0..half {
                let inv = 1.0 / 10000f32.powf(2.0 * j as f32 / head_dim as f32);
                let ang = pos as f32 * inv;
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }
        RopeTables { cos, sin, half }
    }

    /// Positions this table covers (tables always start at position 0
    /// when they come out of [`RopeCache`]).
    pub(crate) fn positions(&self) -> usize {
        if self.half == 0 {
            0
        } else {
            self.cos.len() / self.half
        }
    }

    fn covers(&self, head_dim: usize, upto: usize) -> bool {
        if head_dim / 2 == 0 {
            // no rotation to apply; any table "covers" it
            return true;
        }
        self.half == head_dim / 2 && self.positions() >= upto
    }

    /// Borrowed window over rows [offset, offset + t).
    pub(crate) fn view(&self, offset: usize, t: usize) -> RopeView<'_> {
        let h = self.half;
        RopeView {
            cos: &self.cos[offset * h..(offset + t) * h],
            sin: &self.sin[offset * h..(offset + t) * h],
            half: h,
        }
    }
}

/// A borrowed window of precomputed RoPE rows, row `ti` = absolute
/// position `offset + ti` of the table it was cut from.
pub(crate) struct RopeView<'a> {
    pub(crate) cos: &'a [f32],
    pub(crate) sin: &'a [f32],
    pub(crate) half: usize,
}

/// Lazily grown, shared RoPE table: sin/cos are computed ONCE per
/// position (power-of-two growth up to the longest sequence seen, i.e.
/// the KV-cache capacity in steady state) instead of per sequence per
/// step — `forward_step` used to rebuild a fresh table for every
/// sequence on every decode step.  Reads take the uncontended read-lock
/// path; growth is rare and rebuilds from position 0 with the exact same
/// arithmetic, so cached rows are bit-identical to freshly built ones.
pub(crate) struct RopeCache {
    tables: RwLock<RopeTables>,
}

impl RopeCache {
    pub(crate) fn new() -> Self {
        RopeCache {
            tables: RwLock::new(RopeTables { cos: Vec::new(), sin: Vec::new(), half: 0 }),
        }
    }

    /// Read guard over tables covering positions [0, upto).
    pub(crate) fn upto(&self, head_dim: usize, upto: usize) -> RwLockReadGuard<'_, RopeTables> {
        {
            let g = self.tables.read().expect("rope cache poisoned");
            if g.covers(head_dim, upto) {
                return g;
            }
        }
        {
            let mut w = self.tables.write().expect("rope cache poisoned");
            if !w.covers(head_dim, upto) {
                let cap = upto.next_power_of_two().max(128);
                *w = RopeTables::with_offset(0, cap, head_dim);
            }
        }
        self.tables.read().expect("rope cache poisoned")
    }
}

impl Default for RopeCache {
    fn default() -> Self {
        RopeCache::new()
    }
}

/// Rotate interleaved (even, odd) pairs of every head, in place.
/// `data` is (b*t, d) row-major with d = h * hd.
pub(crate) fn apply_rope(
    data: &mut [f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    rope: &RopeView<'_>,
) {
    let d = h * hd;
    let half = rope.half;
    for bi in 0..b {
        for ti in 0..t {
            let row = &mut data[(bi * t + ti) * d..(bi * t + ti + 1) * d];
            for head in 0..h {
                for j in 0..half {
                    let c = rope.cos[ti * half + j];
                    let s = rope.sin[ti * half + j];
                    let i0 = head * hd + 2 * j;
                    let x1 = row[i0];
                    let x2 = row[i0 + 1];
                    row[i0] = x1 * c - x2 * s;
                    row[i0 + 1] = x1 * s + x2 * c;
                }
            }
        }
    }
}

/// Deterministic argmax over logits, total on NaN inputs: NaN entries are
/// skipped (a NaN anywhere used to poison every `v > bv` comparison and
/// silently return whatever index preceded it), ties break to the FIRST
/// maximal index, and an all-NaN/empty row falls back to 0.  The greedy
/// decode path and the samplers in `serve::sampling` both route through
/// this.
pub fn argmax(row: &[f32]) -> usize {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i).unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

fn build_layer(
    cfg: &ModelConfig,
    params: &ParamStore,
    qparams: Option<&ParamStore>,
    block: usize,
    lin: LinearKind,
    spec: QuantSpec,
    scale: f32,
) -> Result<(PackedLayer, Option<Adapter>)> {
    let (d_in, d_out) = cfg.linear_shape(lin);
    let w = params.require(&cfg.weight_key(block, lin))?;
    if w.shape() != [d_in, d_out] {
        return Err(Error::shape(format!(
            "linear {} block {block}: weight {:?}, want [{d_in}, {d_out}]",
            lin.as_str(),
            w.shape()
        )));
    }
    let prefix = cfg.qparam_prefix(block, lin);

    let weight = match qparams {
        Some(qp) if spec.bits <= 8 => {
            let gamma = qp.require(&format!("{prefix}gamma"))?;
            let beta = qp.require(&format!("{prefix}beta"))?;
            let (codes, s, z) = quantize_ints(w, gamma, beta, spec)?;
            LayerWeight::Packed(PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec)?)
        }
        _ => LayerWeight::Dense(w.clone()),
    };

    let adapter = match qparams {
        None => None,
        Some(qp) => {
            let a = qp.require(&format!("{prefix}lora_a"))?.clone();
            let b_t = qp.require(&format!("{prefix}lora_b"))?.transpose()?;
            let col_scale = match qp.get(&format!("{prefix}mag")) {
                None => None,
                Some(mag) => {
                    // DoRA: mag_c / ||Q + scale*A*B^T||_col, the +1e-8
                    // inside the sqrt matching kernels/ref.py.
                    let q = match &weight {
                        LayerWeight::Packed(pl) => pl.dequantize()?,
                        LayerWeight::Dense(t) => t.clone(),
                    };
                    let ab = a.matmul(&b_t)?; // (d_in, d_out)
                    let mut sumsq = vec![0.0f32; d_out];
                    for r in 0..d_in {
                        let qrow = q.row(r);
                        let abrow = ab.row(r);
                        for c in 0..d_out {
                            let m = qrow[c] + scale * abrow[c];
                            sumsq[c] += m * m;
                        }
                    }
                    Some(
                        mag.data()
                            .iter()
                            .zip(&sumsq)
                            .map(|(&m, &s)| m / (s + 1e-8).sqrt())
                            .collect(),
                    )
                }
            };
            Some(Adapter { a, b_t, scale, col_scale })
        }
    };

    Ok((PackedLayer { weight }, adapter))
}

impl PackedModel {
    /// Build a servable model from flat parameter stores.
    ///
    /// * `qparams = None` -> full-precision reference (dense, no adapters).
    /// * `spec.bits <= 8` -> linears are packed via the affine quantizer
    ///   with the store's gamma/beta clipping (bit-identical to the
    ///   in-graph fake-quant path).
    /// * `spec.bits > 8` (e.g. 16) -> linears stay dense f32 — the path
    ///   for baselines whose `params` already hold dequantized Q.
    pub fn build(
        cfg: ModelConfig,
        params: &ParamStore,
        qparams: Option<&ParamStore>,
        spec: QuantSpec,
        scale: f32,
    ) -> Result<Self> {
        let embed = params.require("embed")?.clone();
        let final_norm = params.require("final_norm")?.clone();
        let lm_head = params.require("lm_head")?.clone();
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        let mut ad_layers: Vec<[Option<Adapter>; ADAPTER_SLOTS]> =
            Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            let lay = |lin: LinearKind| build_layer(&cfg, params, qparams, b, lin, spec, scale);
            let (wq, aq) = lay(LinearKind::Wq)?;
            let (wk, ak) = lay(LinearKind::Wk)?;
            let (wv, av) = lay(LinearKind::Wv)?;
            let (wo, ao) = lay(LinearKind::Wo)?;
            let (wgate, agate) = lay(LinearKind::Wgate)?;
            let (wup, aup) = lay(LinearKind::Wup)?;
            let (wdown, adown) = lay(LinearKind::Wdown)?;
            blocks.push(PackedBlock {
                attn_norm: params.require(&format!("blocks.{b}.attn_norm"))?.clone(),
                ffn_norm: params.require(&format!("blocks.{b}.ffn_norm"))?.clone(),
                wq,
                wk,
                wv,
                wo,
                wgate,
                wup,
                wdown,
            });
            ad_layers.push([aq, ak, av, ao, agate, aup, adown]);
        }
        let default_adapter = if ad_layers.iter().any(|arr| arr.iter().any(|a| a.is_some())) {
            Some(Arc::new(AdapterSet { name: "builtin".to_string(), layers: ad_layers }))
        } else {
            None
        };
        Ok(PackedModel {
            cfg,
            spec,
            embed,
            final_norm,
            lm_head,
            blocks,
            default_adapter,
            rope: RopeCache::new(),
        })
    }

    /// Build from any quantizer's `QuantResult`: in-graph quantizers
    /// (rtn, omniquant, apiq-*) pack at their native bits; weight-override
    /// baselines (eval_bits 16) serve their dequantized weights densely.
    pub fn from_quant_result(
        cfg: ModelConfig,
        r: &QuantResult,
        group: usize,
        scale: f32,
    ) -> Result<Self> {
        let bits = r.eval_bits.round() as u32;
        Self::build(cfg, &r.params, Some(&r.qparams), QuantSpec::new(bits, group), scale)
    }

    /// Batched forward: tokens (B, T) -> logits (B, T, V).
    pub fn logits(&self, tokens: &IntTensor) -> Result<Tensor> {
        if tokens.shape().len() != 2 {
            return Err(Error::shape("PackedModel::logits wants (B, T) tokens"));
        }
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        let d = self.cfg.d_model;
        let vocab = self.cfg.vocab;
        let h = self.cfg.n_heads;
        let hd = d / h;
        let tables = self.rope.upto(hd, t);
        let rope = tables.view(0, t);

        // Embed.
        let mut x = Tensor::zeros(&[b * t, d]);
        {
            let xd = x.data_mut();
            for (i, &tok) in tokens.data().iter().enumerate() {
                let tok = (tok.max(0) as usize).min(vocab - 1);
                xd[i * d..(i + 1) * d].copy_from_slice(self.embed.row(tok));
            }
        }

        let set = self.default_adapter.as_deref();
        for (li, block) in self.blocks.iter().enumerate() {
            x = block.forward(&self.cfg, &x, b, t, &rope, li, set)?;
        }

        rmsnorm_rows(x.data_mut(), d, self.final_norm.data());
        let logits = x.matmul(&self.lm_head)?;
        logits.reshape(&[b, t, vocab])
    }

    /// Actual bytes resident for serving (packed codes + metadata + dense
    /// f32 tensors + adapters) — the measured counterpart of
    /// `MemoryModel::inference_weights`.
    pub fn resident_bytes(&self) -> usize {
        let mut total = (self.embed.len() + self.final_norm.len() + self.lm_head.len()) * 4;
        for blk in &self.blocks {
            total += (blk.attn_norm.len() + blk.ffn_norm.len()) * 4;
            for lay in [
                &blk.wq, &blk.wk, &blk.wv, &blk.wo, &blk.wgate, &blk.wup, &blk.wdown,
            ] {
                total += lay.resident_bytes();
            }
        }
        if let Some(set) = &self.default_adapter {
            total += set.resident_bytes();
        }
        total
    }

    /// Clone a depth-truncated copy of this model: the first `n_layers`
    /// blocks under the same embedding, final norm, and LM head — the
    /// self-draft construction for speculative decoding (`--draft-layers`).
    /// Vocabulary and tokenization agree with the target by construction,
    /// which is all the draft/verify loop needs; the cut model is a real
    /// [`PackedModel`], so every decode path (paged caches included) works
    /// on it unchanged.
    pub fn prefix_cut(&self, n_layers: usize) -> Result<PackedModel> {
        if n_layers == 0 || n_layers > self.cfg.n_layers {
            return Err(Error::config(format!(
                "prefix_cut: want 1..={} layers, got {n_layers}",
                self.cfg.n_layers
            )));
        }
        let mut cfg = self.cfg;
        cfg.n_layers = n_layers;
        Ok(PackedModel {
            cfg,
            spec: self.spec,
            embed: self.embed.clone(),
            final_norm: self.final_norm.clone(),
            lm_head: self.lm_head.clone(),
            blocks: self.blocks[..n_layers].to_vec(),
            default_adapter: self
                .default_adapter
                .as_ref()
                .map(|s| Arc::new(s.prefix_cut(n_layers))),
            rope: RopeCache::new(),
        })
    }

    /// Were LoRA/DoRA adapters built into the serving path?  Scans every
    /// (block, linear) slot of the default set — a set whose adapters sit
    /// only on later blocks or non-wq projections still counts.
    pub fn has_adapters(&self) -> bool {
        self.default_adapter
            .as_ref()
            .map(|s| !s.is_empty())
            .unwrap_or(false)
    }

    /// Average bits per linear-layer weight as stored (dense layers count
    /// as 32-bit) — the serving analogue of the paper's §5.1 caveat.
    pub fn effective_bits(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut elems = 0usize;
        for blk in &self.blocks {
            for lay in [
                &blk.wq, &blk.wk, &blk.wv, &blk.wo, &blk.wgate, &blk.wup, &blk.wdown,
            ] {
                let n = lay.weight_elems();
                let b = match &lay.weight {
                    LayerWeight::Packed(pl) => pl.effective_bits(),
                    LayerWeight::Dense(_) => 32.0,
                };
                bits += b * n as f64;
                elems += n;
            }
        }
        if elems == 0 {
            0.0
        } else {
            bits / elems as f64
        }
    }
}

impl PackedBlock {
    /// One block over x (b*t, d); returns the block output (b*t, d).
    /// `li` is this block's index into `set` (the routed adapter set).
    #[allow(clippy::too_many_arguments)]
    fn forward(
        &self,
        cfg: &ModelConfig,
        x: &Tensor,
        b: usize,
        t: usize,
        rope: &RopeView<'_>,
        li: usize,
        set: Option<&AdapterSet>,
    ) -> Result<Tensor> {
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = d / h;
        let ad = |slot: usize| set.and_then(|s| s.get(li, slot));

        // -- attention branch --
        let mut attn_in = x.clone();
        rmsnorm_rows(attn_in.data_mut(), d, self.attn_norm.data());
        let mut q = self.wq.forward(&attn_in, ad(SLOT_WQ))?;
        let mut k = self.wk.forward(&attn_in, ad(SLOT_WK))?;
        let v = self.wv.forward(&attn_in, ad(SLOT_WV))?;
        apply_rope(q.data_mut(), b, t, h, hd, rope);
        apply_rope(k.data_mut(), b, t, h, hd, rope);

        // causal softmax attention, per (batch, head)
        let mut ctx = Tensor::zeros(&[b * t, d]);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        let (qd, kd, vd) = (q.data(), k.data(), v.data());
        let cd = ctx.data_mut();
        let mut probs = vec![0.0f32; t];
        for bi in 0..b {
            for head in 0..h {
                let off = head * hd;
                for tq in 0..t {
                    let qrow = &qd[(bi * t + tq) * d + off..(bi * t + tq) * d + off + hd];
                    let mut mx = f32::NEG_INFINITY;
                    for (tk, p) in probs.iter_mut().enumerate().take(tq + 1) {
                        let krow = &kd[(bi * t + tk) * d + off..(bi * t + tk) * d + off + hd];
                        let mut s = 0.0f32;
                        for j in 0..hd {
                            s += qrow[j] * krow[j];
                        }
                        let s = s * inv_sqrt;
                        *p = s;
                        mx = mx.max(s);
                    }
                    let mut denom = 0.0f32;
                    for p in probs.iter_mut().take(tq + 1) {
                        *p = (*p - mx).exp();
                        denom += *p;
                    }
                    let inv = 1.0 / denom;
                    let crow_start = (bi * t + tq) * d + off;
                    for tk in 0..=tq {
                        let p = probs[tk] * inv;
                        let vrow = &vd[(bi * t + tk) * d + off..(bi * t + tk) * d + off + hd];
                        let crow = &mut cd[crow_start..crow_start + hd];
                        for j in 0..hd {
                            crow[j] += p * vrow[j];
                        }
                    }
                }
            }
        }
        let attn_out = self.wo.forward(&ctx, ad(SLOT_WO))?;
        let x1 = x.add(&attn_out)?;

        // -- FFN branch (SwiGLU) --
        let mut ffn_in = x1.clone();
        rmsnorm_rows(ffn_in.data_mut(), d, self.ffn_norm.data());
        let mut hidden = self.wgate.forward(&ffn_in, ad(SLOT_WGATE))?;
        let up = self.wup.forward(&ffn_in, ad(SLOT_WUP))?;
        for (g, &u) in hidden.data_mut().iter_mut().zip(up.data()) {
            let gv = *g;
            *g = gv / (1.0 + (-gv).exp()) * u; // silu(gate) * up
        }
        let ffn_out = self.wdown.forward(&hidden, ad(SLOT_WDOWN))?;
        x1.add(&ffn_out)
    }
}

// ---------------------------------------------------------------------------
// Greedy decoding
// ---------------------------------------------------------------------------

/// Outcome of a batched greedy generation run.
pub struct GenReport {
    /// Per-sequence token ids, prompt + generated.
    pub tokens: Vec<Vec<i32>>,
    pub prompt_len: usize,
    pub new_tokens: usize,
    pub wall_secs: f64,
}

impl GenReport {
    /// Generated tokens per second across the batch.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        (self.tokens.len() * self.new_tokens) as f64 / self.wall_secs
    }
}

/// Batched greedy decoding: extend `prompt` (B, T0) by `max_new` argmax
/// tokens.  Delegates to the KV-cached incremental decode in
/// `serve::decode` (O(T) per emitted token); the original full-prefix
/// recompute survives as `serve::decode::generate_recompute` for the
/// bit-equivalence tests and the decode benchmark.
pub fn generate_greedy(
    model: &PackedModel,
    prompt: &IntTensor,
    max_new: usize,
) -> Result<GenReport> {
    crate::serve::decode::generate(model, prompt, max_new, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rmsnorm_unit_rows() {
        let mut data = vec![3.0f32, 3.0, 3.0, 3.0];
        let w = vec![1.0f32; 4];
        rmsnorm_rows(&mut data, 4, &w);
        for v in data {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(3);
        let (b, t, h, hd) = (1, 4, 2, 8);
        let x = Tensor::randn(&[b * t, h * hd], 1.0, &mut rng);
        let mut y = x.clone();
        let tables = RopeTables::with_offset(0, t, hd);
        apply_rope(y.data_mut(), b, t, h, hd, &tables.view(0, t));
        // rotations preserve the per-pair norm
        for i in 0..b * t * h * hd / 2 {
            let (a0, a1) = (x.data()[2 * i], x.data()[2 * i + 1]);
            let (b0, b1) = (y.data()[2 * i], y.data()[2 * i + 1]);
            let na = a0 * a0 + a1 * a1;
            let nb = b0 * b0 + b1 * b1;
            assert!((na - nb).abs() < 1e-3, "pair {i}: {na} vs {nb}");
        }
        // position 0 is the identity rotation
        assert_eq!(&x.data()[..h * hd], &y.data()[..h * hd]);
    }

    #[test]
    fn rope_cache_rows_match_fresh_offset_tables() {
        let cache = RopeCache::new();
        let hd = 8;
        let guard = cache.upto(hd, 10);
        let fresh = RopeTables::with_offset(4, 3, hd);
        let view = guard.view(4, 3);
        assert_eq!(view.cos, &fresh.cos[..], "cached cos rows must be bit-identical");
        assert_eq!(view.sin, &fresh.sin[..], "cached sin rows must be bit-identical");
        drop(guard);
        // growth rebuilds from position 0 with identical arithmetic
        let grown = cache.upto(hd, 1000);
        assert!(grown.positions() >= 1000);
        let regrown = grown.view(4, 3);
        assert_eq!(regrown.cos, &fresh.cos[..]);
        assert_eq!(regrown.sin, &fresh.sin[..]);
    }

    #[test]
    fn argmax_first_max_ties_and_nan_total() {
        // plain max
        assert_eq!(argmax(&[0.0, 3.0, 1.0]), 1);
        // ties break to the FIRST maximal index
        assert_eq!(argmax(&[2.0, 5.0, 5.0, 1.0]), 1);
        // NaN is skipped wherever it appears, including before/after the max
        assert_eq!(argmax(&[f32::NAN, 2.0, 7.0]), 2);
        assert_eq!(argmax(&[2.0, f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[1.0, 7.0, f32::NAN]), 1);
        // -inf is a real (comparable) value
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // total on degenerate rows
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
    }

    #[test]
    fn adapter_lowrank_and_dora_rescale() {
        let mut rng = Rng::new(5);
        let (d_in, d_out, r) = (8, 6, 2);
        let w = Tensor::randn(&[d_in, d_out], 0.5, &mut rng);
        let a = Tensor::randn(&[d_in, r], 0.5, &mut rng);
        let bmat = Tensor::randn(&[d_out, r], 0.5, &mut rng);
        let b_t = bmat.transpose().unwrap();
        let scale = 0.7f32;

        // dense reference: x @ (W + scale*A*B^T)
        let ab = a.matmul(&b_t).unwrap();
        let merged = w.add(&ab.scale(scale)).unwrap();
        let x = Tensor::randn(&[3, d_in], 1.0, &mut rng);
        let want = x.matmul(&merged).unwrap();

        let layer = PackedLayer { weight: LayerWeight::Dense(w.clone()) };
        let lora = Adapter { a: a.clone(), b_t: b_t.clone(), scale, col_scale: None };
        let got = layer.forward(&x, Some(&lora)).unwrap();
        let rel = got.sub(&want).unwrap().fro_norm() / want.fro_norm();
        assert!(rel < 1e-5, "lora rel {rel}");

        // DoRA: column rescale by mag / ||merged||_col
        let mut col_scale = vec![0.0f32; d_out];
        let mag = 1.5f32;
        for c in 0..d_out {
            let mut s = 0.0f32;
            for row in 0..d_in {
                s += merged.at2(row, c) * merged.at2(row, c);
            }
            col_scale[c] = mag / (s + 1e-8).sqrt();
        }
        let dora_layer = PackedLayer { weight: LayerWeight::Dense(w) };
        let dora = Adapter { a, b_t, scale, col_scale: Some(col_scale.clone()) };
        let got2 = dora_layer.forward(&x, Some(&dora)).unwrap();
        for tr in 0..3 {
            for c in 0..d_out {
                let expect = want.at2(tr, c) * col_scale[c];
                assert!((got2.at2(tr, c) - expect).abs() < 1e-4);
            }
        }
    }
}
