//! Experiment pipeline: the shared plumbing every table/figure binary
//! uses — pretrain-or-load, calibration batches, quantize, evaluate,
//! finetune — so the `examples/` drivers stay declarative.

use std::path::PathBuf;

use crate::data::{Batch, Batcher, Task, ZipfMarkovCorpus};
use crate::error::Result;
use crate::eval::{accuracy_from_logits, mc_accuracy_from_logits, Evaluator, ModelMode};
use crate::model::{checkpoint, ModelConfig, ParamStore};
use crate::quant::QuantSpec;
use crate::quantizers::{by_name, ApiQ, ApiQHyper, QuantResult, QuantizeCtx, Quantizer};
use crate::runtime::Runtime;
use crate::tensor::Rng;
use crate::train::{FinetuneData, Finetuner, LoraPosition, Pretrainer, TrainReport};

/// Defaults mirrored by the artifact plan in `python/compile/aot.py`.
pub const DEFAULT_RANK: usize = 16;
pub const DEFAULT_GROUP: usize = 64;
pub const DEFAULT_SCALE: f32 = 1.0;
/// Calibration set: n_batches of calib_batch sequences each — the stand-in
/// for the paper's "128 sentences from WikiText-2".
pub const DEFAULT_CALIB_BATCHES: usize = 4;

/// Default pretraining budget per model size (CPU-host calibrated: the
/// tiny model needs ~1.5k steps before 2-bit quantization meaningfully
/// damages it — an undertrained model has no knowledge to forget).
pub fn default_pretrain_steps(size: &str) -> usize {
    match size {
        "base" => 120,
        "small" => 200,
        _ => 1500,
    }
}

/// A prepared experiment environment.
pub struct Env {
    pub runtime: Runtime,
    pub cfg: ModelConfig,
    pub params: ParamStore,
    pub corpus: ZipfMarkovCorpus,
    pub calib: Vec<Batch>,
    pub seed: u64,
    pub verbose: bool,
}

impl Env {
    /// Pretrain (or load a cached checkpoint) and build calibration data.
    pub fn prepare(
        artifacts_dir: impl Into<PathBuf>,
        size: &str,
        pretrain_steps: usize,
        seed: u64,
    ) -> Result<Env> {
        let runtime = Runtime::new(artifacts_dir)?;
        let cfg = ModelConfig::by_name(size)?;
        let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed);
        let ckpt = checkpoint::pretrained_path(cfg.name, pretrain_steps, seed);
        let params = if ckpt.exists() {
            eprintln!("[env] loading cached checkpoint {}", ckpt.display());
            checkpoint::load(&ckpt)?
        } else {
            eprintln!(
                "[env] pretraining {} ({} params) for {pretrain_steps} steps ...",
                cfg.name,
                cfg.n_params()
            );
            let mut params = cfg.init_params(seed);
            let trainer = Pretrainer::new(&runtime, cfg, pretrain_steps);
            let report = trainer.train(&mut params, &corpus, pretrain_steps, seed ^ 0x7EA1)?;
            eprintln!(
                "[env] pretraining done: loss {:.4} -> {:.4} in {:.1}s",
                report.losses.first().copied().unwrap_or(f32::NAN),
                report.tail_mean(10),
                report.wall_secs
            );
            checkpoint::save(&params, &ckpt)?;
            params
        };
        let batcher = Batcher::new(cfg.calib_batch, cfg.seq_len);
        let mut crng = Rng::new(seed ^ 0xCA11B);
        let calib = (0..DEFAULT_CALIB_BATCHES)
            .map(|_| batcher.lm_batch(&corpus, &mut crng))
            .collect();
        Ok(Env { runtime, cfg, params, corpus, calib, seed, verbose: true })
    }

    /// Build a QuantizeCtx for this env.
    pub fn ctx(&self, spec: QuantSpec, rank: usize) -> QuantizeCtx<'_> {
        QuantizeCtx {
            runtime: &self.runtime,
            cfg: self.cfg,
            params: &self.params,
            spec,
            rank,
            scale: DEFAULT_SCALE,
            calib: &self.calib,
            seed: self.seed,
            verbose: self.verbose,
        }
    }

    /// Run a named quantizer at (bits, group, rank).
    pub fn quantize(&self, method: &str, bits: u32, group: usize, rank: usize) -> Result<QuantResult> {
        let q = by_name(method)?;
        q.run(&self.ctx(QuantSpec::new(bits, group), rank))
    }

    /// Run an ApiQ variant with explicit hyper-parameters.
    pub fn quantize_apiq(
        &self,
        apiq: ApiQ,
        bits: u32,
        group: usize,
        rank: usize,
        hyper: ApiQHyper,
    ) -> Result<QuantResult> {
        let q = apiq.with_hyper(hyper);
        q.run(&self.ctx(QuantSpec::new(bits, group), rank))
    }

    /// Held-out LM eval batches (disjoint RNG stream from training).
    pub fn eval_batches(&self, n: usize) -> Vec<Batch> {
        let batcher = Batcher::new(self.cfg.batch, self.cfg.seq_len);
        let mut rng = Rng::new(self.seed ^ 0xE7A1);
        (0..n).map(|_| batcher.lm_batch(&self.corpus, &mut rng)).collect()
    }

    /// Held-out task eval batches.
    pub fn task_batches(&self, task: &dyn Task, n: usize) -> Vec<Batch> {
        let batcher = Batcher::new(self.cfg.batch, self.cfg.seq_len);
        let mut rng = Rng::new(self.seed ^ 0x7A5C);
        (0..n).map(|_| batcher.task_batch(task, &mut rng)).collect()
    }

    fn mode_for(&self, r: &QuantResult, rank: usize, group: usize, dora: bool) -> ModelMode {
        ModelMode::Quant {
            rank,
            group,
            bits: r.eval_bits,
            scale: DEFAULT_SCALE,
            dora,
        }
    }

    /// Perplexity of a quantized model on held-out corpus batches.
    pub fn ppl(&self, r: &QuantResult, rank: usize, group: usize, n_batches: usize) -> Result<f64> {
        let ev = Evaluator::new(&self.runtime, self.cfg);
        let batches = self.eval_batches(n_batches);
        let dora = r.method.contains("dora");
        ev.perplexity(&self.mode_for(r, rank, group, dora), &r.params, Some(&r.qparams), &batches)
    }

    /// Full-precision reference perplexity.
    pub fn ppl_fp(&self, n_batches: usize) -> Result<f64> {
        let ev = Evaluator::new(&self.runtime, self.cfg);
        let batches = self.eval_batches(n_batches);
        ev.perplexity(&ModelMode::Fp, &self.params, None, &batches)
    }

    /// Task accuracy (generative exact-match or MC depending on samples).
    pub fn task_accuracy(
        &self,
        r: &QuantResult,
        rank: usize,
        group: usize,
        task: &dyn Task,
        n_batches: usize,
        mc: bool,
    ) -> Result<f64> {
        let ev = Evaluator::new(&self.runtime, self.cfg);
        let dora = r.method.contains("dora");
        let mode = self.mode_for(r, rank, group, dora);
        let batches = self.task_batches(task, n_batches);
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in &batches {
            let logits = ev.logits(&mode, &r.params, Some(&r.qparams), b)?;
            let (c, t) = if mc {
                mc_accuracy_from_logits(&logits, b, self.cfg.vocab)
            } else {
                accuracy_from_logits(&logits, b, self.cfg.vocab)
            };
            correct += c;
            total += t;
        }
        Ok(if total == 0 { f64::NAN } else { correct as f64 / total as f64 })
    }

    /// Finetune a quantizer result's adapters on `data`.
    #[allow(clippy::too_many_arguments)]
    pub fn finetune(
        &self,
        r: &mut QuantResult,
        rank: usize,
        group: usize,
        data: &FinetuneData,
        steps: usize,
        lr: f32,
        position: LoraPosition,
    ) -> Result<TrainReport> {
        let mut ft = Finetuner::new(&self.runtime, self.cfg, rank, group, steps);
        ft.schedule = crate::train::LrSchedule::linear_warmup(lr, steps, steps / 10 + 1);
        ft.position = position;
        ft.dora = r.method.contains("dora");
        ft.log_every = if self.verbose { 25 } else { 0 };
        ft.train(
            &r.params,
            &mut r.qparams,
            r.eval_bits,
            DEFAULT_SCALE,
            data,
            steps,
            self.seed ^ 0xF17E,
        )
    }
}
