//! Training drivers: full-precision pretraining (creates the "pretrained
//! LLM" substrate) and QLoRA-style adapter finetuning on the frozen
//! quantized base — both one-PJRT-execute-per-step through the AOT
//! artifacts, with optimizer state threaded through the step signature.

pub mod schedule;

pub use schedule::{LrSchedule, ScheduleKind};

use crate::data::{Batch, Batcher, Task, ZipfMarkovCorpus};
use crate::error::Result;
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::{Bindings, Runtime};
use crate::tensor::Rng;

/// Where adapter LR multipliers go (Table 1 positions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoraPosition {
    All,
    FfnOnly,
    AttnOnly,
}

impl LoraPosition {
    pub fn muls(&self) -> (f32, f32) {
        match self {
            LoraPosition::All => (1.0, 1.0),
            LoraPosition::FfnOnly => (0.0, 1.0),
            LoraPosition::AttnOnly => (1.0, 0.0),
        }
    }

    pub fn parse(s: &str) -> Self {
        match s {
            "ffn" => LoraPosition::FfnOnly,
            "attn" => LoraPosition::AttnOnly,
            _ => LoraPosition::All,
        }
    }
}

/// Shared training report (loss curve + wall time).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub wall_secs: f64,
    pub steps: usize,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean loss over the last k steps (smoother than the final step).
    pub fn tail_mean(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let n = self.losses.len();
        let tail = &self.losses[n.saturating_sub(k)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

/// Full-precision pretraining on the synthetic corpus.
pub struct Pretrainer<'r> {
    pub runtime: &'r Runtime,
    pub cfg: ModelConfig,
    pub schedule: LrSchedule,
    pub wd: f32,
    pub log_every: usize,
}

impl<'r> Pretrainer<'r> {
    pub fn new(runtime: &'r Runtime, cfg: ModelConfig, steps: usize) -> Self {
        Pretrainer {
            runtime,
            cfg,
            schedule: LrSchedule::cosine(3e-3, steps, steps / 20 + 1),
            wd: 0.01,
            log_every: 20,
        }
    }

    /// Train `params` in place for `steps` steps; returns the loss curve.
    pub fn train(
        &self,
        params: &mut ParamStore,
        corpus: &ZipfMarkovCorpus,
        steps: usize,
        seed: u64,
    ) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let name = format!("pretrain_step_{}", self.cfg.name);
        let batcher = Batcher::new(self.cfg.batch, self.cfg.seq_len);
        let mut rng = Rng::new(seed);
        let mut m = params.zeros_like();
        let mut v = params.zeros_like();
        let mut report = TrainReport::default();
        for step in 1..=steps {
            let batch = batcher.lm_batch(corpus, &mut rng);
            let lr = self.schedule.lr_at(step);
            let bind = Bindings::new()
                .group("params", params)
                .group("m", &m)
                .group("v", &v)
                .int("tokens", &batch.tokens)
                .tensor("mask", &batch.mask)
                .scalar("t", step as f32)
                .scalar("lr", lr)
                .scalar("wd", self.wd);
            let out = self.runtime.run(&name, &bind)?;
            *params = out.group("params");
            m = out.group("m");
            v = out.group("v");
            let loss = out.scalar("loss")?;
            report.losses.push(loss);
            if self.log_every > 0 && step % self.log_every == 0 {
                eprintln!("[pretrain {}] step {step}/{steps} lr {lr:.2e} loss {loss:.4}", self.cfg.name);
            }
        }
        report.steps = steps;
        report.wall_secs = t0.elapsed().as_secs_f64();
        Ok(report)
    }
}

/// What the finetuner trains on.
pub enum FinetuneData<'a> {
    /// Language modeling on the corpus (Table 6 WikiText analogue).
    Corpus(&'a ZipfMarkovCorpus),
    /// A single task (Table 6 GSM8K analogue / Table 5 GLUE analogue).
    Task(&'a dyn Task),
    /// A uniform mixture of tasks (Tables 7/8 multi-task setting).
    Mixture(Vec<&'a dyn Task>),
}

/// Adapter finetuning on the frozen quantized base.
pub struct Finetuner<'r> {
    pub runtime: &'r Runtime,
    pub cfg: ModelConfig,
    pub rank: usize,
    pub group: usize,
    pub dora: bool,
    pub schedule: LrSchedule,
    pub wd: f32,
    pub position: LoraPosition,
    pub log_every: usize,
}

impl<'r> Finetuner<'r> {
    pub fn new(runtime: &'r Runtime, cfg: ModelConfig, rank: usize, group: usize, steps: usize) -> Self {
        Finetuner {
            runtime,
            cfg,
            rank,
            group,
            dora: false,
            schedule: LrSchedule::linear_warmup(1e-3, steps, steps / 10 + 1),
            wd: 0.0,
            position: LoraPosition::All,
            log_every: 20,
        }
    }

    fn artifact(&self) -> String {
        let suffix = if self.dora { "_dora" } else { "" };
        format!(
            "finetune_step_{}_r{}_g{}{}",
            self.cfg.name, self.rank, self.group, suffix
        )
    }

    fn next_batch(&self, data: &FinetuneData, batcher: &Batcher, rng: &mut Rng) -> Batch {
        match data {
            FinetuneData::Corpus(c) => batcher.lm_batch(c, rng),
            FinetuneData::Task(t) => batcher.task_batch(*t, rng),
            FinetuneData::Mixture(ts) => {
                let i = rng.below(ts.len());
                batcher.task_batch(ts[i], rng)
            }
        }
    }

    /// Finetune adapters in `qparams` (in place); base `params` frozen.
    /// `bits` is the eval_bits of the quantizer result.
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        params: &ParamStore,
        qparams: &mut ParamStore,
        bits: f32,
        scale: f32,
        data: &FinetuneData,
        steps: usize,
        seed: u64,
    ) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let name = self.artifact();
        let batcher = Batcher::new(self.cfg.batch, self.cfg.seq_len);
        let mut rng = Rng::new(seed);
        let trainable = |k: &str| {
            let leaf = k.rsplit('.').next().unwrap_or("");
            matches!(leaf, "lora_a" | "lora_b") || (self.dora && leaf == "mag")
        };
        let mut m = qparams.filtered(trainable).zeros_like();
        let mut v = m.clone();
        let (mul_attn, mul_ffn) = self.position.muls();
        let mut report = TrainReport::default();
        for step in 1..=steps {
            let batch = self.next_batch(data, &batcher, &mut rng);
            let lr = self.schedule.lr_at(step);
            let bind = Bindings::new()
                .group("params", params)
                .group("qparams", qparams)
                .group("m", &m)
                .group("v", &v)
                .int("tokens", &batch.tokens)
                .tensor("mask", &batch.mask)
                .scalar("t", step as f32)
                .scalar("lr", lr)
                .scalar("wd", self.wd)
                .scalar("bits", bits)
                .scalar("scale", scale)
                .scalar("lr_attn_mul", mul_attn)
                .scalar("lr_ffn_mul", mul_ffn);
            let out = self.runtime.run(&name, &bind)?;
            *qparams = out.group("qparams");
            m = out.group("m");
            v = out.group("v");
            let loss = out.scalar("loss")?;
            report.losses.push(loss);
            if self.log_every > 0 && step % self.log_every == 0 {
                eprintln!("[finetune {}] step {step}/{steps} loss {loss:.4}", self.cfg.name);
            }
        }
        report.steps = steps;
        report.wall_secs = t0.elapsed().as_secs_f64();
        Ok(report)
    }
}
