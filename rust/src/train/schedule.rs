//! Learning-rate schedules (cosine / linear with warmup — the paper's
//! Table A.4 finetuning recipes).

/// Schedule shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    Constant,
    Cosine,
    Linear,
}

/// LR schedule with linear warmup then decay to ~0.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub kind: ScheduleKind,
    pub peak: f32,
    pub total_steps: usize,
    pub warmup_steps: usize,
}

impl LrSchedule {
    pub fn constant(peak: f32) -> Self {
        LrSchedule { kind: ScheduleKind::Constant, peak, total_steps: 1, warmup_steps: 0 }
    }

    pub fn cosine(peak: f32, total: usize, warmup: usize) -> Self {
        LrSchedule { kind: ScheduleKind::Cosine, peak, total_steps: total.max(1), warmup_steps: warmup }
    }

    pub fn linear_warmup(peak: f32, total: usize, warmup: usize) -> Self {
        LrSchedule { kind: ScheduleKind::Linear, peak, total_steps: total.max(1), warmup_steps: warmup }
    }

    /// LR at 1-based step.
    pub fn lr_at(&self, step: usize) -> f32 {
        if self.kind == ScheduleKind::Constant {
            return self.peak;
        }
        if self.warmup_steps > 0 && step <= self.warmup_steps {
            return self.peak * step as f32 / self.warmup_steps as f32;
        }
        let after = (step - self.warmup_steps) as f32;
        let span = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let frac = (after / span).clamp(0.0, 1.0);
        match self.kind {
            ScheduleKind::Cosine => self.peak * 0.5 * (1.0 + (std::f32::consts::PI * frac).cos()),
            ScheduleKind::Linear => self.peak * (1.0 - frac),
            ScheduleKind::Constant => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::cosine(1.0, 100, 10);
        assert!(s.lr_at(1) < s.lr_at(5));
        assert!((s.lr_at(10) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = LrSchedule::cosine(1.0, 100, 0);
        assert!(s.lr_at(100) < 1e-3);
        assert!(s.lr_at(50) > 0.3 && s.lr_at(50) < 0.7);
    }

    #[test]
    fn linear_decays_monotonically() {
        let s = LrSchedule::linear_warmup(1.0, 100, 10);
        let mut last = f32::INFINITY;
        for step in 10..=100 {
            let lr = s.lr_at(step);
            assert!(lr <= last + 1e-9);
            last = lr;
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.5);
        assert_eq!(s.lr_at(1), 0.5);
        assert_eq!(s.lr_at(1000), 0.5);
    }
}
