//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `subcommand --flag value --bool-flag` with typed accessors and
//! an auto-generated usage string.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: positional subcommand + `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Every `--key value` occurrence in order — `flags` keeps only the
    /// last one per key, this keeps them all for repeatable flags.
    occurrences: Vec<(String, String)>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(name) = item.strip_prefix("--") {
                // --key=value or --key value or --bool-flag
                if let Some((k, v)) = name.split_once('=') {
                    out.occurrences.push((k.to_string(), v.to_string()));
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.occurrences.push((name.to_string(), v.clone()));
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.bools.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = item;
            } else {
                out.positionals.push(item);
            }
        }
        Ok(out)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// All values of a repeatable flag, in command-line order
    /// (`--adapter a=1 --adapter b=2` -> `["a=1", "b=2"]`).
    pub fn all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn u32_or(&self, key: &str, default: u32) -> Result<u32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| Error::config(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect(),
        }
    }

    pub fn u32_list_or(&self, key: &str, default: &[u32]) -> Result<Vec<u32>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().map_err(|e| Error::config(format!("--{key}: {e}"))))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("quantize --size small --bits 2 --verbose");
        assert_eq!(a.command, "quantize");
        assert_eq!(a.str_or("size", "x"), "small");
        assert_eq!(a.u32_or("bits", 0).unwrap(), 2);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("eval --bits=3 --lr=1e-3");
        assert_eq!(a.u32_or("bits", 0).unwrap(), 3);
        assert!((a.f32_or("lr", 0.0).unwrap() - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn lists() {
        let a = parse("x --bits 2,3,4 --methods apiq-bw,loftq");
        assert_eq!(a.u32_list_or("bits", &[]).unwrap(), vec![2, 3, 4]);
        assert_eq!(a.list_or("methods", &[]), vec!["apiq-bw", "loftq"]);
    }

    #[test]
    fn repeatable_flags_keep_every_occurrence() {
        let a = parse("serve --adapter a=one.apq --adapter=b=two.apq --addr :0");
        assert_eq!(a.all("adapter"), vec!["a=one.apq", "b=two.apq"]);
        // last occurrence wins for the scalar accessors
        assert_eq!(a.get("adapter"), Some("b=two.apq"));
        assert!(a.all("missing").is_empty());
    }

    #[test]
    fn positionals() {
        let a = parse("report memory");
        assert_eq!(a.command, "report");
        assert_eq!(a.positionals, vec!["memory"]);
    }

    #[test]
    fn bad_typed_flag_errors() {
        let a = parse("x --bits lots");
        assert!(a.u32_or("bits", 0).is_err());
    }
}
