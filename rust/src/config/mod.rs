//! Configuration: a dependency-free key=value config format with
//! sections, typed accessors, and CLI `-o key=value` overrides.
//!
//! Format (TOML-lite):
//!
//! ```text
//! # comment
//! [experiment]
//! size = small
//! bits = 2,3,4
//! methods = qlora,loftq,apiq-bw
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed config: "section.key" -> raw string value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[') {
                let sec = sec
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unterminated section", ln + 1)))?;
                section = sec.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::config(format!("line {}: expected key = value", ln + 1)))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, v.trim().to_string());
        }
        Ok(Config { map })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::io(format!("{}: {e}", path.as_ref().display())))?;
        Self::parse(&text)
    }

    /// Apply a CLI override "section.key=value".
    pub fn set_override(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| Error::config(format!("override '{kv}' is not key=value")))?;
        self.map.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("{key}={v}: {e}"))),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("{key}={v}: {e}"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::config(format!("{key}={v}: {e}"))),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(Error::config(format!("{key}={v}: not a bool"))),
        }
    }

    /// Comma-separated list accessor.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect(),
        }
    }

    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().map_err(|e| Error::config(format!("{key}: {e}"))))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# experiment config
[experiment]
size = small
bits = 2,3,4
steps = 200
lr = 3e-4
verbose = true
";

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("experiment.size", "x"), "small");
        assert_eq!(c.usize_or("experiment.steps", 0).unwrap(), 200);
        assert!((c.f32_or("experiment.lr", 0.0).unwrap() - 3e-4).abs() < 1e-9);
        assert!(c.bool_or("experiment.verbose", false).unwrap());
        assert_eq!(
            c.usize_list_or("experiment.bits", &[]).unwrap(),
            vec![2, 3, 4]
        );
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 7).unwrap(), 7);
        assert_eq!(c.list_or("nope", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_override("experiment.size=tiny").unwrap();
        assert_eq!(c.str_or("experiment.size", "x"), "tiny");
        assert!(c.set_override("no-equals-sign").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("keyonly\n").is_err());
    }
}
pub mod args;
