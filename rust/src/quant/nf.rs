//! NormalFloat (NF) codebook quantization — the QLoRA baseline's format.
//!
//! QLoRA (Dettmers et al., 2023) quantizes to the quantiles of a standard
//! normal ("NF4"); the paper's footnote 2 notes LoftQ/QLoRA use NF while
//! ApiQ uses uniform affine.  We implement the NF codebook for b in
//! {2,3,4} so the QLoRA baseline is faithful: per group, weights are
//! scaled by absmax and snapped to the nearest codebook entry.

use crate::error::Result;
use crate::tensor::Tensor;

/// Inverse CDF of the standard normal (Acklam's rational approximation;
/// |rel err| < 1.15e-9 — far below f32 resolution).
fn norm_ppf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let pl = 0.02425;
    if p < pl {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - pl {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

/// NF codebook with 2^bits entries in [-1, 1], built from evenly spaced
/// normal quantiles with guaranteed 0 and +/-1 entries (QLoRA's recipe).
pub fn nf_codebook(bits: u32) -> Vec<f32> {
    let n = 1usize << bits;
    // half the entries negative, half non-negative, always include 0 and ±1
    let neg = n / 2;
    let pos = n - neg; // includes 0
    let mut code = Vec::with_capacity(n);
    // negative side: quantiles in [off, 0.5) -> values strictly below 0
    let off_n = 0.5 / (2.0 * neg as f64);
    let d_neg = norm_ppf(off_n).abs();
    for i in 0..neg {
        let p = off_n + (i as f64) * (0.5 - off_n) / neg as f64;
        code.push((norm_ppf(p) / d_neg) as f32);
    }
    // positive side: quantiles in [0.5, 1 - off] -> 0 and positives
    let off_p = 0.5 / (2.0 * pos as f64);
    let d_pos = norm_ppf(1.0 - off_p).abs();
    for i in 0..pos {
        let p = 0.5 + (i as f64) * (0.5 - off_p) / (pos as f64 - 1.0).max(1.0);
        code.push((norm_ppf(p) / d_pos) as f32);
    }
    code.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // force exact endpoints / zero
    code[0] = -1.0;
    let last = code.len() - 1;
    code[last] = 1.0;
    // snap the closest-to-zero entry to exactly zero
    let zi = code
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    code[zi] = 0.0;
    code
}

/// Group-wise NF fake quantization (absmax scaling per group), grouping
/// along the input dimension as in the affine quantizer.
pub fn nf_fakequant(w: &Tensor, bits: u32, group: usize) -> Result<Tensor> {
    let (d_in, d_out) = (w.rows(), w.cols());
    let code = nf_codebook(bits);
    let mut out = Tensor::zeros(&[d_in, d_out]);
    let n_groups = d_in / group;
    for gi in 0..n_groups {
        for c in 0..d_out {
            let mut absmax = 0.0f32;
            for r in 0..group {
                absmax = absmax.max(w.at2(gi * group + r, c).abs());
            }
            let absmax = absmax.max(1e-12);
            for r in 0..group {
                let v = w.at2(gi * group + r, c) / absmax;
                // nearest codebook entry (codebook is sorted, tiny: scan)
                let mut best = code[0];
                let mut bd = (v - code[0]).abs();
                for &cd in &code[1..] {
                    let d = (v - cd).abs();
                    if d < bd {
                        bd = d;
                        best = cd;
                    }
                }
                out.set2(gi * group + r, c, best * absmax);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn codebook_properties() {
        for bits in [2u32, 3, 4] {
            let c = nf_codebook(bits);
            assert_eq!(c.len(), 1 << bits);
            assert_eq!(c[0], -1.0);
            assert_eq!(*c.last().unwrap(), 1.0);
            assert!(c.contains(&0.0));
            for w in c.windows(2) {
                assert!(w[1] > w[0]);
            }
        }
    }

    #[test]
    fn nf_output_on_codebook() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[64, 4], 0.3, &mut rng);
        let q = nf_fakequant(&w, 4, 64).unwrap();
        // every column value / absmax must be a codebook entry
        let code = nf_codebook(4);
        for c in 0..4 {
            let mut absmax = 0.0f32;
            for r in 0..64 {
                absmax = absmax.max(w.at2(r, c).abs());
            }
            for r in 0..64 {
                let v = q.at2(r, c) / absmax;
                assert!(
                    code.iter().any(|&cd| (cd - v).abs() < 1e-5),
                    "value {v} not on codebook"
                );
            }
        }
    }

    #[test]
    fn nf_beats_nothing_and_more_bits_help() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[256, 16], 0.3, &mut rng);
        let e2 = nf_fakequant(&w, 2, 64).unwrap().sub(&w).unwrap().fro_norm();
        let e4 = nf_fakequant(&w, 4, 64).unwrap().sub(&w).unwrap().fro_norm();
        assert!(e4 < e2);
    }

    #[test]
    fn nf_on_gaussian_beats_uniform_affine() {
        // NF is quantile-matched to the normal distribution: on gaussian
        // weights it should beat uniform affine at 4 bits (QLoRA's claim).
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[512, 8], 0.25, &mut rng);
        let e_nf = nf_fakequant(&w, 4, 64).unwrap().sub(&w).unwrap().fro_norm();
        let (g, b) = crate::quant::affine::open_clip(512, 8, 64);
        let e_aff = crate::quant::affine::fakequant(&w, &g, &b, crate::quant::QuantSpec::new(4, 64))
            .unwrap()
            .sub(&w)
            .unwrap()
            .fro_norm();
        assert!(e_nf < e_aff, "nf {e_nf} vs affine {e_aff}");
    }
}
