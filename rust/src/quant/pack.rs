//! Sub-byte bit-packing for quantized weight storage.
//!
//! The deployed format of a quantized linear layer: integer codes packed
//! little-endian into a byte stream (2-bit: 4 codes/byte, 3-bit: 8 codes
//! in 3 bytes, 4-bit: 2 codes/byte), plus per-group f32 scales and u8
//! zero-points.  This is what "2-bit model on disk / in GPU memory" means
//! in the paper's memory accounting (Fig. 2, Table 4) — the memory model
//! in `metrics::memory` prices exactly this struct.
//!
//! `PackedLinear::matmul_fused` / `matvec_fused` are the serving hot
//! path: they accumulate `x · s(q − z)` straight from the packed codes
//! through the runtime-dispatched SIMD kernels in `kernels::dequant`,
//! never materializing the dense f32 weight (the dequantize-on-the-fly
//! GEMM of FineQuant-style weight-only inference).  `matvec_fused` is
//! the decode specialization for `n_tok <= 4`.

use crate::error::{Error, Result};
use crate::kernels::dequant::{fused_gemv, fused_matmul, PackedView};
use crate::kernels::pool::{self, ThreadPool};
use crate::kernels::Kernel;
use crate::quant::affine::{dequantize, QuantSpec};
use crate::tensor::Tensor;

/// Pack `codes` (each < 2^bits) into a little-endian bit stream.
pub fn pack_codes(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let c = c & ((1u32 << bits) - 1);
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= (c << off) as u8;
        if off + bits as usize > 8 {
            out[byte + 1] |= (c >> (8 - off)) as u8;
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(packed: &[u8], bits: u32, n: usize) -> Vec<u32> {
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (packed[byte] as u32) >> off;
        if off + bits as usize > 8 {
            v |= (packed[byte + 1] as u32) << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    out
}

/// A quantized linear layer in storage form.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub spec: QuantSpec,
    /// Bit-packed codes, row-major (d_in, d_out).
    pub packed: Vec<u8>,
    /// Per-group scales (d_in/group, d_out).
    pub scales: Tensor,
    /// Per-group zero-points, row-major (d_in/group, d_out), stored as
    /// real u8 levels — exactly the byte the paper's Fig. 2 / Table 4
    /// accounting prices (they used to sit in an f32 Tensor, making the
    /// struct 4x heavier than `storage_bytes()` claimed).
    pub zeros: Vec<u8>,
}

impl PackedLinear {
    /// Build from integer codes + per-group metadata.  `zeros` arrives as
    /// the f32-level tensor `quantize_ints` produces (values are integers
    /// in [0, 2^bits - 1], bits <= 8) and is narrowed to u8 storage.
    pub fn from_codes(
        codes: &[u32],
        scales: Tensor,
        zeros: Tensor,
        d_in: usize,
        d_out: usize,
        spec: QuantSpec,
    ) -> Result<Self> {
        if codes.len() != d_in * d_out {
            return Err(Error::shape("PackedLinear: code count mismatch"));
        }
        if !(1..=8).contains(&spec.bits) {
            return Err(Error::shape(format!(
                "PackedLinear: {} bits not packable (supported: 1..=8); \
                 serve wider weights densely",
                spec.bits
            )));
        }
        if spec.group == 0 || d_in % spec.group != 0 {
            return Err(Error::shape(format!(
                "PackedLinear: d_in {d_in} not divisible by group {}",
                spec.group
            )));
        }
        let n_groups = d_in / spec.group;
        if scales.shape() != [n_groups, d_out] || zeros.shape() != [n_groups, d_out] {
            return Err(Error::shape(format!(
                "PackedLinear: scales/zeros shape {:?}/{:?}, want [{n_groups}, {d_out}]",
                scales.shape(),
                zeros.shape()
            )));
        }
        let zeros_u8 = zeros
            .data()
            .iter()
            .map(|&z| z.clamp(0.0, 255.0) as u8)
            .collect();
        Ok(PackedLinear {
            d_in,
            d_out,
            spec,
            packed: pack_codes(codes, spec.bits),
            scales,
            zeros: zeros_u8,
        })
    }

    /// Zero-points widened back to the f32 tensor layout (d_in/group, d_out).
    pub fn zeros_f32(&self) -> Tensor {
        let n_groups = self.d_in / self.spec.group;
        let data = self.zeros.iter().map(|&z| z as f32).collect();
        Tensor::new(vec![n_groups, self.d_out], data)
            .expect("zeros length is n_groups * d_out by construction")
    }

    /// Dequantize back to a dense f32 weight.
    pub fn dequantize(&self) -> Result<Tensor> {
        let codes = unpack_codes(&self.packed, self.spec.bits, self.d_in * self.d_out);
        dequantize(
            &codes,
            &self.scales,
            &self.zeros_f32(),
            self.d_in,
            self.d_out,
            self.spec.group,
        )
    }

    /// Borrowed raw-parts view of the payload for the compute kernels.
    pub fn view(&self) -> PackedView<'_> {
        PackedView {
            packed: &self.packed,
            scales: self.scales.data(),
            zeros: &self.zeros,
            d_in: self.d_in,
            d_out: self.d_out,
            group: self.spec.group,
            bits: self.spec.bits as usize,
        }
    }

    fn check_x(&self, x: &Tensor, what: &str) -> Result<()> {
        if x.rank() != 2 || x.cols() != self.d_in {
            return Err(Error::shape(format!(
                "{what}: x {:?} vs packed ({}, {})",
                x.shape(),
                self.d_in,
                self.d_out
            )));
        }
        Ok(())
    }

    /// Fused dequantize-on-the-fly matmul: y = x @ (s · (q − z)) for
    /// x (n_tok, d_in) -> (n_tok, d_out), without ever materializing the
    /// dense weight.  Runs the runtime-dispatched kernels in
    /// `kernels::dequant` on the persistent worker pool — workers write
    /// straight into disjoint column panels of the output (the per-call
    /// `thread::scope` spawn and the per-panel `Vec` copy-back of PR 1
    /// are both gone).  Every output element accumulates in ascending-k
    /// order, so results agree bit-for-bit with the scalar oracle and
    /// with `x.matmul(&self.dequantize()?)`'s reduction order.
    pub fn matmul_fused(&self, x: &Tensor) -> Result<Tensor> {
        self.matmul_fused_with(crate::kernels::active(), pool::global(), x)
    }

    /// [`Self::matmul_fused`] with explicit kernel + pool (what the
    /// determinism tests drive at 1/2/N threads and scalar-vs-SIMD).
    pub fn matmul_fused_with(
        &self,
        kernel: Kernel,
        pool: &ThreadPool,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.check_x(x, "matmul_fused")?;
        let n_tok = x.rows();
        let mut out = vec![0.0f32; n_tok * self.d_out];
        let prof = crate::obs::profile::timer();
        fused_matmul(kernel, pool, &self.view(), x.data(), n_tok, &mut out);
        if let Some(t0) = prof {
            crate::obs::profile::record(
                crate::obs::profile::KernelKind::FusedPanel,
                t0.elapsed().as_nanos() as u64,
                2 * (n_tok * self.d_in * self.d_out) as u64,
            );
        }
        Tensor::new(vec![n_tok, self.d_out], out)
    }

    /// Decode-specialized fused GEMV for `n_tok <= 4` (the batch-1
    /// `forward_step` hot path): column-major tile traversal of the
    /// packed payload, dequantizing each code straight into the
    /// accumulate with no group-scratch roundtrip.  Bitwise-identical
    /// output to [`Self::matmul_fused`]; wider inputs fall back to the
    /// panel path.
    pub fn matvec_fused(&self, x: &Tensor) -> Result<Tensor> {
        self.matvec_fused_with(crate::kernels::active(), pool::global(), x)
    }

    /// [`Self::matvec_fused`] with explicit kernel + pool.
    pub fn matvec_fused_with(
        &self,
        kernel: Kernel,
        pool: &ThreadPool,
        x: &Tensor,
    ) -> Result<Tensor> {
        self.check_x(x, "matvec_fused")?;
        let n_tok = x.rows();
        let mut out = vec![0.0f32; n_tok * self.d_out];
        let prof = crate::obs::profile::timer();
        fused_gemv(kernel, pool, &self.view(), x.data(), n_tok, &mut out);
        if let Some(t0) = prof {
            crate::obs::profile::record(
                crate::obs::profile::KernelKind::MatvecFused,
                t0.elapsed().as_nanos() as u64,
                2 * (n_tok * self.d_in * self.d_out) as u64,
            );
        }
        Tensor::new(vec![n_tok, self.d_out], out)
    }

    /// Largest row count [`Self::matvec_fused`] specializes for.
    pub const MATVEC_MAX_ROWS: usize = crate::kernels::dequant::MATVEC_MAX_ROWS;

    /// Bytes on disk/GPU for the quantized payload (codes + metadata),
    /// the quantity the paper's Fig. 2 / Table 4 account in GB.  Now an
    /// exact description of this struct: packed codes + f32 scales + u8
    /// zero-points.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4 + self.zeros.len()
    }

    /// Effective bits per weight including group metadata — the paper's
    /// "average bit-width per parameter" caveat (§5.1).
    pub fn effective_bits(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.d_in * self.d_out) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::affine::{open_clip, quantize_ints};
    use crate::tensor::Rng;

    #[test]
    fn pack_roundtrip_all_bits() {
        for bits in [2u32, 3, 4, 8] {
            let n = 1000;
            let mask = (1u32 << bits) - 1;
            let mut rng = Rng::new(bits as u64);
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & mask).collect();
            let packed = pack_codes(&codes, bits);
            let back = unpack_codes(&packed, bits, n);
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn packed_size_is_tight() {
        let codes = vec![1u32; 400];
        assert_eq!(pack_codes(&codes, 2).len(), 100);
        assert_eq!(pack_codes(&codes, 3).len(), 150);
        assert_eq!(pack_codes(&codes, 4).len(), 200);
    }

    #[test]
    fn packed_linear_roundtrip_matches_fakequant() {
        let mut rng = Rng::new(7);
        let spec = QuantSpec::new(2, 64);
        let w = Tensor::randn(&[128, 32], 0.2, &mut rng);
        let (g, b) = open_clip(128, 32, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let direct = crate::quant::affine::dequantize(&codes, &s, &z, 128, 32, 64).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, 128, 32, spec).unwrap();
        let via_pack = pl.dequantize().unwrap();
        assert_eq!(direct, via_pack);
    }

    #[test]
    fn zeros_stored_as_bytes() {
        let mut rng = Rng::new(9);
        let spec = QuantSpec::new(3, 64);
        let w = Tensor::randn(&[64, 8], 0.2, &mut rng);
        let (g, b) = open_clip(64, 8, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z.clone(), 64, 8, spec).unwrap();
        assert_eq!(pl.zeros.len(), 8);
        // the u8 narrowing is lossless for integral zero-points
        for (zu, zf) in pl.zeros.iter().zip(z.data()) {
            assert_eq!(*zu as f32, *zf);
        }
        // storage prices exactly what the struct holds
        assert_eq!(
            pl.storage_bytes(),
            pl.packed.len() + pl.scales.len() * 4 + pl.zeros.len()
        );
    }

    #[test]
    fn matmul_fused_matches_dequant_dense() {
        let mut rng = Rng::new(11);
        for bits in [2u32, 3, 4] {
            let spec = QuantSpec::new(bits, 64);
            let (d_in, d_out) = (128, 48);
            let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
            let (g, b) = open_clip(d_in, d_out, 64);
            let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
            let pl = PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec).unwrap();
            let x = Tensor::randn(&[5, d_in], 1.0, &mut rng);
            let fused = pl.matmul_fused(&x).unwrap();
            let dense = x.matmul(&pl.dequantize().unwrap()).unwrap();
            let rel = fused.sub(&dense).unwrap().fro_norm() / dense.fro_norm().max(1e-12);
            assert!(rel <= 1e-5, "bits={bits}: rel err {rel}");
        }
    }

    #[test]
    fn matvec_fused_bitwise_matches_matmul_fused() {
        let mut rng = Rng::new(23);
        for bits in [2u32, 3, 4] {
            let spec = QuantSpec::new(bits, 32);
            // d_out deliberately not a multiple of the 64-col tile
            let (d_in, d_out) = (96, 83);
            let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
            let (g, b) = open_clip(d_in, d_out, 32);
            let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
            let pl = PackedLinear::from_codes(&codes, s, z, d_in, d_out, spec).unwrap();
            for n_tok in 1..=PackedLinear::MATVEC_MAX_ROWS {
                let x = Tensor::randn(&[n_tok, d_in], 1.0, &mut rng);
                let gemv = pl.matvec_fused(&x).unwrap();
                let panel = pl.matmul_fused(&x).unwrap();
                assert_eq!(
                    gemv.data(),
                    panel.data(),
                    "bits={bits} n_tok={n_tok}: GEMV and panel paths must agree bitwise"
                );
            }
            // wider inputs fall back to the panel path
            let x = Tensor::randn(&[PackedLinear::MATVEC_MAX_ROWS + 2, d_in], 1.0, &mut rng);
            let wide = pl.matvec_fused(&x).unwrap();
            assert_eq!(wide.data(), pl.matmul_fused(&x).unwrap().data());
        }
    }

    #[test]
    fn matmul_fused_rejects_bad_shapes() {
        let mut rng = Rng::new(12);
        let spec = QuantSpec::new(2, 64);
        let w = Tensor::randn(&[64, 8], 0.2, &mut rng);
        let (g, b) = open_clip(64, 8, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, 64, 8, spec).unwrap();
        assert!(pl.matmul_fused(&Tensor::zeros(&[3, 32])).is_err());
    }

    #[test]
    fn effective_bits_close_to_nominal() {
        let mut rng = Rng::new(8);
        let spec = QuantSpec::new(2, 64);
        let w = Tensor::randn(&[256, 256], 0.2, &mut rng);
        let (g, b) = open_clip(256, 256, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, 256, 256, spec).unwrap();
        let eb = pl.effective_bits();
        // 2-bit codes + (4 + 1 bytes per 64 weights) metadata = 2.625 exactly
        assert!((eb - 2.625).abs() < 1e-9, "effective bits {eb}");
    }
}
