//! Sub-byte bit-packing for quantized weight storage.
//!
//! The deployed format of a quantized linear layer: integer codes packed
//! little-endian into a byte stream (2-bit: 4 codes/byte, 3-bit: 8 codes
//! in 3 bytes, 4-bit: 2 codes/byte), plus per-group f32 scales and u8
//! zero-points.  This is what "2-bit model on disk / in GPU memory" means
//! in the paper's memory accounting (Fig. 2, Table 4) — the memory model
//! in `metrics::memory` prices exactly this struct.

use crate::error::{Error, Result};
use crate::quant::affine::{dequantize, QuantSpec};
use crate::tensor::Tensor;

/// Pack `codes` (each < 2^bits) into a little-endian bit stream.
pub fn pack_codes(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let c = c & ((1u32 << bits) - 1);
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= (c << off) as u8;
        if off + bits as usize > 8 {
            out[byte + 1] |= (c >> (8 - off)) as u8;
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(packed: &[u8], bits: u32, n: usize) -> Vec<u32> {
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = (packed[byte] as u32) >> off;
        if off + bits as usize > 8 {
            v |= (packed[byte + 1] as u32) << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    out
}

/// A quantized linear layer in storage form.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub spec: QuantSpec,
    /// Bit-packed codes, row-major (d_in, d_out).
    pub packed: Vec<u8>,
    /// Per-group scales (d_in/group, d_out).
    pub scales: Tensor,
    /// Per-group zero-points (d_in/group, d_out), stored as f32 levels.
    pub zeros: Tensor,
}

impl PackedLinear {
    pub fn from_codes(
        codes: &[u32],
        scales: Tensor,
        zeros: Tensor,
        d_in: usize,
        d_out: usize,
        spec: QuantSpec,
    ) -> Result<Self> {
        if codes.len() != d_in * d_out {
            return Err(Error::shape("PackedLinear: code count mismatch"));
        }
        Ok(PackedLinear {
            d_in,
            d_out,
            spec,
            packed: pack_codes(codes, spec.bits),
            scales,
            zeros,
        })
    }

    /// Dequantize back to a dense f32 weight.
    pub fn dequantize(&self) -> Result<Tensor> {
        let codes = unpack_codes(&self.packed, self.spec.bits, self.d_in * self.d_out);
        dequantize(
            &codes,
            &self.scales,
            &self.zeros,
            self.d_in,
            self.d_out,
            self.spec.group,
        )
    }

    /// Bytes on disk/GPU for the quantized payload (codes + metadata),
    /// the quantity the paper's Fig. 2 / Table 4 account in GB.
    pub fn storage_bytes(&self) -> usize {
        let meta = self.scales.len() * 4 + self.zeros.len(); // f32 scales, u8 zeros
        self.packed.len() + meta
    }

    /// Effective bits per weight including group metadata — the paper's
    /// "average bit-width per parameter" caveat (§5.1).
    pub fn effective_bits(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.d_in * self.d_out) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::affine::{open_clip, quantize_ints};
    use crate::tensor::Rng;

    #[test]
    fn pack_roundtrip_all_bits() {
        for bits in [2u32, 3, 4, 8] {
            let n = 1000;
            let mask = (1u32 << bits) - 1;
            let mut rng = Rng::new(bits as u64);
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32 & mask).collect();
            let packed = pack_codes(&codes, bits);
            let back = unpack_codes(&packed, bits, n);
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn packed_size_is_tight() {
        let codes = vec![1u32; 400];
        assert_eq!(pack_codes(&codes, 2).len(), 100);
        assert_eq!(pack_codes(&codes, 3).len(), 150);
        assert_eq!(pack_codes(&codes, 4).len(), 200);
    }

    #[test]
    fn packed_linear_roundtrip_matches_fakequant() {
        let mut rng = Rng::new(7);
        let spec = QuantSpec::new(2, 64);
        let w = Tensor::randn(&[128, 32], 0.2, &mut rng);
        let (g, b) = open_clip(128, 32, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let direct = crate::quant::affine::dequantize(&codes, &s, &z, 128, 32, 64).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, 128, 32, spec).unwrap();
        let via_pack = pl.dequantize().unwrap();
        assert_eq!(direct, via_pack);
    }

    #[test]
    fn effective_bits_close_to_nominal() {
        let mut rng = Rng::new(8);
        let spec = QuantSpec::new(2, 64);
        let w = Tensor::randn(&[256, 256], 0.2, &mut rng);
        let (g, b) = open_clip(256, 256, 64);
        let (codes, s, z) = quantize_ints(&w, &g, &b, spec).unwrap();
        let pl = PackedLinear::from_codes(&codes, s, z, 256, 256, spec).unwrap();
        let eb = pl.effective_bits();
        // 2-bit + (4+1 bytes per 64 weights) metadata = 2 + 40/64 = 2.625
        assert!(eb > 2.0 && eb < 2.7, "effective bits {eb}");
    }
}
