//! Host-side uniform affine quantization — bit-compatible with the L1
//! Pallas kernel / jnp reference (`python/compile/kernels/ref.py`).
//!
//! Semantics (paper Eq. 1/3 with §4.3 learnable clipping):
//!
//!   per group g (= `group` consecutive input rows of one output column):
//!     hi = sigmoid(gamma) * max(W_g)     lo = sigmoid(beta) * min(W_g)
//!     s  = max((hi - lo) / (2^b - 1), 1e-8)
//!     z  = clamp(round(-lo / s), 0, 2^b - 1)
//!     q  = clamp(round(w / s) + z, 0, 2^b - 1)        (stored integer)
//!     Q  = s * (q - z)                                 (dequantized)
//!
//! The Rust copy exists because the coordinator must (a) run the RTN /
//! GPTQ / AWQ / LoftQ baselines entirely host-side, and (b) produce the
//! final *packed* integer codes from the calibrated (gamma, beta).  An
//! integration test cross-checks it against the `fakequant_*` HLO
//! artifacts to ~1e-6.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Static description of a quantization configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantSpec {
    /// Bit-width b (2, 3, 4 in the paper; 16 = effectively identity).
    pub bits: u32,
    /// Group size along the input dimension (64 or 128 in the paper).
    pub group: usize,
}

impl QuantSpec {
    pub fn new(bits: u32, group: usize) -> Self {
        QuantSpec { bits, group }
    }

    /// Number of representable levels minus one (2^b - 1).
    pub fn max_level(&self) -> f32 {
        (2u64.pow(self.bits) - 1) as f32
    }

    /// Groups per column for a (d_in, d_out) weight.
    pub fn groups(&self, d_in: usize) -> Result<usize> {
        if d_in % self.group != 0 {
            return Err(Error::shape(format!(
                "d_in {} not divisible by group {}",
                d_in, self.group
            )));
        }
        Ok(d_in / self.group)
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Round-half-to-even, matching XLA/jnp `round` semantics.
///
/// Delegates to the IEEE-754 roundTiesToEven primitive.  The previous
/// hand-rolled version compared `(x - x.trunc()).abs() == 0.5` (an exact
/// float compare that can misclassify ties produced by FP division) and
/// cast `x.floor() as i64` to test evenness (saturating for |x| > 2^63).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Per-group scale/zero-point for `w` (d_in x d_out) under (gamma, beta)
/// clipping logits of shape (d_in/group, d_out).
/// Returns (scales, zeros), both (d_in/group, d_out).
pub fn scales_zeros(
    w: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    spec: QuantSpec,
) -> Result<(Tensor, Tensor)> {
    let (d_in, d_out) = (w.rows(), w.cols());
    let n_groups = spec.groups(d_in)?;
    if gamma.shape() != [n_groups, d_out] || beta.shape() != [n_groups, d_out] {
        return Err(Error::shape(format!(
            "gamma/beta shape {:?}/{:?}, want [{}, {}]",
            gamma.shape(),
            beta.shape(),
            n_groups,
            d_out
        )));
    }
    let m = spec.max_level();
    let mut s = Tensor::zeros(&[n_groups, d_out]);
    let mut z = Tensor::zeros(&[n_groups, d_out]);
    for gi in 0..n_groups {
        for c in 0..d_out {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for r in 0..spec.group {
                let v = w.at2(gi * spec.group + r, c);
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            let hi = sigmoid(gamma.at2(gi, c)) * wmax;
            let lo = sigmoid(beta.at2(gi, c)) * wmin;
            let sc = ((hi - lo) / m).max(1e-8);
            let zp = round_ties_even(-lo / sc).clamp(0.0, m);
            s.set2(gi, c, sc);
            z.set2(gi, c, zp);
        }
    }
    Ok((s, z))
}

/// Integer codes q in [0, 2^b - 1] for `w`. Returns (codes, scales, zeros);
/// codes as u32 (any bit-width up to 16), row-major (d_in, d_out).
pub fn quantize_ints(
    w: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    spec: QuantSpec,
) -> Result<(Vec<u32>, Tensor, Tensor)> {
    let (s, z) = scales_zeros(w, gamma, beta, spec)?;
    let (d_in, d_out) = (w.rows(), w.cols());
    let m = spec.max_level();
    let mut codes = vec![0u32; d_in * d_out];
    for r in 0..d_in {
        let gi = r / spec.group;
        for c in 0..d_out {
            let q = (round_ties_even(w.at2(r, c) / s.at2(gi, c)) + z.at2(gi, c))
                .clamp(0.0, m);
            codes[r * d_out + c] = q as u32;
        }
    }
    Ok((codes, s, z))
}

/// Dequantize integer codes back to f32: Q = s * (q - z).
pub fn dequantize(
    codes: &[u32],
    scales: &Tensor,
    zeros: &Tensor,
    d_in: usize,
    d_out: usize,
    group: usize,
) -> Result<Tensor> {
    if codes.len() != d_in * d_out {
        return Err(Error::shape("dequantize: code count mismatch"));
    }
    let mut out = Tensor::zeros(&[d_in, d_out]);
    for r in 0..d_in {
        let gi = r / group;
        for c in 0..d_out {
            let q = codes[r * d_out + c] as f32;
            out.set2(r, c, scales.at2(gi, c) * (q - zeros.at2(gi, c)));
        }
    }
    Ok(out)
}

/// Quantize-dequantize in one call (the fake-quant used everywhere).
pub fn fakequant(w: &Tensor, gamma: &Tensor, beta: &Tensor, spec: QuantSpec) -> Result<Tensor> {
    let (codes, s, z) = quantize_ints(w, gamma, beta, spec)?;
    dequantize(&codes, &s, &z, w.rows(), w.cols(), spec.group)
}

/// RTN default clipping: gamma = beta = +inf effectively (sigmoid -> 1).
/// The paper's init gamma = beta = 4 (sigma(4) ~ 0.982) is used by the
/// learned quantizers; RTN proper uses the full range.
pub fn open_clip(d_in: usize, d_out: usize, group: usize) -> (Tensor, Tensor) {
    let g = d_in / group;
    (Tensor::full(&[g, d_out], 30.0), Tensor::full(&[g, d_out], 30.0))
}

/// The paper's learnable-clip initialization (gamma = beta = 4).
pub fn paper_init_clip(d_in: usize, d_out: usize, group: usize) -> (Tensor, Tensor) {
    let g = d_in / group;
    (Tensor::full(&[g, d_out], 4.0), Tensor::full(&[g, d_out], 4.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn spec2() -> QuantSpec {
        QuantSpec::new(2, 64)
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[128, 16], 0.1, &mut rng);
        let (g, b) = paper_init_clip(128, 16, 64);
        let (codes, _, _) = quantize_ints(&w, &g, &b, spec2()).unwrap();
        assert!(codes.iter().all(|&c| c <= 3));
    }

    #[test]
    fn roundtrip_is_idempotent() {
        // fakequant(fakequant(w)) == fakequant(w): already-quantized values
        // land exactly on levels.
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[64, 8], 0.2, &mut rng);
        let (g, b) = open_clip(64, 8, 64);
        let q1 = fakequant(&w, &g, &b, spec2()).unwrap();
        let q2 = fakequant(&q1, &g, &b, spec2()).unwrap();
        let err = q1.sub(&q2).unwrap().fro_norm();
        assert!(err < 1e-5, "err {err}");
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[256, 32], 0.3, &mut rng);
        let (g, b) = open_clip(256, 32, 64);
        let mut last = f32::INFINITY;
        for bits in [2u32, 3, 4, 8] {
            let q = fakequant(&w, &g, &b, QuantSpec::new(bits, 64)).unwrap();
            let e = q.sub(&w).unwrap().fro_norm();
            assert!(e < last, "bits {bits}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn tighter_clip_changes_levels() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[64, 4], 1.0, &mut rng);
        let (g_open, b_open) = open_clip(64, 4, 64);
        let g_tight = Tensor::full(&[1, 4], -1.0);
        let b_tight = Tensor::full(&[1, 4], -1.0);
        let q_open = fakequant(&w, &g_open, &b_open, spec2()).unwrap();
        let q_tight = fakequant(&w, &g_tight, &b_tight, spec2()).unwrap();
        assert!(q_open.sub(&q_tight).unwrap().fro_norm() > 1e-3);
        // tight clip shrinks the dynamic range of the dequantized values
        assert!(q_tight.abs_max() < q_open.abs_max());
    }

    #[test]
    fn groupwise_independence() {
        // Scaling one group's weights must not change another group's codes.
        let mut rng = Rng::new(5);
        let mut w = Tensor::randn(&[128, 4], 0.1, &mut rng);
        let (g, b) = open_clip(128, 4, 64);
        let (codes1, _, _) = quantize_ints(&w, &g, &b, spec2()).unwrap();
        for r in 64..128 {
            for c in 0..4 {
                let v = w.at2(r, c) * 10.0;
                w.set2(r, c, v);
            }
        }
        let (codes2, _, _) = quantize_ints(&w, &g, &b, spec2()).unwrap();
        // group 0 codes unchanged
        assert_eq!(&codes1[..64 * 4], &codes2[..64 * 4]);
    }

    #[test]
    fn round_ties_even_edges() {
        // ties pick the even neighbour, both signs
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        // non-ties round to nearest
        assert_eq!(round_ties_even(2.4), 2.0);
        assert_eq!(round_ties_even(-2.6), -3.0);
        // huge magnitudes (already integral in f32) are fixed points;
        // the old `floor() as i64` evenness test saturated past 2^63
        for v in [1e20f32, -1e20, 2f32.powi(63), -(2f32.powi(63)), f32::MAX, f32::MIN] {
            assert_eq!(round_ties_even(v), v, "{v}");
        }
        assert!(round_ties_even(f32::NAN).is_nan());
    }

    #[test]
    fn bits16_near_identity() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[64, 8], 0.2, &mut rng);
        let (g, b) = open_clip(64, 8, 64);
        let q = fakequant(&w, &g, &b, QuantSpec::new(16, 64)).unwrap();
        let rel = q.sub(&w).unwrap().fro_norm() / w.fro_norm();
        assert!(rel < 1e-4, "rel {rel}");
    }
}
