//! Quantization substrate: the paper's uniform affine quantizer (Eq. 1/3)
//! with learnable clipping, sub-byte bit-packing for storage, and the
//! NF-codebook variant used by the QLoRA baseline.

pub mod affine;
pub mod nf;
pub mod pack;

pub use affine::{dequantize, fakequant, quantize_ints, QuantSpec};
pub use nf::nf_fakequant;
pub use pack::{pack_codes, unpack_codes, PackedLinear};
