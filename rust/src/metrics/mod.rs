//! Metrics: the paper's diagnostic quantities (weight error of Fig. 3 /
//! A.1, activation error of Fig. 4, the Q/A/B histograms of Fig. 5, and
//! the GPU-memory accounting of Fig. 2 / Table 4), serving latency
//! percentiles for `repro bench-serve`, plus table emitters.

pub mod histogram;
pub mod latency;
pub mod memory;
pub mod table;

pub use histogram::Histogram;
pub use latency::LatencySummary;
pub use memory::MemoryModel;
pub use table::TableBuilder;

use crate::error::Result;
use crate::model::ParamStore;
use crate::tensor::Tensor;

/// ‖W − (Q + A·Bᵀ·scale)‖_F — the weight error of Fig. 3 / Fig. A.1.
pub fn weight_error(w: &Tensor, q_eff: &Tensor) -> Result<f32> {
    Ok(w.sub(q_eff)?.fro_norm())
}

/// Effective quantized weight Q + scale·A·Bᵀ for one linear layer, given
/// its qparam view (`gamma`,`beta`,`lora_a`,`lora_b`) and a dequantized Q.
pub fn effective_weight(q: &Tensor, qp: &ParamStore, scale: f32) -> Result<Tensor> {
    let a = qp.require("lora_a")?;
    let b = qp.require("lora_b")?;
    let ab = a.matmul(&b.transpose()?)?;
    q.add(&ab.scale(scale))
}

/// Per-token activation error ‖X·W − Y_q‖_F / n_tokens (Fig. 4's metric),
/// where `y` = X·W (fp stream) and `yq` the quantized layer's output.
pub fn activation_error_per_token(y: &Tensor, yq: &Tensor) -> Result<f32> {
    let n_tok = y.shape()[0] as f32;
    Ok(y.sub(yq)?.fro_norm() / n_tok)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn weight_error_zero_for_identical() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        assert_eq!(weight_error(&w, &w).unwrap(), 0.0);
    }

    #[test]
    fn effective_weight_includes_lowrank() {
        let mut rng = Rng::new(2);
        let q = Tensor::zeros(&[4, 4]);
        let mut qp = ParamStore::new();
        let a = Tensor::randn(&[4, 2], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 2], 1.0, &mut rng);
        let expect = a.matmul(&b.transpose().unwrap()).unwrap().scale(2.0);
        qp.insert("lora_a", a);
        qp.insert("lora_b", b);
        let eff = effective_weight(&q, &qp, 2.0).unwrap();
        assert!(eff.sub(&expect).unwrap().fro_norm() < 1e-6);
    }

    #[test]
    fn act_error_normalizes_by_tokens() {
        let y = Tensor::full(&[10, 4], 1.0);
        let yq = Tensor::full(&[10, 4], 0.0);
        let e = activation_error_per_token(&y, &yq).unwrap();
        assert!((e - (40f32).sqrt() / 10.0).abs() < 1e-6);
    }
}
