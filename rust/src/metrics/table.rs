//! Markdown/CSV table emitter — every experiment binary prints its paper
//! table through this, so EXPERIMENTS.md rows are copy-pasteable.

/// Simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct TableBuilder {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: impl Into<String>) -> Self {
        TableBuilder { title: title.into(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    /// Format a float with sensible precision for ppl/acc cells, matching
    /// the paper's style (big perplexities in scientific notation).
    pub fn num(v: f64) -> String {
        if !v.is_finite() {
            "N.A.".into()
        } else if v.abs() >= 1e4 {
            format!("{v:.1e}")
        } else if v.abs() >= 100.0 {
            format!("{v:.1}")
        } else {
            format!("{v:.2}")
        }
    }

    pub fn pct(v: f64) -> String {
        if v.is_finite() {
            format!("{:.1}", v * 100.0)
        } else {
            "N.A.".into()
        }
    }

    /// Render as a GitHub-markdown table with an underlined title.
    pub fn markdown(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |row: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {cell:<w$} |", w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// CSV rendering (for downstream plotting).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = TableBuilder::new("Table X").header(&["method", "ppl"]);
        t.row_strs(&["ApiQ-bw", "7.59"]);
        t.row_strs(&["QLoRA", "1.8e5"]);
        let md = t.markdown();
        assert!(md.contains("### Table X"));
        assert!(md.contains("| ApiQ-bw |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(TableBuilder::num(7.593), "7.59");
        assert_eq!(TableBuilder::num(431.97), "432.0");
        assert_eq!(TableBuilder::num(1.8e5), "1.8e5");
        assert_eq!(TableBuilder::num(f64::NAN), "N.A.");
    }

    #[test]
    fn csv_roundtrip_columns() {
        let mut t = TableBuilder::new("t").header(&["a", "b"]);
        t.row_strs(&["1", "2"]);
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }
}
