//! Latency distributions for the serving benchmarks: nearest-rank
//! percentiles over a batch of observations, with a compact
//! milliseconds formatter the `bench-serve` report prints.

/// Nearest-rank percentile of an ascending-sorted slice (q in [0, 1]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Summary of one latency distribution (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    /// Build from unsorted observations in seconds.
    pub fn from_secs(mut xs: Vec<f64>) -> Self {
        if xs.is_empty() {
            return LatencySummary::default();
        }
        xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        let n = xs.len();
        LatencySummary {
            n,
            mean_s: xs.iter().sum::<f64>() / n as f64,
            p50_s: percentile(&xs, 0.50),
            p90_s: percentile(&xs, 0.90),
            p99_s: percentile(&xs, 0.99),
            max_s: xs[n - 1],
        }
    }

    /// `mean 12.3ms p50 11.0ms p90 20.1ms p99 33.0ms max 35.2ms`
    pub fn fmt_ms(&self) -> String {
        format!(
            "mean {:.1}ms p50 {:.1}ms p90 {:.1}ms p99 {:.1}ms max {:.1}ms",
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p90_s * 1e3,
            self.p99_s * 1e3,
            self.max_s * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.25), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.75), 3.0);
        assert_eq!(percentile(&xs, 0.99), 4.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.5], 0.5), 7.5);
    }

    #[test]
    fn summary_orders_unsorted_input() {
        let s = LatencySummary::from_secs(vec![0.03, 0.01, 0.02]);
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 0.02).abs() < 1e-12);
        assert_eq!(s.p50_s, 0.02);
        assert_eq!(s.max_s, 0.03);
        assert!(s.p99_s <= s.max_s && s.p50_s <= s.p90_s);
        assert!(s.fmt_ms().contains("p90"));
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let s = LatencySummary::from_secs(vec![]);
        assert_eq!(s.n, 0);
        assert_eq!(s.max_s, 0.0);
    }
}
