//! Value histograms (Fig. 5 / A.2–A.5: distributions of Q, A, B).
//!
//! Renders as an ASCII sparkline table so the paper's histogram figures
//! can be regenerated in a terminal / EXPERIMENTS.md.

/// Fixed-range histogram with uniform bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub n: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], n: 0, underflow: 0, overflow: 0 }
    }

    /// Build over data with range = (min, max) of the data.
    pub fn auto(data: &[f32], bins: usize) -> Self {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() || lo == hi {
            lo = -1.0;
            hi = 1.0;
        }
        let mut h = Histogram::new(lo, hi + (hi - lo) * 1e-6, bins);
        h.extend(data);
        h
    }

    pub fn add(&mut self, v: f32) {
        self.n += 1;
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let n_bins = self.counts.len();
            let b = ((v - self.lo) / (self.hi - self.lo) * n_bins as f32) as usize;
            self.counts[b.min(n_bins - 1)] += 1;
        }
    }

    pub fn extend(&mut self, data: &[f32]) {
        for &v in data {
            self.add(v);
        }
    }

    /// Number of bins with any mass (distinct-level detector: a b-bit
    /// quantized weight has <= 2^b populated levels per group scale).
    pub fn populated_bins(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Width of the central interval holding `frac` of the mass
    /// (the Fig. 5 "distribution span" comparison between ApiQ and LoftQ).
    pub fn central_span(&self, frac: f32) -> f32 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (self.n as f32 * frac) as u64;
        let bin_w = (self.hi - self.lo) / self.counts.len() as f32;
        // expand symmetric window around the median bin
        let mut cum = 0u64;
        let mut median_bin = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum * 2 >= self.n {
                median_bin = i;
                break;
            }
        }
        let mut mass = self.counts[median_bin];
        let (mut l, mut r) = (median_bin, median_bin);
        while mass < target && (l > 0 || r + 1 < self.counts.len()) {
            let left_gain = if l > 0 { self.counts[l - 1] } else { 0 };
            let right_gain = if r + 1 < self.counts.len() { self.counts[r + 1] } else { 0 };
            if left_gain >= right_gain && l > 0 {
                l -= 1;
                mass += left_gain;
            } else if r + 1 < self.counts.len() {
                r += 1;
                mass += right_gain;
            } else if l > 0 {
                l -= 1;
                mass += left_gain;
            }
        }
        (r - l + 1) as f32 * bin_w
    }

    /// ASCII rendering (one row per bin, '#' bar scaled to the max bin).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bin_w = (self.hi - self.lo) / self.counts.len() as f32;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let x = self.lo + bin_w * i as f32;
            let bar = (c as f64 / max as f64 * width as f64) as usize;
            out.push_str(&format!("{x:>9.4} | {} {c}\n", "#".repeat(bar)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_bounds() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.extend(&[0.05, 0.15, 0.15, 0.95, -0.5, 2.0]);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.n, 6);
    }

    #[test]
    fn auto_covers_data() {
        let data = vec![-3.0, 0.0, 5.0, 1.0];
        let h = Histogram::auto(&data, 8);
        assert_eq!(h.underflow + h.overflow, 0);
        assert_eq!(h.counts.iter().sum::<u64>(), 4);
    }

    #[test]
    fn populated_bins_detects_discrete_levels() {
        // 2-bit-like data: 4 distinct values
        let mut data = Vec::new();
        for _ in 0..100 {
            data.extend_from_slice(&[-0.3, -0.1, 0.1, 0.3]);
        }
        let h = Histogram::auto(&data, 64);
        assert_eq!(h.populated_bins(), 4);
    }

    #[test]
    fn central_span_narrower_for_concentrated() {
        let narrow: Vec<f32> = (0..1000).map(|i| (i % 10) as f32 * 0.001).collect();
        let wide: Vec<f32> = (0..1000).map(|i| (i % 10) as f32 * 0.1).collect();
        let hn = Histogram::new(-1.0, 1.0, 100);
        let mut hn = hn;
        hn.extend(&narrow);
        let mut hw = Histogram::new(-1.0, 1.0, 100);
        hw.extend(&wide);
        assert!(hn.central_span(0.9) < hw.central_span(0.9));
    }
}
