//! Analytic GPU-memory model — regenerates Fig. 2 (memory allocation for
//! finetuning) and the memory column of Table 4.
//!
//! The paper's Fig. 2 decomposes finetuning memory into (1) model
//! weights, (2) optimizer state (Adam: 2 moments per trainable param),
//! (3) activations.  These are accounting identities over parameter
//! counts and formats, so the model reproduces the paper's numbers
//! *exactly* when fed Llama-2-7B's dimensions — see
//! `benches/memory_model.rs` and `repro report memory`.

use crate::model::ModelConfig;
use crate::quant::QuantSpec;

/// Finetuning regimes of Fig. 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Regime {
    /// Full finetuning in bf16 + Adam.
    FullFt,
    /// LoRA on a bf16 base.
    Lora { rank: usize },
    /// QLoRA-style: quantized base + LoRA (the ApiQ setting).
    QLora { rank: usize, spec: QuantSpec },
}

/// Byte-level breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    pub weights: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub gradients: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.optimizer + self.activations + self.gradients
    }

    pub fn gb(x: u64) -> f64 {
        x as f64 / 1e9
    }
}

/// Parameter-count description of an arbitrary transformer (so the model
/// can also price the paper's Llama-2-7B for the Fig. 2 cross-check).
#[derive(Clone, Copy, Debug)]
pub struct ArchShape {
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ArchShape {
    pub fn from_config(cfg: &ModelConfig) -> Self {
        ArchShape {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            d_ffn: cfg.d_ffn,
            vocab: cfg.vocab,
            seq_len: cfg.seq_len,
            batch: cfg.batch,
        }
    }

    /// Llama-2-7B's shape (for reproducing the paper's absolute numbers).
    pub fn llama2_7b() -> Self {
        ArchShape {
            n_layers: 32, d_model: 4096, d_ffn: 11008, vocab: 32000,
            seq_len: 2048, batch: 1,
        }
    }

    pub fn linear_params(&self) -> u64 {
        // q,k,v,o: d*d each; gate,up: d*ffn; down: ffn*d
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        (4 * d * d + 3 * d * f) * self.n_layers as u64
    }

    pub fn total_params(&self) -> u64 {
        let d = self.d_model as u64;
        self.linear_params()
            + 2 * self.vocab as u64 * d      // embed + head
            + (2 * self.n_layers as u64 + 1) * d // norms
    }

    pub fn lora_params(&self, rank: usize) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        let r = rank as u64;
        // per linear: (d_in + d_out) * r, all 7 linears, all layers
        ((4 * (d + d) + 2 * (d + f) + (f + d)) * r) * self.n_layers as u64
    }

    /// Activation bytes retained for backward (checkpoint-free), bf16.
    /// Per layer we retain the major intermediates: block input, attn
    /// scores probs (b h t t), qkv, ffn intermediates — a standard rough
    /// accounting matching the order of magnitude in the paper's Fig. 2.
    pub fn activation_bytes(&self, bytes_per: u64) -> u64 {
        let b = self.batch as u64;
        let t = self.seq_len as u64;
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        let per_layer = b * t * d * 6 + b * t * f * 3;
        (per_layer * self.n_layers as u64 + b * t * self.vocab as u64) * bytes_per
    }
}

/// The memory model.
pub struct MemoryModel {
    pub arch: ArchShape,
}

impl MemoryModel {
    pub fn new(arch: ArchShape) -> Self {
        MemoryModel { arch }
    }

    /// Bytes per weight for the quantized payload incl. group metadata.
    fn quant_bytes(total: u64, spec: QuantSpec) -> u64 {
        let codes = total * spec.bits as u64 / 8;
        // per group: f32 scale + u8 zero
        let meta = total / spec.group as u64 * 5;
        codes + meta
    }

    pub fn breakdown(&self, regime: Regime) -> MemoryBreakdown {
        let p = self.arch.total_params();
        let lin = self.arch.linear_params();
        let other = p - lin;
        match regime {
            Regime::FullFt => MemoryBreakdown {
                weights: 2 * p,            // bf16
                optimizer: 4 * p,          // Adam m+v in bf16 (paper Fig. 2)
                gradients: 2 * p,          // bf16 grads
                activations: self.arch.activation_bytes(2),
            },
            Regime::Lora { rank } => {
                let l = self.arch.lora_params(rank);
                MemoryBreakdown {
                    weights: 2 * (p + l),
                    optimizer: 4 * l,
                    gradients: 2 * l,
                    activations: self.arch.activation_bytes(2),
                }
            }
            Regime::QLora { rank, spec } => {
                let l = self.arch.lora_params(rank);
                MemoryBreakdown {
                    // linears quantized, the rest bf16
                    weights: Self::quant_bytes(lin, spec) + 2 * other + 2 * l,
                    optimizer: 4 * l,
                    gradients: 2 * l,
                    activations: self.arch.activation_bytes(2),
                }
            }
        }
    }

    /// Weights-resident bytes for *serving* (no optimizer / gradient /
    /// activation state): packed quantized linears + f32 embed/head/norms
    /// + f32 LoRA adapters at `rank` (0 = no adapters).  `spec: None`
    /// prices dense-f32 linears (the fp reference, or weight-override
    /// baselines that serve dequantized weights).  The measured
    /// counterpart is `infer::PackedModel::resident_bytes`.
    pub fn inference_weights(&self, spec: Option<QuantSpec>, rank: usize) -> u64 {
        let p = self.arch.total_params();
        let lin = self.arch.linear_params();
        let other = p - lin;
        let adapters = 4 * self.arch.lora_params(rank);
        match spec {
            None => 4 * p + adapters,
            Some(spec) => Self::quant_bytes(lin, spec) + 4 * other + adapters,
        }
    }

    /// Peak memory during *quantization* (Table 4's right column):
    /// ApiQ-lw holds one layer's tensors + calib activations; ApiQ-bw one
    /// block's; LoftQ needs the SVD workspace of the largest linear.
    pub fn quantization_peak(&self, method: &str, _spec: QuantSpec, rank: usize, calib_tokens: u64) -> u64 {
        let d = self.arch.d_model as u64;
        let f = self.arch.d_ffn as u64;
        let big = d * f; // largest linear
        let weights_q = 2 * self.arch.total_params() / 4; // ~4-bit working set
        // activation caches are kept in fp16 by all methods
        let act16 = calib_tokens * d * 2;
        match method {
            // Hessian (d x d f32) + half the activation cache (layer-local)
            "gptq" => weights_q + 4 * d * d + act16 / 2,
            "rtn" => weights_q + 4 * big,
            // full fp16 weights resident + SVD workspace -> the most
            // memory-hungry (Table 4)
            "loftq" => 2 * self.arch.total_params() + 16 * big,
            // one layer + adapters + the dual X / X^q stream
            "apiq-lw" => weights_q + 4 * (big + (d + f) * rank as u64) + 2 * act16,
            // whole block resident + block-internal activation cache on
            // top of the dual streams (Table 4: bw > lw)
            "apiq-bw" | "omniquant" => {
                let block = 4 * d * d + 3 * d * f;
                weights_q + 4 * block + 4 * act16
            }
            _ => 2 * self.arch.total_params(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_matches_paper_fig2() {
        // Paper: ~12.6 GB bf16 weights for 7B params; full-FT Adam ~26.4GB;
        // QLoRA 4-bit weights ~4.6GB.
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let p = m.arch.total_params();
        assert!((6.5e9..7.5e9).contains(&(p as f64)), "params {p}");
        let full = m.breakdown(Regime::FullFt);
        let w_gb = MemoryBreakdown::gb(full.weights);
        assert!((12.0..14.5).contains(&w_gb), "weights {w_gb} GB");
        let opt_gb = MemoryBreakdown::gb(full.optimizer);
        assert!((24.0..29.0).contains(&opt_gb), "optimizer {opt_gb} GB");
        let q = m.breakdown(Regime::QLora { rank: 64, spec: QuantSpec::new(4, 64) });
        let qw_gb = MemoryBreakdown::gb(q.weights);
        assert!((3.5..6.0).contains(&qw_gb), "qlora weights {qw_gb} GB");
    }

    #[test]
    fn lora_optimizer_much_smaller_than_full() {
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let full = m.breakdown(Regime::FullFt);
        let lora = m.breakdown(Regime::Lora { rank: 64 });
        assert!(lora.optimizer * 4 < full.optimizer);
    }

    #[test]
    fn lower_bits_smaller_weights() {
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let w2 = m.breakdown(Regime::QLora { rank: 64, spec: QuantSpec::new(2, 64) }).weights;
        let w4 = m.breakdown(Regime::QLora { rank: 64, spec: QuantSpec::new(4, 64) }).weights;
        assert!(w2 < w4);
    }

    #[test]
    fn bw_peak_exceeds_lw_peak() {
        // Table 4: ApiQ-bw uses more quantization memory than ApiQ-lw.
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let spec = QuantSpec::new(2, 64);
        let lw = m.quantization_peak("apiq-lw", spec, 64, 128 * 2048);
        let bw = m.quantization_peak("apiq-bw", spec, 64, 128 * 2048);
        assert!(bw > lw);
    }

    #[test]
    fn inference_weights_shrink_with_bits() {
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let fp = m.inference_weights(None, 0);
        let w4 = m.inference_weights(Some(QuantSpec::new(4, 64)), 16);
        let w2 = m.inference_weights(Some(QuantSpec::new(2, 64)), 16);
        assert!(w2 < w4 && w4 < fp, "{w2} {w4} {fp}");
        // 2-bit linears should land well under a quarter of fp
        assert!((w2 as f64) < 0.45 * fp as f64);
    }

    #[test]
    fn loftq_peak_is_largest() {
        // Table 4: LoftQ's SVD makes it the most memory-hungry.
        let m = MemoryModel::new(ArchShape::llama2_7b());
        let spec = QuantSpec::new(2, 64);
        let loftq = m.quantization_peak("loftq", spec, 64, 128 * 2048);
        for other in ["gptq", "apiq-lw", "apiq-bw"] {
            assert!(loftq > m.quantization_peak(other, spec, 64, 128 * 2048), "{other}");
        }
    }
}
