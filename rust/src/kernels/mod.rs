//! The SIMD compute core: runtime-dispatched GEMM + fused-dequant
//! kernels and the persistent worker pool they run on.
//!
//! Layout:
//!
//! * [`pool`] — the channel-fed persistent thread pool ([`pool::global`],
//!   sized once from `REPRO_THREADS` / available parallelism) that
//!   replaces the per-call `std::thread::scope` spawns of PR 1.
//! * [`gemm`] — dense f32 GEMM tiles (scalar reference + AVX2).
//! * [`dequant`] — fused dequantize-on-the-fly kernels over the packed
//!   sub-byte payload: the batched bit-stream unpacker, the group-scratch
//!   panel matmul, and the decode-specialized GEMV for `n_tok <= 4`.
//!
//! ## Dispatch
//!
//! [`active`] picks the widest kernel the CPU supports at first use
//! (`is_x86_feature_detected!("avx2")` + `"fma"`), overridable with
//! `REPRO_KERNEL=scalar|avx2` for benchmarks and CI.  The scalar path is
//! not a leftover: it is the portable build AND the reference oracle the
//! property tests compare against.
//!
//! ## Determinism
//!
//! Every kernel — scalar or SIMD, serial or pooled — produces bitwise
//! identical output for the same input:
//!
//! * each output element accumulates its k-products in ascending-k order
//!   (fixed reduction order, no horizontal sums);
//! * SIMD lanes use separate IEEE `mul` + `add` steps, never contracted
//!   FMA, so each lane reproduces the scalar arithmetic exactly (the
//!   `fma` feature is still required — the dequant path leans on AVX2
//!   integer conversions that ship with it on every real core);
//! * task decomposition is derived from the problem shape only, never
//!   from the pool width, so thread count cannot reorder anything.
//!
//! Greedy decode streams are therefore token-identical across kernel
//! choices and thread counts; `tests/kernels.rs` pins the bitwise claim.

pub mod dequant;
pub mod gemm;
pub mod pool;

use std::sync::OnceLock;

/// A selectable compute kernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable reference path; also the equivalence oracle.
    Scalar,
    /// x86_64 AVX2 (+FMA-capable CPU) vectorized path.
    Avx2,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
        }
    }
}

/// True when this build + CPU can run the AVX2 kernels.
pub fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The kernel the dispatched entry points use: `REPRO_KERNEL` override
/// when set (`scalar` forces the reference path; `avx2` is ignored with a
/// warning on CPUs that lack it), else feature detection.  Latched once.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let detected = if simd_supported() { Kernel::Avx2 } else { Kernel::Scalar };
        match std::env::var("REPRO_KERNEL").ok().as_deref() {
            Some("scalar") => Kernel::Scalar,
            Some("avx2") => {
                if detected != Kernel::Avx2 {
                    eprintln!("[kernels] REPRO_KERNEL=avx2 but CPU lacks avx2+fma; using scalar");
                }
                detected
            }
            Some(other) => {
                eprintln!("[kernels] unknown REPRO_KERNEL '{other}'; using {}", detected.name());
                detected
            }
            None => detected,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_are_stable() {
        // BENCH_kernels.json and the CI dispatch check grep these.
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Avx2.name(), "avx2");
    }

    #[test]
    fn active_is_consistent_with_detection() {
        // With no env override the dispatcher must pick the widest
        // supported kernel; with one, it must still be a valid kernel.
        let k = active();
        if !simd_supported() {
            assert_eq!(k, Kernel::Scalar, "cannot dispatch avx2 without CPU support");
        }
    }
}
