//! Fused dequantize-on-the-fly kernels over the packed sub-byte payload.
//!
//! The serving hot path: `y = x @ (s * (q - z))` computed straight from
//! the bit-packed codes, never materializing the dense f32 weight.  Two
//! shapes:
//!
//! * [`fused_matmul`] — the panel path (prefill, batched steps): per
//!   column block, each quantization group is unpacked into a
//!   `group x cols` scratch tile once and swept by all token rows.
//! * [`fused_gemv`] — the decode path (`n_tok <= MATVEC_MAX_ROWS`):
//!   column-major tile traversal of the payload, dequantizing each code
//!   directly into the accumulate with no scratch roundtrip — the batch-1
//!   `forward_step` stops paying the row-panel layout tax.
//!
//! Codes come out of the stream through [`unpack_run`], a u64 bit-buffer
//! that amortizes the byte arithmetic to one shift+mask per code (8+
//! codes per refill at serving widths) instead of PR 1's per-element
//! byte/offset/carry dance.
//!
//! Bitwise contract (see `kernels` module docs): scalar and AVX2, panel
//! and GEMV, serial and pooled all accumulate every output element in
//! ascending-k order with separate IEEE mul + add — outputs are bitwise
//! identical across all of them, which is what keeps greedy decode
//! streams token-identical whatever the dispatcher picks.

use crate::kernels::gemm::GEMM_PARALLEL_MIN_FLOPS;
use crate::kernels::pool::{ThreadPool, UnsafeSlice};
use crate::kernels::Kernel;

/// Column-block width of the fused task grid (and the GEMV tile).
pub const FUSED_COL_BLOCK: usize = 64;

/// Largest `n_tok` the GEMV path specializes for; wider inputs take the
/// panel path.
pub const MATVEC_MAX_ROWS: usize = 4;

/// Borrowed view of a packed linear's payload — raw parts, so the
/// kernels stay decoupled from the storage struct in `quant::pack`.
#[derive(Clone, Copy)]
pub struct PackedView<'a> {
    /// Little-endian bit-packed codes, row-major `(d_in, d_out)`.
    pub packed: &'a [u8],
    /// Per-group scales, row-major `(d_in / group, d_out)`.
    pub scales: &'a [f32],
    /// Per-group zero-points, row-major `(d_in / group, d_out)`.
    pub zeros: &'a [u8],
    pub d_in: usize,
    pub d_out: usize,
    pub group: usize,
    pub bits: usize,
}

/// Unpack `out.len()` consecutive codes starting at absolute bit
/// `bitpos` of the little-endian stream.  A u64 bit buffer is refilled a
/// byte-run at a time, so extraction is one shift+mask per code.
/// Callers guarantee the stream holds `bitpos + out.len() * bits` bits.
#[inline]
pub fn unpack_run(packed: &[u8], bitpos: usize, bits: usize, out: &mut [u32]) {
    debug_assert!((1..=8).contains(&bits));
    let mask = (1u32 << bits) - 1;
    let mut byte = bitpos >> 3;
    let mut buf: u64 = 0;
    let mut have: usize = 0;
    while have <= 56 && byte < packed.len() {
        buf |= (packed[byte] as u64) << have;
        have += 8;
        byte += 1;
    }
    let skip = bitpos & 7;
    buf >>= skip;
    have = have.saturating_sub(skip);
    for o in out.iter_mut() {
        if have < bits {
            while have <= 56 && byte < packed.len() {
                buf |= (packed[byte] as u64) << have;
                have += 8;
                byte += 1;
            }
        }
        *o = (buf as u32) & mask;
        buf >>= bits;
        have = have.saturating_sub(bits);
    }
}

/// Scalar fused panel tile over columns `[j0, j0 + cols)`: per group,
/// dequantize a `group x cols` scratch block (codes -> `s * (q - z)`),
/// then accumulate all `n_tok` rows through it.  Groups ascend, rows
/// within a group ascend — global ascending-k order per output element.
fn tile_scalar(
    v: &PackedView<'_>,
    x: &[f32],
    n_tok: usize,
    out: &UnsafeSlice<'_, f32>,
    j0: usize,
    cols: usize,
) {
    let d_out = v.d_out;
    let group = v.group;
    let n_groups = v.d_in / group;
    let mut wblock = vec![0.0f32; group * cols];
    let mut codes = vec![0u32; cols];
    for gi in 0..n_groups {
        let srow = &v.scales[gi * d_out + j0..gi * d_out + j0 + cols];
        let zrow = &v.zeros[gi * d_out + j0..gi * d_out + j0 + cols];
        for r in 0..group {
            let row = gi * group + r;
            unpack_run(v.packed, (row * d_out + j0) * v.bits, v.bits, &mut codes);
            let wrow = &mut wblock[r * cols..(r + 1) * cols];
            for j in 0..cols {
                wrow[j] = srow[j] * (codes[j] as f32 - zrow[j] as f32);
            }
        }
        for t in 0..n_tok {
            let xrow = &x[t * v.d_in + gi * group..t * v.d_in + (gi + 1) * group];
            // SAFETY: column blocks are disjoint per task.
            let orow = unsafe { out.slice_mut(t * d_out + j0, cols) };
            for (r, &xv) in xrow.iter().enumerate() {
                let wrow = &wblock[r * cols..(r + 1) * cols];
                for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// Scalar fused GEMV tile: walk the column tile down ALL weight rows in
/// order, dequantizing each code straight into the accumulate — no
/// group scratch.  Per-element arithmetic identical to [`tile_scalar`].
fn gemv_scalar(
    v: &PackedView<'_>,
    x: &[f32],
    n_tok: usize,
    out: &UnsafeSlice<'_, f32>,
    j0: usize,
    cols: usize,
) {
    debug_assert!(n_tok <= MATVEC_MAX_ROWS && cols <= FUSED_COL_BLOCK);
    let d_out = v.d_out;
    let mut codes = [0u32; FUSED_COL_BLOCK];
    let codes = &mut codes[..cols];
    let mut w = [0.0f32; FUSED_COL_BLOCK];
    let w = &mut w[..cols];
    for row in 0..v.d_in {
        let gi = row / v.group;
        let srow = &v.scales[gi * d_out + j0..gi * d_out + j0 + cols];
        let zrow = &v.zeros[gi * d_out + j0..gi * d_out + j0 + cols];
        unpack_run(v.packed, (row * d_out + j0) * v.bits, v.bits, codes);
        for j in 0..cols {
            w[j] = srow[j] * (codes[j] as f32 - zrow[j] as f32);
        }
        for t in 0..n_tok {
            let xv = x[t * v.d_in + row];
            // SAFETY: column blocks are disjoint per task.
            let orow = unsafe { out.slice_mut(t * d_out + j0, cols) };
            for (o, &wv) in orow.iter_mut().zip(w.iter()) {
                *o += xv * wv;
            }
        }
    }
}

/// Borrowed view of a quantized KV page plane (or a contiguous row range
/// of one): little-endian packed codes plus per-group scale / zero-point
/// metadata, laid out row-major with `d` values per KV row and one
/// `(scale, zero)` pair per `group` consecutive values.  The paged KV
/// store hands these to the attention core so dequantization fuses into
/// the segment walk; the kernels stay decoupled from `serve::block`'s
/// storage struct the same way [`PackedView`] decouples them from
/// `quant::pack`.
#[derive(Clone, Copy)]
pub struct KvQuantView<'a> {
    /// Little-endian bit-packed codes for `rows * d` values.
    pub codes: &'a [u8],
    /// One scale per `group` consecutive values.
    pub scales: &'a [f32],
    /// One zero-point level per `group` consecutive values.
    pub zeros: &'a [u8],
    /// Values per KV row.
    pub d: usize,
    /// Values per scale/zero group (a head slice in the KV layout).
    pub group: usize,
    /// Code width.  The KV layouts pack 4 or 8, so a group never
    /// straddles a byte; other widths fall back to [`unpack_run`].
    pub bits: u32,
}

impl KvQuantView<'_> {
    /// Code at value index `idx` (4-bit: low nibble first, matching
    /// `quant::pack::pack_codes`).
    #[inline]
    pub fn code_at(&self, idx: usize) -> u32 {
        match self.bits {
            8 => self.codes[idx] as u32,
            4 => ((self.codes[idx / 2] >> ((idx & 1) * 4)) & 0xF) as u32,
            _ => {
                let mut one = [0u32; 1];
                unpack_run(self.codes, idx * self.bits as usize, self.bits as usize, &mut one);
                one[0]
            }
        }
    }

    /// Dequantized value at index `idx`: `s * (q - z)`.
    #[inline]
    pub fn dq_at(&self, idx: usize) -> f32 {
        let g = idx / self.group;
        self.scales[g] * (self.code_at(idx) as f32 - self.zeros[g] as f32)
    }
}

/// Validate the bounds the KV kernels rely on (the AVX2 path reads raw
/// pointers).  O(1) integer compares; panics on violation.
#[inline]
fn check_kv_view(v: &KvQuantView<'_>, start: usize, n: usize) {
    let end = start + n;
    assert!(v.bits >= 1 && v.bits <= 8, "KvQuantView: bits {} not in 1..=8", v.bits);
    assert!(v.group > 0, "KvQuantView: zero group");
    assert!(
        v.codes.len() * 8 >= end * v.bits as usize,
        "KvQuantView: codes too short for value range {start}..{end}"
    );
    let groups = end.div_ceil(v.group);
    assert!(v.scales.len() >= groups, "KvQuantView: scales too short");
    assert!(v.zeros.len() >= groups, "KvQuantView: zeros too short");
}

/// Scalar KV dequant: values `[start, start + out.len())` of the view
/// into `out`.  This is the oracle the AVX2 path must match bitwise.
pub fn kv_dequant_scalar(v: &KvQuantView<'_>, start: usize, out: &mut [f32]) {
    check_kv_view(v, start, out.len());
    for (j, o) in out.iter_mut().enumerate() {
        let idx = start + j;
        let g = idx / v.group;
        *o = v.scales[g] * (v.code_at(idx) as f32 - v.zeros[g] as f32);
    }
}

/// Scalar fused KV value-accumulate: `ctx[j] += pw * (s * (q - z))` over
/// values `[start, start + ctx.len())`, ascending `j` — the attention
/// core's value accumulation with dequant fused in.  Oracle for the AVX2
/// path.
pub fn kv_accum_scalar(v: &KvQuantView<'_>, start: usize, pw: f32, ctx: &mut [f32]) {
    check_kv_view(v, start, ctx.len());
    for (j, c) in ctx.iter_mut().enumerate() {
        let idx = start + j;
        let g = idx / v.group;
        let dq = v.scales[g] * (v.code_at(idx) as f32 - v.zeros[g] as f32);
        *c += pw * dq;
    }
}

/// Dequantize a KV value run with the selected kernel.  Scalar and AVX2
/// produce bitwise-identical output (separate IEEE mul + sub per lane,
/// integer-exact conversions), so the attention score path can dequantize
/// K head-slices through either and keep the bitwise determinism
/// contract.
pub fn kv_row_dequant(kernel: Kernel, v: &KvQuantView<'_>, start: usize, out: &mut [f32]) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selected after feature detection; bounds
        // validated by check_kv_view inside both paths.
        Kernel::Avx2 => unsafe { avx2::kv_dequant(v, start, out) },
        #[cfg(not(target_arch = "x86_64"))]
        Kernel::Avx2 => kv_dequant_scalar(v, start, out),
        Kernel::Scalar => kv_dequant_scalar(v, start, out),
    }
}

/// Fused dequant value-accumulate with the selected kernel, bitwise
/// identical across kernels (`ctx[j] += pw * (s * (q - z))` per lane in
/// the scalar operation order).
pub fn kv_row_accum(kernel: Kernel, v: &KvQuantView<'_>, start: usize, pw: f32, ctx: &mut [f32]) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `kv_row_dequant`.
        Kernel::Avx2 => unsafe { avx2::kv_accum(v, start, pw, ctx) },
        #[cfg(not(target_arch = "x86_64"))]
        Kernel::Avx2 => kv_accum_scalar(v, start, pw, ctx),
        Kernel::Scalar => kv_accum_scalar(v, start, pw, ctx),
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// Dequantize 8 codes at `codes[j..]` against `srow/zrow[j..]`:
    /// `s * (cvt(q) - cvt(z))`, all conversions integer-exact.
    ///
    /// # Safety
    ///
    /// avx2 must be available and `j + 8 <= len` for all three slices.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dequant8(codes: *const u32, srow: *const f32, zrow: *const u8) -> __m256 {
        let q = _mm256_cvtepi32_ps(_mm256_loadu_si256(codes as *const __m256i));
        let z = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_loadl_epi64(zrow as *const __m128i)));
        let s = _mm256_loadu_ps(srow);
        _mm256_mul_ps(s, _mm256_sub_ps(q, z))
    }

    /// Load 8 consecutive KV codes starting at value index `idx` as f32
    /// lanes (integer-exact conversion).
    ///
    /// # Safety
    ///
    /// avx2 must be available and `idx + 8` must be within the view's
    /// packed code range (checked by `check_kv_view` in the dispatchers).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn kv_load8(v: &KvQuantView<'_>, idx: usize) -> __m256 {
        if v.bits == 8 {
            _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_loadl_epi64(
                v.codes.as_ptr().add(idx) as *const __m128i,
            )))
        } else {
            // 4-bit (or narrower) codes: decode lanes through the same
            // `code_at` the scalar path uses, then convert — lane values
            // are identical by construction.
            let mut buf = [0i32; 8];
            for (k, b) in buf.iter_mut().enumerate() {
                *b = v.code_at(idx + k) as i32;
            }
            _mm256_cvtepi32_ps(_mm256_loadu_si256(buf.as_ptr() as *const __m256i))
        }
    }

    /// AVX2 KV dequant, bitwise-equal to [`kv_dequant_scalar`]: within
    /// each scale group the scale/zero are splatted and 8 lanes run the
    /// scalar's exact `s * (q - z)` per lane; group edges and tails fall
    /// back to the scalar expression.
    ///
    /// # Safety
    ///
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn kv_dequant(v: &KvQuantView<'_>, start: usize, out: &mut [f32]) {
        check_kv_view(v, start, out.len());
        let n = out.len();
        let mut j = 0usize;
        while j < n {
            let g = (start + j) / v.group;
            let gend = ((g + 1) * v.group - start).min(n);
            let s = v.scales[g];
            let z = v.zeros[g] as f32;
            let sv = _mm256_set1_ps(s);
            let zv = _mm256_set1_ps(z);
            while j + 8 <= gend {
                let q = kv_load8(v, start + j);
                let w = _mm256_mul_ps(sv, _mm256_sub_ps(q, zv));
                _mm256_storeu_ps(out.as_mut_ptr().add(j), w);
                j += 8;
            }
            while j < gend {
                out[j] = s * (v.code_at(start + j) as f32 - z);
                j += 1;
            }
        }
    }

    /// AVX2 fused KV value-accumulate, bitwise-equal to
    /// [`kv_accum_scalar`]: per lane `ctx[j] + pw * (s * (q - z))` with
    /// separate mul/add (no FMA contraction), so vector lanes match the
    /// scalar operation order exactly.
    ///
    /// # Safety
    ///
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn kv_accum(v: &KvQuantView<'_>, start: usize, pw: f32, ctx: &mut [f32]) {
        check_kv_view(v, start, ctx.len());
        let n = ctx.len();
        let pv = _mm256_set1_ps(pw);
        let mut j = 0usize;
        while j < n {
            let g = (start + j) / v.group;
            let gend = ((g + 1) * v.group - start).min(n);
            let s = v.scales[g];
            let z = v.zeros[g] as f32;
            let sv = _mm256_set1_ps(s);
            let zv = _mm256_set1_ps(z);
            while j + 8 <= gend {
                let q = kv_load8(v, start + j);
                let dq = _mm256_mul_ps(sv, _mm256_sub_ps(q, zv));
                let acc = _mm256_loadu_ps(ctx.as_ptr().add(j));
                let w = _mm256_add_ps(acc, _mm256_mul_ps(pv, dq));
                _mm256_storeu_ps(ctx.as_mut_ptr().add(j), w);
                j += 8;
            }
            while j < gend {
                let dq = s * (v.code_at(start + j) as f32 - z);
                ctx[j] += pw * dq;
                j += 1;
            }
        }
    }

    /// AVX2 fused panel tile, bitwise-equal to [`tile_scalar`]: the
    /// dequant into the scratch block and the token-row sweep are both
    /// vectorized across columns with separate mul + add.
    ///
    /// # Safety
    ///
    /// Caller must have verified avx2+fma support; the column block must
    /// be a disjoint region of `out`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn tile(
        v: &PackedView<'_>,
        x: &[f32],
        n_tok: usize,
        out: &UnsafeSlice<'_, f32>,
        j0: usize,
        cols: usize,
    ) {
        let d_out = v.d_out;
        let group = v.group;
        let n_groups = v.d_in / group;
        let mut wblock = vec![0.0f32; group * cols];
        let mut codes = vec![0u32; cols];
        for gi in 0..n_groups {
            let srow = &v.scales[gi * d_out + j0..gi * d_out + j0 + cols];
            let zrow = &v.zeros[gi * d_out + j0..gi * d_out + j0 + cols];
            for r in 0..group {
                let row = gi * group + r;
                unpack_run(v.packed, (row * d_out + j0) * v.bits, v.bits, &mut codes);
                let wrow = &mut wblock[r * cols..(r + 1) * cols];
                let (cp, sp, zp) = (codes.as_ptr(), srow.as_ptr(), zrow.as_ptr());
                let mut j = 0usize;
                while j + 8 <= cols {
                    let w = dequant8(cp.add(j), sp.add(j), zp.add(j));
                    _mm256_storeu_ps(wrow.as_mut_ptr().add(j), w);
                    j += 8;
                }
                while j < cols {
                    wrow[j] = srow[j] * (codes[j] as f32 - zrow[j] as f32);
                    j += 1;
                }
            }
            for t in 0..n_tok {
                let xrow = &x[t * v.d_in + gi * group..t * v.d_in + (gi + 1) * group];
                let orow = out.slice_mut(t * d_out + j0, cols);
                let op = orow.as_mut_ptr();
                let mut j = 0usize;
                // 32-column sub-tiles: accumulators stay in registers
                // across the whole group.
                while j + 32 <= cols {
                    let p = op.add(j);
                    let mut acc0 = _mm256_loadu_ps(p);
                    let mut acc1 = _mm256_loadu_ps(p.add(8));
                    let mut acc2 = _mm256_loadu_ps(p.add(16));
                    let mut acc3 = _mm256_loadu_ps(p.add(24));
                    for (r, &xv) in xrow.iter().enumerate() {
                        let av = _mm256_set1_ps(xv);
                        let wp = wblock.as_ptr().add(r * cols + j);
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(av, _mm256_loadu_ps(wp)));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(av, _mm256_loadu_ps(wp.add(8))));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(av, _mm256_loadu_ps(wp.add(16))));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(av, _mm256_loadu_ps(wp.add(24))));
                    }
                    _mm256_storeu_ps(p, acc0);
                    _mm256_storeu_ps(p.add(8), acc1);
                    _mm256_storeu_ps(p.add(16), acc2);
                    _mm256_storeu_ps(p.add(24), acc3);
                    j += 32;
                }
                while j + 8 <= cols {
                    let p = op.add(j);
                    let mut acc = _mm256_loadu_ps(p);
                    for (r, &xv) in xrow.iter().enumerate() {
                        let av = _mm256_set1_ps(xv);
                        let wp = wblock.as_ptr().add(r * cols + j);
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, _mm256_loadu_ps(wp)));
                    }
                    _mm256_storeu_ps(p, acc);
                    j += 8;
                }
                while j < cols {
                    let mut acc = *orow.get_unchecked(j);
                    for (r, &xv) in xrow.iter().enumerate() {
                        acc += xv * *wblock.get_unchecked(r * cols + j);
                    }
                    *orow.get_unchecked_mut(j) = acc;
                    j += 1;
                }
            }
        }
    }

    /// AVX2 fused GEMV tile (`n_tok <= 4`), bitwise-equal to
    /// [`gemv_scalar`].  The batch-1 full-width case keeps the whole
    /// 64-column tile in 8 ymm accumulators for the entire k sweep; the
    /// general case shares each dequantized row across the token rows
    /// through a stack tile.
    ///
    /// # Safety
    ///
    /// As for [`tile`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemv_tile(
        v: &PackedView<'_>,
        x: &[f32],
        n_tok: usize,
        out: &UnsafeSlice<'_, f32>,
        j0: usize,
        cols: usize,
    ) {
        debug_assert!(n_tok <= MATVEC_MAX_ROWS && cols <= FUSED_COL_BLOCK);
        if n_tok == 1 && cols == FUSED_COL_BLOCK {
            gemv1_reg(v, x, out, j0);
            return;
        }
        let d_out = v.d_out;
        let mut codes = [0u32; FUSED_COL_BLOCK];
        let mut w = [0.0f32; FUSED_COL_BLOCK];
        for row in 0..v.d_in {
            let gi = row / v.group;
            let sp = v.scales.as_ptr().add(gi * d_out + j0);
            let zp = v.zeros.as_ptr().add(gi * d_out + j0);
            unpack_run(v.packed, (row * d_out + j0) * v.bits, v.bits, &mut codes[..cols]);
            let mut j = 0usize;
            while j + 8 <= cols {
                let wv = dequant8(codes.as_ptr().add(j), sp.add(j), zp.add(j));
                _mm256_storeu_ps(w.as_mut_ptr().add(j), wv);
                j += 8;
            }
            while j < cols {
                w[j] = *sp.add(j) * (codes[j] as f32 - *zp.add(j) as f32);
                j += 1;
            }
            for t in 0..n_tok {
                let av = _mm256_set1_ps(*x.get_unchecked(t * v.d_in + row));
                let orow = out.slice_mut(t * d_out + j0, cols);
                let op = orow.as_mut_ptr();
                let mut j = 0usize;
                while j + 8 <= cols {
                    let p = op.add(j);
                    let acc = _mm256_add_ps(
                        _mm256_loadu_ps(p),
                        _mm256_mul_ps(av, _mm256_loadu_ps(w.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(p, acc);
                    j += 8;
                }
                while j < cols {
                    *orow.get_unchecked_mut(j) +=
                        *x.get_unchecked(t * v.d_in + row) * *w.get_unchecked(j);
                    j += 1;
                }
            }
        }
    }

    /// Batch-1 register-resident GEMV over one full-width column tile:
    /// 8 ymm accumulators hold `y[j0..j0+64]` for the entire k sweep,
    /// dequantizing each weight row straight into the accumulate.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn gemv1_reg(v: &PackedView<'_>, x: &[f32], out: &UnsafeSlice<'_, f32>, j0: usize) {
        let d_out = v.d_out;
        let orow = out.slice_mut(j0, FUSED_COL_BLOCK);
        let op = orow.as_mut_ptr();
        let mut acc = [_mm256_setzero_ps(); 8];
        for (c, a) in acc.iter_mut().enumerate() {
            *a = _mm256_loadu_ps(op.add(8 * c));
        }
        let mut codes = [0u32; FUSED_COL_BLOCK];
        for row in 0..v.d_in {
            let gi = row / v.group;
            let sp = v.scales.as_ptr().add(gi * d_out + j0);
            let zp = v.zeros.as_ptr().add(gi * d_out + j0);
            unpack_run(v.packed, (row * d_out + j0) * v.bits, v.bits, &mut codes);
            let av = _mm256_set1_ps(*x.get_unchecked(row));
            for (c, a) in acc.iter_mut().enumerate() {
                let w = dequant8(codes.as_ptr().add(8 * c), sp.add(8 * c), zp.add(8 * c));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(av, w));
            }
        }
        for (c, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(op.add(8 * c), *a);
        }
    }
}

/// Run the fused task grid: one task per `FUSED_COL_BLOCK`-wide column
/// block (pool workers write straight into their disjoint column panels
/// of `out`), inline when the problem is below the parallel threshold.
fn run_blocks(
    pool: &ThreadPool,
    v: &PackedView<'_>,
    n_tok: usize,
    run: &(dyn Fn(usize) + Sync),
) {
    let col_blocks = v.d_out.div_ceil(FUSED_COL_BLOCK);
    if col_blocks == 1
        || pool.threads() == 1
        || n_tok * v.d_in * v.d_out < GEMM_PARALLEL_MIN_FLOPS
    {
        for cb in 0..col_blocks {
            run(cb);
        }
    } else {
        pool.parallel_for(col_blocks, run);
    }
}

/// Validate the invariants the (unchecked-pointer) tile kernels rely
/// on.  `PackedView` has public fields, so the safe entry points must
/// not trust a caller-built view; these are O(1) checks against O(n^3)
/// work.  Panics on violation.
fn check_view(v: &PackedView<'_>, x: &[f32], n_tok: usize, out: &[f32]) {
    assert!((1..=8).contains(&v.bits), "PackedView: bits {} not in 1..=8", v.bits);
    assert!(
        v.group > 0 && v.d_in % v.group == 0,
        "PackedView: group {} must divide d_in {}",
        v.group,
        v.d_in
    );
    let meta = (v.d_in / v.group) * v.d_out;
    assert!(v.scales.len() >= meta, "PackedView: scales too short");
    assert!(v.zeros.len() >= meta, "PackedView: zeros too short");
    assert!(
        v.packed.len() * 8 >= v.d_in * v.d_out * v.bits,
        "PackedView: packed stream too short"
    );
    assert_eq!(x.len(), n_tok * v.d_in, "PackedView: x length mismatch");
    assert_eq!(out.len(), n_tok * v.d_out, "PackedView: out length mismatch");
}

/// Fused dequant matmul with explicit kernel + pool: `out (n_tok, d_out)
/// += x (n_tok, d_in) @ dequant(v)`.  `out` is expected zeroed (or to
/// hold a partial sum to accumulate onto).
pub fn fused_matmul(
    kernel: Kernel,
    pool: &ThreadPool,
    v: &PackedView<'_>,
    x: &[f32],
    n_tok: usize,
    out: &mut [f32],
) {
    check_view(v, x, n_tok, out);
    let view = UnsafeSlice::new(out);
    let run = |cb: usize| {
        let j0 = cb * FUSED_COL_BLOCK;
        let cols = FUSED_COL_BLOCK.min(v.d_out - j0);
        match kernel {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2 is only selected after feature detection;
            // column blocks are disjoint per task index.
            Kernel::Avx2 => unsafe { avx2::tile(v, x, n_tok, &view, j0, cols) },
            #[cfg(not(target_arch = "x86_64"))]
            Kernel::Avx2 => tile_scalar(v, x, n_tok, &view, j0, cols),
            Kernel::Scalar => tile_scalar(v, x, n_tok, &view, j0, cols),
        }
    };
    run_blocks(pool, v, n_tok, &run);
}

/// Decode-specialized fused GEMV (`n_tok <= MATVEC_MAX_ROWS`): same
/// contract as [`fused_matmul`], bitwise-identical output, but traverses
/// each column tile straight down the payload with no group scratch.
/// Falls back to the panel path for wider inputs.
pub fn fused_gemv(
    kernel: Kernel,
    pool: &ThreadPool,
    v: &PackedView<'_>,
    x: &[f32],
    n_tok: usize,
    out: &mut [f32],
) {
    if n_tok > MATVEC_MAX_ROWS {
        fused_matmul(kernel, pool, v, x, n_tok, out);
        return;
    }
    check_view(v, x, n_tok, out);
    let view = UnsafeSlice::new(out);
    let run = |cb: usize| {
        let j0 = cb * FUSED_COL_BLOCK;
        let cols = FUSED_COL_BLOCK.min(v.d_out - j0);
        match kernel {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: as in `fused_matmul`.
            Kernel::Avx2 => unsafe { avx2::gemv_tile(v, x, n_tok, &view, j0, cols) },
            #[cfg(not(target_arch = "x86_64"))]
            Kernel::Avx2 => gemv_scalar(v, x, n_tok, &view, j0, cols),
            Kernel::Scalar => gemv_scalar(v, x, n_tok, &view, j0, cols),
        }
    };
    run_blocks(pool, v, n_tok, &run);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack_codes;

    #[test]
    fn unpack_run_matches_reference_all_bits_and_offsets() {
        for bits in [1usize, 2, 3, 4, 5, 8] {
            let mask = (1u32 << bits) - 1;
            let n = 200;
            let codes: Vec<u32> =
                (0..n as u32).map(|i| i.wrapping_mul(2654435761) & mask).collect();
            let packed = pack_codes(&codes, bits as u32);
            for start in [0usize, 1, 7, 63, 100] {
                let want = &codes[start..];
                let mut got = vec![0u32; want.len()];
                unpack_run(&packed, start * bits, bits, &mut got);
                assert_eq!(&got, want, "bits={bits} start={start}");
            }
        }
    }

    #[test]
    fn unpack_run_empty_is_noop() {
        let mut out: [u32; 0] = [];
        unpack_run(&[], 0, 2, &mut out);
    }

    /// Deterministic pseudo-random KV view over `rows * d` values.
    fn kv_view_fixture(
        rows: usize,
        d: usize,
        group: usize,
        bits: u32,
        seed: u32,
    ) -> (Vec<u8>, Vec<f32>, Vec<u8>) {
        let n = rows * d;
        let mask = (1u32 << bits) - 1;
        let codes: Vec<u32> =
            (0..n as u32).map(|i| (i ^ seed).wrapping_mul(2654435761) & mask).collect();
        let packed = pack_codes(&codes, bits);
        let groups = n / group;
        let scales: Vec<f32> =
            (0..groups).map(|g| 0.01 + 0.003 * ((g as u32 ^ seed) % 17) as f32).collect();
        let zeros: Vec<u8> = (0..groups).map(|g| ((g as u32 * 7 + seed) & mask) as u8).collect();
        (packed, scales, zeros)
    }

    #[test]
    fn kv_dequant_matches_dq_at_both_widths() {
        for bits in [4u32, 8] {
            let (rows, d, group) = (5usize, 24usize, 12usize);
            let (packed, scales, zeros) = kv_view_fixture(rows, d, group, bits, 3);
            let v = KvQuantView { codes: &packed, scales: &scales, zeros: &zeros, d, group, bits };
            for start in [0usize, d, 2 * d + 7] {
                let n = rows * d - start;
                let mut out = vec![0.0f32; n];
                kv_dequant_scalar(&v, start, &mut out);
                for (j, &o) in out.iter().enumerate() {
                    assert_eq!(o.to_bits(), v.dq_at(start + j).to_bits(), "bits={bits} j={j}");
                }
            }
        }
    }

    #[test]
    fn kv_kernels_bitwise_match_scalar_oracle() {
        if !crate::kernels::simd_supported() {
            return;
        }
        for bits in [4u32, 8] {
            for (rows, d, group) in [(7usize, 64usize, 64usize), (3, 40, 8), (4, 24, 12)] {
                let (packed, scales, zeros) = kv_view_fixture(rows, d, group, bits, 11);
                let v =
                    KvQuantView { codes: &packed, scales: &scales, zeros: &zeros, d, group, bits };
                for start in [0usize, d, d + group] {
                    let n = rows * d - start;
                    let mut want = vec![0.0f32; n];
                    let mut got = vec![0.0f32; n];
                    kv_row_dequant(Kernel::Scalar, &v, start, &mut want);
                    kv_row_dequant(Kernel::Avx2, &v, start, &mut got);
                    let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                    let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(wb, gb, "dequant bits={bits} d={d} start={start}");

                    let mut ctx_s: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
                    let mut ctx_v = ctx_s.clone();
                    kv_row_accum(Kernel::Scalar, &v, start, 0.37, &mut ctx_s);
                    kv_row_accum(Kernel::Avx2, &v, start, 0.37, &mut ctx_v);
                    let sb: Vec<u32> = ctx_s.iter().map(|x| x.to_bits()).collect();
                    let vb: Vec<u32> = ctx_v.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(sb, vb, "accum bits={bits} d={d} start={start}");
                }
            }
        }
    }
}
