//! Dense f32 GEMM tiles: scalar reference + AVX2, k-blocked, pooled.
//!
//! `gemm_accum` accumulates `a (m x k) @ b (k x n)` into `out (m x n)`
//! WITHOUT zeroing `out` first (callers chain calls to accumulate).  Work
//! splits into fixed `MR x NC` output tiles — the grid depends on the
//! problem shape only, never the pool width, so results are bitwise
//! identical at any thread count.  Within a tile, k is swept in
//! `KC`-blocks with the accumulator lanes parked in registers per block;
//! every output element still sums its products in ascending-k order
//! with separate IEEE mul + add steps, so the AVX2 tile reproduces the
//! scalar tile bit for bit (see the module docs in `kernels`).

use crate::kernels::pool;
use crate::kernels::pool::{ThreadPool, UnsafeSlice};
use crate::kernels::Kernel;

/// Output-tile height (rows of `out` per task).
pub const MR: usize = 32;
/// Output-tile width (columns of `out` per task).
pub const NC: usize = 64;
/// k-block: `KC x NC` f32 panel of `b` (64 KB) stays cache-resident
/// while a tile's rows sweep it.
pub const KC: usize = 256;

/// Below this many multiply-accumulates a parallel dispatch costs more
/// than it saves; run the tile grid inline on the caller.  Shared with
/// the fused packed matmul in `kernels::dequant`.
pub const GEMM_PARALLEL_MIN_FLOPS: usize = 1 << 17;

/// Scalar GEMM tile: `out[i0.., j0..] += a[i0.., :] @ b[:, j0..]` over
/// `rows x cols` outputs.  i / k / j ascending — the reference order.
#[allow(clippy::too_many_arguments)]
fn tile_scalar(
    a: &[f32],
    b: &[f32],
    out: &UnsafeSlice<'_, f32>,
    k: usize,
    n: usize,
    i0: usize,
    rows: usize,
    j0: usize,
    cols: usize,
) {
    for i in i0..i0 + rows {
        let arow = &a[i * k..(i + 1) * k];
        // SAFETY: tiles of the task grid are disjoint by construction.
        let orow = unsafe { out.slice_mut(i * n + j0, cols) };
        for (l, &av) in arow.iter().enumerate() {
            let brow = &b[l * n + j0..l * n + j0 + cols];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// AVX2 GEMM tile, bitwise-equal to [`tile_scalar`]: per k-block the
    /// output lanes live in ymm registers, accumulated with separate
    /// `mul` + `add` (no FMA contraction) in ascending-k order.
    ///
    /// # Safety
    ///
    /// Caller must have verified avx2+fma support, and the tile must be
    /// a disjoint region of `out` (see [`UnsafeSlice::slice_mut`]).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn tile(
        a: &[f32],
        b: &[f32],
        out: &UnsafeSlice<'_, f32>,
        k: usize,
        n: usize,
        i0: usize,
        rows: usize,
        j0: usize,
        cols: usize,
    ) {
        for i in i0..i0 + rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = out.slice_mut(i * n + j0, cols);
            let op = orow.as_mut_ptr();
            let mut kb = 0usize;
            while kb < k {
                let kend = (kb + KC).min(k);
                let mut j = 0usize;
                // 32-column sub-tiles: 4 accumulators in registers.
                while j + 32 <= cols {
                    let p = op.add(j);
                    let mut acc0 = _mm256_loadu_ps(p);
                    let mut acc1 = _mm256_loadu_ps(p.add(8));
                    let mut acc2 = _mm256_loadu_ps(p.add(16));
                    let mut acc3 = _mm256_loadu_ps(p.add(24));
                    for l in kb..kend {
                        let av = _mm256_set1_ps(*arow.get_unchecked(l));
                        let bp = b.as_ptr().add(l * n + j0 + j);
                        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(av, _mm256_loadu_ps(bp)));
                        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(8))));
                        acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(16))));
                        acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(24))));
                    }
                    _mm256_storeu_ps(p, acc0);
                    _mm256_storeu_ps(p.add(8), acc1);
                    _mm256_storeu_ps(p.add(16), acc2);
                    _mm256_storeu_ps(p.add(24), acc3);
                    j += 32;
                }
                // 8-column sub-tiles.
                while j + 8 <= cols {
                    let p = op.add(j);
                    let mut acc = _mm256_loadu_ps(p);
                    for l in kb..kend {
                        let av = _mm256_set1_ps(*arow.get_unchecked(l));
                        let bp = b.as_ptr().add(l * n + j0 + j);
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, _mm256_loadu_ps(bp)));
                    }
                    _mm256_storeu_ps(p, acc);
                    j += 8;
                }
                // Scalar tail: identical per-element arithmetic.
                while j < cols {
                    let mut acc = *orow.get_unchecked(j);
                    for l in kb..kend {
                        acc += *arow.get_unchecked(l) * *b.get_unchecked(l * n + j0 + j);
                    }
                    *orow.get_unchecked_mut(j) = acc;
                    j += 1;
                }
                kb = kend;
            }
        }
    }
}

/// GEMM with explicit kernel + pool — the testable entry point (the
/// determinism tests drive this at 1/2/N threads and scalar-vs-SIMD).
pub fn gemm_accum_with(
    kernel: Kernel,
    pool: &ThreadPool,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let col_blocks = n.div_ceil(NC);
    let row_panels = m.div_ceil(MR);
    let n_tasks = row_panels * col_blocks;
    let view = UnsafeSlice::new(out);
    let run_tile = |ti: usize| {
        let i0 = (ti / col_blocks) * MR;
        let j0 = (ti % col_blocks) * NC;
        let rows = MR.min(m - i0);
        let cols = NC.min(n - j0);
        match kernel {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Kernel::Avx2 is only selected after feature
            // detection; the tile region is disjoint per task index.
            Kernel::Avx2 => unsafe { avx2::tile(a, b, &view, k, n, i0, rows, j0, cols) },
            #[cfg(not(target_arch = "x86_64"))]
            Kernel::Avx2 => tile_scalar(a, b, &view, k, n, i0, rows, j0, cols),
            Kernel::Scalar => tile_scalar(a, b, &view, k, n, i0, rows, j0, cols),
        }
    };
    if n_tasks == 1 || pool.threads() == 1 || m * k * n < GEMM_PARALLEL_MIN_FLOPS {
        for ti in 0..n_tasks {
            run_tile(ti);
        }
    } else {
        pool.parallel_for(n_tasks, &run_tile);
    }
}

/// Dispatched GEMM on the global pool — what `Tensor::matmul` and every
/// dense layer forward route through.
pub fn gemm_accum(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let prof = crate::obs::profile::timer();
    gemm_accum_with(super::active(), pool::global(), a, b, out, m, k, n);
    if let Some(t0) = prof {
        crate::obs::profile::record(
            crate::obs::profile::KernelKind::DenseGemm,
            t0.elapsed().as_nanos() as u64,
            2 * (m * k * n) as u64,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let av = a[i * k + l];
                for j in 0..n {
                    out[i * n + j] += av * b[l * n + j];
                }
            }
        }
        out
    }

    fn fill(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = crate::tensor::Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn tiles_match_naive_awkward_shapes() {
        let pool = ThreadPool::with_threads(3);
        for &(m, k, n) in &[(1, 7, 5), (33, 65, 67), (4, 300, 91), (70, 16, 64)] {
            let a = fill(m as u64 * 31 + n as u64, m * k);
            let b = fill(k as u64 * 7 + 1, k * n);
            let want = naive(&a, &b, m, k, n);
            for kern in [Kernel::Scalar, kernels::active()] {
                let mut out = vec![0.0f32; m * n];
                gemm_accum_with(kern, &pool, &a, &b, &mut out, m, k, n);
                assert_eq!(out, want, "{m}x{k}x{n} kernel {}", kern.name());
            }
        }
    }

    #[test]
    fn accumulates_into_existing_output() {
        let pool = ThreadPool::with_threads(2);
        let p1 = ThreadPool::with_threads(1);
        let (m, k, n) = (3, 4, 5);
        let a = fill(1, m * k);
        let b = fill(2, k * n);
        // scalar and dispatched kernels must agree bitwise even when the
        // output starts non-zero (the accumulate contract)
        let mut want = vec![1.5f32; m * n];
        gemm_accum_with(Kernel::Scalar, &p1, &a, &b, &mut want, m, k, n);
        let mut out = vec![1.5f32; m * n];
        gemm_accum_with(kernels::active(), &pool, &a, &b, &mut out, m, k, n);
        assert_eq!(out, want);
        // and the accumulate really started from 1.5, not from 0
        for (o, z) in want.iter().zip(naive(&a, &b, m, k, n).iter()) {
            assert!((o - z - 1.5).abs() < 1e-4, "{o} vs {z} + 1.5");
        }
    }
}
