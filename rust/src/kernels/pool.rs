//! Persistent worker thread pool for the compute kernels.
//!
//! PR 1's GEMM spawned a fresh `std::thread::scope` per matmul call —
//! dozens of thread spawns per decoded token once the serve subsystem
//! made batch-1 `forward_step` the hot path.  This pool spawns its
//! workers ONCE (sized from `REPRO_THREADS`, else the machine's available
//! parallelism) and feeds them batches over channels; a `parallel_for`
//! call costs a channel send + wake instead of clone/spawn/join.
//!
//! Determinism contract: `parallel_for(n_tasks, f)` runs `f(0..n_tasks)`
//! exactly once each, with task decomposition chosen by the CALLER from
//! problem shape alone (never from the pool size).  Tasks write disjoint
//! output regions, so which worker runs which task cannot affect results
//! — the kernels above this produce bitwise-identical output at 1, 2, or
//! N threads (`tests/kernels.rs` pins this).

use std::cell::Cell;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Mutex, OnceLock};

thread_local! {
    /// True while this thread is executing a pool task.  A nested
    /// `parallel_for` from inside a task runs its batch inline instead
    /// of dispatching — two tasks blocking on jobs queued to each
    /// other's workers would otherwise deadlock.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// Pool width: `REPRO_THREADS` if set (and > 0), otherwise the machine's
/// available parallelism.  Latched once per process.
pub fn pool_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("REPRO_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// The process-wide kernel pool, spawned on first use.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::with_threads(pool_threads()))
}

/// One in-flight `parallel_for` call, shared with workers by pointer.
/// Lives on the caller's stack; the caller does not return until every
/// worker it dispatched to has sent its completion message, so the
/// borrow can never dangle.
struct Batch<'a> {
    task: &'a (dyn Fn(usize) + Sync),
    n_tasks: usize,
    next: AtomicUsize,
    panicked: AtomicBool,
}

impl Batch<'_> {
    /// Claim and run tasks until the batch is drained.  Task panics are
    /// caught (a dead worker would deadlock every later matmul) and
    /// re-raised on the calling thread after the join.
    fn run(&self) {
        let prof = crate::obs::profile::timer();
        IN_POOL_TASK.with(|flag| {
            let prev = flag.replace(true);
            loop {
                let i = self.next.fetch_add(1, Ordering::Relaxed);
                if i >= self.n_tasks {
                    break;
                }
                if catch_unwind(AssertUnwindSafe(|| (self.task)(i))).is_err() {
                    self.panicked.store(true, Ordering::Release);
                }
            }
            flag.set(prev);
        });
        if let Some(t0) = prof {
            crate::obs::profile::record_lane(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// A dispatched batch reference plus the completion channel the worker
/// signals on when it is finished touching the batch.
struct Job {
    batch: *const Batch<'static>,
    done: Sender<()>,
}

// SAFETY: the Batch pointer is only dereferenced while the dispatching
// `parallel_for` call keeps the batch alive (it blocks on `done`), and
// the closure inside is `Sync`.
unsafe impl Send for Job {}

fn worker_loop(lane: usize, rx: Receiver<Job>) {
    crate::obs::profile::set_lane(lane);
    while let Ok(job) = rx.recv() {
        // SAFETY: the dispatcher holds the batch on its stack until it
        // has received the `done` message sent below.
        unsafe { (*job.batch).run() };
        let _ = job.done.send(());
    }
}

/// Channel-fed persistent thread pool.  `with_threads(n)` spawns `n - 1`
/// workers; the thread calling `parallel_for` always participates as the
/// n-th lane, so small pools degrade gracefully to inline execution.
pub struct ThreadPool {
    workers: Vec<Mutex<Sender<Job>>>,
}

impl ThreadPool {
    pub fn with_threads(n: usize) -> Self {
        let n = n.max(1);
        let mut workers = Vec::with_capacity(n - 1);
        for i in 0..n - 1 {
            let (tx, rx) = channel::<Job>();
            std::thread::Builder::new()
                .name(format!("repro-kernel-{i}"))
                // worker i owns profiling lane i + 1; lane 0 belongs to
                // whichever thread dispatches the batch
                .spawn(move || worker_loop(i + 1, rx))
                .expect("spawn kernel pool worker");
            workers.push(Mutex::new(tx));
        }
        ThreadPool { workers }
    }

    /// Total compute lanes: persistent workers plus the calling thread.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `task(0..n_tasks)`, each index exactly once, across the pool.
    /// Blocks until every task has finished.  Concurrent calls from
    /// different threads are safe: each caller always makes progress on
    /// its own batch, so a busy pool delays but never deadlocks.  A
    /// nested call from inside a pool task runs its whole batch inline
    /// on the current thread (dispatching could deadlock two mutually
    /// waiting tasks).
    pub fn parallel_for(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let batch = Batch {
            task,
            n_tasks,
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        };
        if n_tasks == 1 || self.workers.is_empty() || IN_POOL_TASK.with(|f| f.get()) {
            batch.run();
        } else {
            let (done_tx, done_rx) = channel::<()>();
            // At most n_tasks - 1 helpers: the caller claims work too.
            let helpers = self.workers.len().min(n_tasks - 1);
            let mut dispatched = 0usize;
            for w in self.workers.iter().take(helpers) {
                let job = Job {
                    // SAFETY (lifetime erasure): we block on `done_rx`
                    // below until this worker reports in, so the batch
                    // outlives every dereference of this pointer.
                    batch: unsafe {
                        std::mem::transmute::<*const Batch<'_>, *const Batch<'static>>(&batch)
                    },
                    done: done_tx.clone(),
                };
                if w.lock().expect("kernel pool sender poisoned").send(job).is_ok() {
                    dispatched += 1;
                }
            }
            batch.run();
            for _ in 0..dispatched {
                let _ = done_rx.recv();
            }
        }
        if batch.panicked.load(Ordering::Acquire) {
            panic!("kernel pool task panicked");
        }
    }
}

/// Shared-mutable view over a caller-owned `&mut [T]` for pool tasks that
/// write DISJOINT regions (e.g. the column panels of a fused matmul
/// output, which are strided and therefore cannot be split with
/// `chunks_mut`).  The unsafety of handing out overlapping regions is
/// concentrated in [`UnsafeSlice::slice_mut`].
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through `slice_mut`, whose contract requires
// callers to hand each region to at most one task.
unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        UnsafeSlice { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Disjoint mutable window `[start, start + len)`.
    ///
    /// # Safety
    ///
    /// The range must be in bounds, and no two live slices returned from
    /// the same `UnsafeSlice` may overlap (each output region must be
    /// owned by exactly one task at a time).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ThreadPool::with_threads(4);
        let n = 257;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        pool.parallel_for(n, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::with_threads(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(10, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn disjoint_writes_through_unsafe_slice() {
        let pool = ThreadPool::with_threads(3);
        let mut data = vec![0u32; 100];
        let view = UnsafeSlice::new(&mut data);
        pool.parallel_for(10, &|i| {
            // SAFETY: chunks [10i, 10i+10) are disjoint per task.
            let chunk = unsafe { view.slice_mut(i * 10, 10) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 10 + j) as u32;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, j as u32);
        }
    }

    #[test]
    fn task_panic_propagates_without_killing_workers() {
        let pool = ThreadPool::with_threads(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // the pool must still be usable afterwards
        let sum = AtomicUsize::new(0);
        pool.parallel_for(8, &|i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn nested_parallel_for_runs_inline_without_deadlock() {
        let pool = std::sync::Arc::new(ThreadPool::with_threads(2));
        let p = pool.clone();
        let total = AtomicUsize::new(0);
        pool.parallel_for(4, &|_| {
            let inner = AtomicUsize::new(0);
            p.parallel_for(8, &|i| {
                inner.fetch_add(i, Ordering::Relaxed);
            });
            total.fetch_add(inner.load(Ordering::Relaxed), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 28);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let pool = std::sync::Arc::new(ThreadPool::with_threads(2));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                let sum = AtomicUsize::new(0);
                p.parallel_for(64, &|i| {
                    sum.fetch_add(i + t as usize, Ordering::Relaxed);
                });
                sum.load(Ordering::Relaxed)
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), 2016 + 64 * t);
        }
    }
}
