//! AWQ-lite (Lin et al., 2023) — activation-aware weight quantization.
//!
//! AWQ's insight: the weights multiplying high-magnitude activation
//! channels matter most, so scale them up before quantization (and fold
//! the inverse into the activation path).  Per linear layer:
//!
//!   s_c = mean(|X_c|)^alpha              (per input channel c)
//!   Q   = RTN(W * s) / s                 (scale, quantize, unscale)
//!
//! with alpha grid-searched per layer to minimize ‖X W − X Q‖ on the
//! calibration sample — exactly the reference implementation's search,
//! minus its kernel-fusion engineering.  Produces a dequantized Q
//! (weight override, eval_bits = 16).

use crate::error::Result;
use crate::model::LINEAR_NAMES;
use crate::quant::affine::{fakequant, open_clip};
use crate::quant::QuantSpec;
use crate::quantizers::{default_adapter_qparams, init_streams, QuantResult, QuantizeCtx, Quantizer};
use crate::tensor::Tensor;

/// AWQ with an alpha grid (0 = plain RTN included as a candidate).
pub struct AwqLite {
    pub alpha_grid: Vec<f32>,
}

impl Default for AwqLite {
    fn default() -> Self {
        AwqLite { alpha_grid: vec![0.0, 0.25, 0.5, 0.75, 1.0] }
    }
}

impl AwqLite {
    /// Quantize one layer given stacked input activations X (n_tok, d_in).
    /// Returns (Q, best_alpha).
    pub fn quantize_layer(&self, w: &Tensor, x: &Tensor, spec: QuantSpec) -> Result<(Tensor, f32)> {
        let (d_in, d_out) = (w.rows(), w.cols());
        // per-channel mean |x|
        let n = x.rows();
        let mut ch = vec![0.0f32; d_in];
        for r in 0..n {
            let row = x.row(r);
            for c in 0..d_in {
                ch[c] += row[c].abs();
            }
        }
        for c in ch.iter_mut() {
            *c = (*c / n as f32).max(1e-8);
        }
        let y = x.matmul(w)?;
        let (gamma, beta) = open_clip(d_in, d_out, spec.group);

        let mut best: Option<(f32, Tensor, f32)> = None; // (err, q, alpha)
        for &alpha in &self.alpha_grid {
            // scale rows of W by s_c = ch[c]^alpha (normalized to mean 1)
            let mut s: Vec<f32> = ch.iter().map(|&c| c.powf(alpha)).collect();
            let mean_s = s.iter().sum::<f32>() / s.len() as f32;
            for v in s.iter_mut() {
                *v /= mean_s.max(1e-8);
            }
            let mut ws = w.clone();
            for r in 0..d_in {
                for c in 0..d_out {
                    let v = ws.at2(r, c) * s[r];
                    ws.set2(r, c, v);
                }
            }
            let mut q = fakequant(&ws, &gamma, &beta, spec)?;
            for r in 0..d_in {
                for c in 0..d_out {
                    let v = q.at2(r, c) / s[r];
                    q.set2(r, c, v);
                }
            }
            let err = y.sub(&x.matmul(&q)?)?.fro_norm();
            if best.as_ref().map(|(e, _, _)| err < *e).unwrap_or(true) {
                best = Some((err, q, alpha));
            }
        }
        let (_, q, alpha) = best.unwrap();
        Ok((q, alpha))
    }
}

impl Quantizer for AwqLite {
    fn name(&self) -> String {
        "awq".into()
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let mut params = ctx.params.clone();
        let mut streams = init_streams(ctx)?;
        for b in 0..ctx.cfg.n_layers {
            let bp = params.view(&format!("blocks.{b}."));
            // collect per-linear activations over all calib batches
            for lin in LINEAR_NAMES {
                let mut xs: Vec<Tensor> = Vec::new();
                for i in 0..streams.n_batches() {
                    let acts = streams.fp_acts(ctx.runtime, &bp, i)?;
                    xs.push(acts.input_for(lin)?);
                }
                // stack
                let d_in = xs[0].cols();
                let total: usize = xs.iter().map(|t| t.rows()).sum();
                let mut data = Vec::with_capacity(total * d_in);
                for t in &xs {
                    data.extend_from_slice(t.data());
                }
                let x = Tensor::new(vec![total, d_in], data)?;
                let key = ctx.cfg.weight_key(b, lin);
                let w = params.require(&key)?;
                let (q, _alpha) = self.quantize_layer(w, &x, ctx.spec)?;
                params.insert(key, q);
            }
            streams.advance_fp(ctx.runtime, &bp)?;
            if ctx.verbose {
                eprintln!("[awq] block {b} done");
            }
        }
        let qparams = default_adapter_qparams(ctx, true);
        Ok(QuantResult {
            method: self.name(),
            params,
            qparams,
            eval_bits: 16.0,
            wall_secs: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn awq_no_worse_than_rtn_on_skewed_channels() {
        // Construct inputs with strongly skewed channel magnitudes -- the
        // regime AWQ targets. Its grid includes alpha=0 (= RTN), so it can
        // only match or beat RTN in activation error.
        let mut rng = Rng::new(1);
        let (n, d_in, d_out) = (256, 64, 32);
        let mut x = Tensor::randn(&[n, d_in], 1.0, &mut rng);
        for r in 0..n {
            for c in 0..8 {
                let v = x.at2(r, c) * 20.0; // 8 hot channels
                x.set2(r, c, v);
            }
        }
        let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
        let spec = QuantSpec::new(2, 64);
        let (q_awq, alpha) = AwqLite::default().quantize_layer(&w, &x, spec).unwrap();
        let (g, b) = open_clip(d_in, d_out, 64);
        let q_rtn = fakequant(&w, &g, &b, spec).unwrap();
        let y = x.matmul(&w).unwrap();
        let e_awq = y.sub(&x.matmul(&q_awq).unwrap()).unwrap().fro_norm();
        let e_rtn = y.sub(&x.matmul(&q_rtn).unwrap()).unwrap().fro_norm();
        assert!(e_awq <= e_rtn + 1e-3, "awq {e_awq} vs rtn {e_rtn}");
        // on this construction a nonzero alpha should win
        assert!(alpha > 0.0, "expected activation-aware scaling to engage");
    }

    #[test]
    fn alpha_zero_equals_rtn() {
        let mut rng = Rng::new(2);
        let (d_in, d_out) = (64, 16);
        let x = Tensor::randn(&[64, d_in], 1.0, &mut rng);
        let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
        let spec = QuantSpec::new(2, 64);
        let awq = AwqLite { alpha_grid: vec![0.0] };
        let (q, alpha) = awq.quantize_layer(&w, &x, spec).unwrap();
        assert_eq!(alpha, 0.0);
        let (g, b) = open_clip(d_in, d_out, 64);
        let rtn = fakequant(&w, &g, &b, spec).unwrap();
        assert!(q.sub(&rtn).unwrap().fro_norm() < 1e-5);
    }
}
