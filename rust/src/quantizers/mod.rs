//! The quantizer registry: the paper's method (ApiQ-lw / ApiQ-bw) plus
//! every baseline it compares against (Tables 2, 3, 5–8):
//!
//! | paper name  | module    | mechanism                                        |
//! |-------------|-----------|--------------------------------------------------|
//! | RTN         | `rtn`     | round-to-nearest uniform affine, open clip       |
//! | QLoRA       | `rtn`     | NF-codebook round-to-nearest (Dettmers 2023)     |
//! | GPTQ(-LoRA) | `gptq`    | Hessian-aware OBQ column updates (Frantar 2022)  |
//! | AWQ         | `awq`     | activation-aware per-channel scale (Lin 2023)    |
//! | LoftQ       | `loftq`   | alternating NF-quant / SVD low-rank fit (Li 2023)|
//! | OmniQuant   | `apiq`    | ApiQ-lw with the LoRA LR pinned to 0 (Shao 2023) |
//! | ApiQ-lw     | `apiq`    | Algorithm 1, layer-wise                          |
//! | ApiQ-bw     | `apiq`    | Algorithm 1, block-wise (§4.2)                   |
//!
//! Every quantizer returns a `QuantResult` that plugs into the same eval
//! and finetune paths: baselines that produce an explicit dequantized Q
//! override the weight store and set `eval_bits = 16` (the in-graph
//! fake-quant becomes an identity); learned-clipping methods keep the
//! original weights and quantize in-graph at native bits.

pub mod apiq;
pub mod awq;
pub mod gptq;
pub mod loftq;
pub mod rtn;

pub use apiq::{ApiQ, ApiQHyper, ApiQMode};
pub use awq::AwqLite;
pub use gptq::Gptq;
pub use loftq::LoftQ;
pub use rtn::{QLoraNf, Rtn};

use std::time::Instant;

use crate::calib::CalibStreams;
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::QuantSpec;
use crate::runtime::Runtime;

/// Shared context handed to every quantizer.
pub struct QuantizeCtx<'a> {
    pub runtime: &'a Runtime,
    pub cfg: ModelConfig,
    /// Full-precision pretrained parameters.
    pub params: &'a ParamStore,
    pub spec: QuantSpec,
    pub rank: usize,
    /// LoRA scale (alpha/r), runtime scalar for the fused kernel.
    pub scale: f32,
    /// Calibration token batches (the "128 sentences" of the paper).
    pub calib: &'a [Batch],
    pub seed: u64,
    /// Print per-block progress.
    pub verbose: bool,
}

/// What a quantizer hands back to the pipeline.
pub struct QuantResult {
    pub method: String,
    /// Possibly weight-overridden parameter store (baselines producing an
    /// explicit dequantized Q). Otherwise a clone of the input params.
    pub params: ParamStore,
    /// gamma/beta/lora_a/lora_b (+ mag) for every linear.
    pub qparams: ParamStore,
    /// bits scalar for the eval/finetune artifacts: native bits for
    /// in-graph quantizers, 16.0 when `params` already holds Q.
    pub eval_bits: f32,
    /// Wall-clock of the quantization step (Table 4, duration column).
    pub wall_secs: f64,
}

/// A quantization method.
pub trait Quantizer {
    fn name(&self) -> String;
    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult>;

    /// Timed wrapper filling `wall_secs`.
    fn run(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let t0 = Instant::now();
        let mut r = self.quantize(ctx)?;
        r.wall_secs = t0.elapsed().as_secs_f64();
        if ctx.verbose {
            eprintln!("[quant] {} done in {:.1}s", r.method, r.wall_secs);
        }
        Ok(r)
    }
}

/// Construct a quantizer by its CLI name.
pub fn by_name(name: &str) -> Result<Box<dyn Quantizer>> {
    Ok(match name {
        "rtn" => Box::new(Rtn),
        "qlora" => Box::new(QLoraNf),
        "gptq" => Box::new(Gptq::default()),
        "awq" => Box::new(AwqLite::default()),
        "loftq" => Box::new(LoftQ::default()),
        "omniquant" => Box::new(ApiQ::omniquant()),
        "apiq-lw" => Box::new(ApiQ::lw()),
        "apiq-bw" => Box::new(ApiQ::bw()),
        "apiq-bw-dora" => Box::new(ApiQ::bw_dora()),
        _ => return Err(Error::config(format!("unknown quantizer '{name}'"))),
    })
}

/// All method names in the paper's comparison order.
pub const ALL_METHODS: [&str; 8] = [
    "rtn", "qlora", "gptq", "awq", "loftq", "omniquant", "apiq-lw", "apiq-bw",
];

/// Helper shared by baselines: qparams with open clipping, Kaiming A,
/// zero B (the "QLoRA default init" the paper criticizes in §3.1).
pub fn default_adapter_qparams(ctx: &QuantizeCtx, open_clip: bool) -> ParamStore {
    let mut qp = ctx.cfg.init_qparams(ctx.spec, ctx.rank, false, ctx.seed ^ 0xADA7);
    if open_clip {
        for key in qp.keys().cloned().collect::<Vec<_>>() {
            if key.ends_with(".gamma") || key.ends_with(".beta") {
                let t = qp.get_mut(&key).unwrap();
                for v in t.data_mut() {
                    *v = 30.0; // sigmoid(30) == 1.0 in f32
                }
            }
        }
    }
    qp
}

/// Helper: fresh calib streams for methods that need activations.
pub fn init_streams(ctx: &QuantizeCtx) -> Result<CalibStreams> {
    CalibStreams::init(ctx.runtime, ctx.cfg, ctx.params, ctx.calib)
}
