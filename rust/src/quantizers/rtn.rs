//! Round-to-nearest baselines: uniform-affine RTN (Table 3's `RTN`) and
//! the NF-codebook QLoRA quantizer (Tables 2, 5–8's `QLoRA`).
//!
//! Both keep the paper-criticized "default LoRA init": A ~ Kaiming,
//! B = 0, so W' = Q at the start of finetuning — the distorted starting
//! point of §3.1 that ApiQ exists to fix.

use crate::error::Result;
use crate::model::LINEAR_NAMES;
use crate::quant::nf_fakequant;
use crate::quantizers::{default_adapter_qparams, QuantResult, QuantizeCtx, Quantizer};

/// Uniform affine round-to-nearest with full (open) clip range. Since the
/// eval/finetune artifacts apply exactly this quantizer in-graph, RTN
/// needs no weight override: it just ships open-clip qparams and native
/// bits.
pub struct Rtn;

impl Quantizer for Rtn {
    fn name(&self) -> String {
        "rtn".into()
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let qparams = default_adapter_qparams(ctx, true);
        Ok(QuantResult {
            method: self.name(),
            params: ctx.params.clone(),
            qparams,
            eval_bits: ctx.spec.bits as f32,
            wall_secs: 0.0,
        })
    }
}

/// QLoRA: NormalFloat quantization of every linear weight (host-side),
/// default LoRA init.  The dequantized NF weights override the param
/// store and the artifacts run with bits=16 (identity in-graph quant).
pub struct QLoraNf;

impl Quantizer for QLoraNf {
    fn name(&self) -> String {
        "qlora".into()
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let mut params = ctx.params.clone();
        for i in 0..ctx.cfg.n_layers {
            for lin in LINEAR_NAMES {
                let key = ctx.cfg.weight_key(i, lin);
                let w = params.require(&key)?;
                let q = nf_fakequant(w, ctx.spec.bits, ctx.spec.group)?;
                params.insert(key, q);
            }
        }
        let qparams = default_adapter_qparams(ctx, true);
        Ok(QuantResult {
            method: self.name(),
            params,
            qparams,
            eval_bits: 16.0,
            wall_secs: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINY;
    use crate::quant::QuantSpec;

    // Runtime-free harness: quantizers that don't touch artifacts can be
    // tested without a PJRT client by faking the context pieces they use.
    // (Runtime is only dereferenced by activation-based methods.)
    fn ctx<'a>(
        runtime: &'a crate::runtime::Runtime,
        params: &'a crate::model::ParamStore,
    ) -> QuantizeCtx<'a> {
        QuantizeCtx {
            runtime,
            cfg: TINY,
            params,
            spec: QuantSpec::new(2, 64),
            rank: 16,
            scale: 1.0,
            calib: &[],
            seed: 1,
            verbose: false,
        }
    }

    #[test]
    fn qlora_overrides_weights() {
        // Only run when a CPU PJRT client can be built (always true here,
        // but keep the guard for sandboxed unit runs).
        let Ok(runtime) = crate::runtime::Runtime::new("artifacts") else {
            return;
        };
        let params = TINY.init_params(7);
        let c = ctx(&runtime, &params);
        let r = QLoraNf.quantize(&c).unwrap();
        assert_eq!(r.eval_bits, 16.0);
        // weights changed
        let w0 = params.get("blocks.0.wq").unwrap();
        let w1 = r.params.get("blocks.0.wq").unwrap();
        assert!(w0.sub(w1).unwrap().fro_norm() > 0.0);
        // embed untouched (not quantized)
        assert_eq!(params.get("embed").unwrap(), r.params.get("embed").unwrap());
        // B zero init
        assert_eq!(r.qparams.get("blocks.0.wq.lora_b").unwrap().fro_norm(), 0.0);
    }

    #[test]
    fn rtn_keeps_weights_native_bits() {
        let Ok(runtime) = crate::runtime::Runtime::new("artifacts") else {
            return;
        };
        let params = TINY.init_params(7);
        let c = ctx(&runtime, &params);
        let r = Rtn.quantize(&c).unwrap();
        assert_eq!(r.eval_bits, 2.0);
        assert_eq!(
            params.get("blocks.0.wq").unwrap(),
            r.params.get("blocks.0.wq").unwrap()
        );
        // open clip
        assert_eq!(r.qparams.get("blocks.0.wq.gamma").unwrap().data()[0], 30.0);
    }
}
