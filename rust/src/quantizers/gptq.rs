//! GPTQ (Frantar et al., 2022) — Hessian-aware one-shot quantization.
//!
//! Per linear layer with input activations X (collected from the
//! calibration stream):
//!
//!   H = 2 X^T X + λ I                       (proxy Hessian, d_in x d_in)
//!   for each input row w_i (processed in order):
//!     quantize w_i -> q_i  (group-wise uniform affine, open clip)
//!     err_i = (w_i - q_i) / [H^-1]_ii
//!     w_j  -= [H^-1]_ji * err_i   for j > i  (error feedback)
//!
//! We implement the classic OBQ row loop off a Cholesky factorization of
//! H (solving for the needed H^-1 columns lazily).  Activations come from
//! the *full-precision* stream (standard GPTQ collects pre-quantization
//! activations layer by layer; the sequential-propagation refinement
//! belongs to ApiQ and is deliberately absent here — that gap is the
//! paper's point).
//!
//! GPTQ-LoRA (Tables 7, 8) = this quantizer + default LoRA init, which is
//! exactly what `QuantResult` encodes.

use crate::calib::CalibStreams;
use crate::error::Result;
use crate::model::{ModelConfig, ParamStore, LINEAR_NAMES};
use crate::quant::affine::{open_clip, scales_zeros};
use crate::quant::QuantSpec;
use crate::quantizers::{default_adapter_qparams, init_streams, QuantResult, QuantizeCtx, Quantizer};
use crate::tensor::linalg::{cholesky_in_place, cholesky_solve};
use crate::tensor::Tensor;

/// GPTQ with a relative dampening factor λ = damp * mean(diag H).
pub struct Gptq {
    pub damp: f32,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { damp: 0.01 }
    }
}

impl Gptq {
    /// Quantize one weight (d_in, d_out) given the layer Hessian H
    /// (d_in x d_in). Returns the dequantized Q.
    pub fn quantize_layer(&self, w: &Tensor, h: &Tensor, spec: QuantSpec) -> Result<Tensor> {
        let (d_in, d_out) = (w.rows(), w.cols());
        let m = spec.max_level();
        // Dampen + invert via Cholesky.
        let mut hd = h.data().to_vec();
        let mean_diag: f32 =
            (0..d_in).map(|i| hd[i * d_in + i]).sum::<f32>() / d_in as f32;
        let lambda = self.damp * mean_diag.max(1e-6);
        for i in 0..d_in {
            hd[i * d_in + i] += lambda;
        }
        let mut l = hd.clone();
        cholesky_in_place(&mut l, d_in)?;
        // Full H^-1 (column solves). d_in <= ~2112, fine host-side.
        let mut hinv = vec![0.0f32; d_in * d_in];
        let mut e = vec![0.0f32; d_in];
        for c in 0..d_in {
            e[c] = 1.0;
            let col = cholesky_solve(&l, d_in, &e);
            for r in 0..d_in {
                hinv[r * d_in + c] = col[r];
            }
            e[c] = 0.0;
        }

        // Row loop with error feedback. Scales/zeros are computed from the
        // ORIGINAL weights (fixed grid), as in the reference implementation.
        let (gamma, beta) = open_clip(d_in, d_out, spec.group);
        let (s, z) = scales_zeros(w, &gamma, &beta, spec)?;
        let mut wt = w.clone();
        let mut q = Tensor::zeros(&[d_in, d_out]);
        for i in 0..d_in {
            let gi = i / spec.group;
            let dii = hinv[i * d_in + i].max(1e-10);
            // quantize row i on the fixed grid
            let mut err_row = vec![0.0f32; d_out];
            for c in 0..d_out {
                let sc = s.at2(gi, c);
                let zp = z.at2(gi, c);
                let qv = ((wt.at2(i, c) / sc).round() + zp).clamp(0.0, m);
                let deq = sc * (qv - zp);
                q.set2(i, c, deq);
                err_row[c] = (wt.at2(i, c) - deq) / dii;
            }
            // propagate the error to the not-yet-quantized rows
            for j in (i + 1)..d_in {
                let hji = hinv[j * d_in + i];
                if hji == 0.0 {
                    continue;
                }
                for c in 0..d_out {
                    let v = wt.at2(j, c) - hji * err_row[c];
                    wt.set2(j, c, v);
                }
            }
        }
        Ok(q)
    }

    /// Accumulate H = 2 Σ X^T X over calibration batches for each linear
    /// of one block (keyed by linear name).
    fn block_hessians(
        cfg: &ModelConfig,
        streams: &CalibStreams,
        runtime: &crate::runtime::Runtime,
        bp: &ParamStore,
    ) -> Result<Vec<(String, Tensor)>> {
        let mut hs: Vec<(String, Tensor)> = LINEAR_NAMES
            .iter()
            .map(|lin| {
                let (d_in, _) = cfg.linear_shape(*lin);
                (lin.as_str().to_string(), Tensor::zeros(&[d_in, d_in]))
            })
            .collect();
        for i in 0..streams.n_batches() {
            let acts = streams.fp_acts(runtime, bp, i)?;
            for (name, h) in hs.iter_mut() {
                let lin = crate::model::LinearKind::from_str(name).unwrap();
                let x = acts.input_for(lin)?; // (n_tok, d_in)
                let xtx = x.transpose()?.matmul(&x)?;
                *h = h.add(&xtx.scale(2.0))?;
            }
        }
        Ok(hs)
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> String {
        "gptq".into()
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let mut params = ctx.params.clone();
        let mut streams = init_streams(ctx)?;
        for b in 0..ctx.cfg.n_layers {
            let bp = params.view(&format!("blocks.{b}."));
            let hessians = Self::block_hessians(&ctx.cfg, &streams, ctx.runtime, &bp)?;
            for (lin_name, h) in &hessians {
                let key = format!("blocks.{b}.{lin_name}");
                let w = params.require(&key)?;
                let q = self.quantize_layer(w, h, ctx.spec)?;
                params.insert(key, q);
            }
            // advance the (fp) stream with the ORIGINAL weights
            streams.advance_fp(ctx.runtime, &bp)?;
            if ctx.verbose {
                eprintln!("[gptq] block {b} done");
            }
        }
        let qparams = default_adapter_qparams(ctx, true);
        Ok(QuantResult {
            method: self.name(),
            params,
            qparams,
            eval_bits: 16.0,
            wall_secs: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        // The whole point of GPTQ: with correlated X, error feedback gives
        // lower ||XW - XQ|| than plain RTN.
        let mut rng = Rng::new(1);
        let (n, d_in, d_out) = (256, 64, 32);
        // correlated inputs: x = z @ M with a random mixing matrix
        let z = Tensor::randn(&[n, d_in], 1.0, &mut rng);
        let mix = Tensor::randn(&[d_in, d_in], 0.5, &mut rng);
        let x = z.matmul(&mix).unwrap();
        let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
        let spec = QuantSpec::new(2, 64);

        let h = x.transpose().unwrap().matmul(&x).unwrap().scale(2.0);
        let q_gptq = Gptq::default().quantize_layer(&w, &h, spec).unwrap();
        let (g, b) = open_clip(d_in, d_out, 64);
        let q_rtn = crate::quant::affine::fakequant(&w, &g, &b, spec).unwrap();

        let y = x.matmul(&w).unwrap();
        let e_gptq = y.sub(&x.matmul(&q_gptq).unwrap()).unwrap().fro_norm();
        let e_rtn = y.sub(&x.matmul(&q_rtn).unwrap()).unwrap().fro_norm();
        assert!(
            e_gptq < e_rtn,
            "gptq act err {e_gptq} should beat rtn {e_rtn}"
        );
    }

    #[test]
    fn gptq_output_is_on_quant_grid() {
        let mut rng = Rng::new(2);
        let (d_in, d_out) = (64, 16);
        let x = Tensor::randn(&[128, d_in], 1.0, &mut rng);
        let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
        let h = x.transpose().unwrap().matmul(&x).unwrap().scale(2.0);
        let spec = QuantSpec::new(2, 64);
        let q = Gptq::default().quantize_layer(&w, &h, spec).unwrap();
        // each column must take at most 4 distinct values (2-bit)
        for c in 0..d_out {
            let mut vals: Vec<f32> = (0..d_in).map(|r| q.at2(r, c)).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
            assert!(vals.len() <= 4, "column {c} has {} levels", vals.len());
        }
    }

    #[test]
    fn gptq_identity_hessian_reduces_to_rtn() {
        // With H = I there are no cross-row interactions; GPTQ == RTN.
        let mut rng = Rng::new(3);
        let (d_in, d_out) = (64, 8);
        let w = Tensor::randn(&[d_in, d_out], 0.2, &mut rng);
        let mut h = Tensor::zeros(&[d_in, d_in]);
        for i in 0..d_in {
            h.set2(i, i, 1.0);
        }
        let spec = QuantSpec::new(2, 64);
        let q = Gptq { damp: 1e-6 }.quantize_layer(&w, &h, spec).unwrap();
        let (g, b) = open_clip(d_in, d_out, 64);
        let rtn = crate::quant::affine::fakequant(&w, &g, &b, spec).unwrap();
        let diff = q.sub(&rtn).unwrap().fro_norm();
        assert!(diff < 1e-4, "diff {diff}");
    }
}
