//! ApiQ — the paper's contribution (§4), as an L3 coordinator driving the
//! AOT-compiled calibration-step artifacts.
//!
//! * **ApiQ-lw** (§4.1, Algorithm 1): sequential per-linear optimization
//!   of  argmin ‖X·W − X^q·(Q + A·Bᵀ)‖  in the paper's stage order
//!   (q,k,v → o → gate,up → down), with X from the full-precision stream
//!   and X^q from the quantized stream.
//! * **ApiQ-bw** (§4.2): one joint optimization per transformer block,
//!   ‖F(Ws, X) − F(Qs, As, Bs, X^q)‖, then advance both streams.
//! * **OmniQuant-lite** = ApiQ-lw with the LoRA learning rate pinned to 0
//!   (the paper's own characterization: "OmniQuant employs a similar
//!   quantization algorithm as Algorithm 1 without LoRA parameters").
//! * **ApiQ-bw + DoRA** (§6): same block-wise objective with the DoRA
//!   adapter (magnitude + direction), for Tables 9/10.
//!
//! The gradient math (STE through rounding, AdamW on {γ,β} and {A,B} with
//! separate LRs/WDs — Table A.1) lives inside the HLO artifacts; this
//! module owns sequencing, stream propagation, and state threading.

use crate::error::Result;
use crate::model::{ParamStore, CALIB_STAGES};
use crate::quantizers::{init_streams, QuantResult, QuantizeCtx, Quantizer};
use crate::runtime::Bindings;
use crate::tensor::Tensor;

/// Optimization hyper-parameters (paper Table A.1/A.2 analogues).
#[derive(Clone, Copy, Debug)]
pub struct ApiQHyper {
    pub epochs: usize,
    /// Static LR for A, B (0 disables LoRA learning -> OmniQuant).
    pub lr_ab: f32,
    /// Static LR for the clipping logits Θ = {γ, β}.
    pub lr_gb: f32,
    pub wd_ab: f32,
    pub wd_gb: f32,
}

impl Default for ApiQHyper {
    fn default() -> Self {
        // Scaled-down defaults of Table A.1 (paper: 20 epochs, lr 1e-3 /
        // 5e-3); our models are ~1000x smaller so fewer epochs suffice.
        ApiQHyper { epochs: 10, lr_ab: 1e-3, lr_gb: 5e-3, wd_ab: 0.0, wd_gb: 0.0 }
    }
}

/// Layer-wise or block-wise sequencing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApiQMode {
    LayerWise,
    BlockWise,
}

pub struct ApiQ {
    pub mode: ApiQMode,
    pub hyper: ApiQHyper,
    pub dora: bool,
    /// Pin lr_ab to zero (OmniQuant-lite).
    pub omniquant: bool,
}

impl ApiQ {
    pub fn lw() -> Self {
        ApiQ { mode: ApiQMode::LayerWise, hyper: ApiQHyper::default(), dora: false, omniquant: false }
    }

    pub fn bw() -> Self {
        ApiQ { mode: ApiQMode::BlockWise, hyper: ApiQHyper::default(), dora: false, omniquant: false }
    }

    pub fn bw_dora() -> Self {
        ApiQ { mode: ApiQMode::BlockWise, hyper: ApiQHyper::default(), dora: true, omniquant: false }
    }

    pub fn omniquant() -> Self {
        // OmniQuant does block-wise reconstruction (Shao et al., 2023),
        // i.e. exactly ApiQ-bw with the LoRA learning rate pinned to 0.
        ApiQ { mode: ApiQMode::BlockWise, hyper: ApiQHyper::default(), dora: false, omniquant: true }
    }

    pub fn with_hyper(mut self, hyper: ApiQHyper) -> Self {
        self.hyper = hyper;
        self
    }

    fn lr_ab(&self) -> f32 {
        if self.omniquant {
            0.0
        } else {
            self.hyper.lr_ab
        }
    }

    /// Trainable-key filter for the bw artifacts' m/v groups.
    fn bw_trainable(&self, key: &str) -> bool {
        let leaf = key.rsplit('.').next().unwrap_or("");
        matches!(leaf, "gamma" | "beta" | "lora_a" | "lora_b") || (self.dora && leaf == "mag")
    }

    /// Block-wise calibration of one block; returns the final loss.
    #[allow(clippy::too_many_arguments)]
    fn calibrate_block_bw(
        &self,
        ctx: &QuantizeCtx,
        streams: &crate::calib::CalibStreams,
        bp: &ParamStore,
        bqp: &mut ParamStore,
    ) -> Result<f32> {
        let suffix = if self.dora { "_dora" } else { "" };
        let name = format!(
            "bw_calib_{}_r{}_g{}{}",
            ctx.cfg.name, ctx.rank, ctx.spec.group, suffix
        );
        let mut m = bqp.filtered(|k| self.bw_trainable(k)).zeros_like();
        let mut v = m.clone();
        let mut step = 0f32;
        let mut last_loss = f32::NAN;
        for _epoch in 0..self.hyper.epochs {
            for i in 0..streams.n_batches() {
                step += 1.0;
                let bind = Bindings::new()
                    .group("bp", bp)
                    .group("bqp", bqp)
                    .group("m", &m)
                    .group("v", &v)
                    .tensor("x", &streams.x_fp[i])
                    .tensor("xq", &streams.x_q[i])
                    .scalar("t", step)
                    .scalar("lr_ab", self.lr_ab())
                    .scalar("lr_gb", self.hyper.lr_gb)
                    .scalar("wd_ab", self.hyper.wd_ab)
                    .scalar("wd_gb", self.hyper.wd_gb)
                    .scalar("bits", ctx.spec.bits as f32)
                    .scalar("scale", ctx.scale);
                let out = ctx.runtime.run(&name, &bind)?;
                *bqp = out.group("bqp");
                m = out.group("m");
                v = out.group("v");
                last_loss = out.scalar("loss")?;
            }
        }
        Ok(last_loss)
    }

    /// Layer-wise calibration of one block (Algorithm 1 over the paper's
    /// stage order); returns the final loss of the last stage.
    fn calibrate_block_lw(
        &self,
        ctx: &QuantizeCtx,
        streams: &crate::calib::CalibStreams,
        bp: &ParamStore,
        bqp: &mut ParamStore,
    ) -> Result<f32> {
        let mut last_loss = f32::NAN;
        for stage in CALIB_STAGES {
            // (Re)collect activations with the current (partially
            // calibrated) quantized block -- the sequential propagation
            // that distinguishes ApiQ from LoftQ.
            let mut xs: Vec<Tensor> = Vec::with_capacity(streams.n_batches());
            let mut xqs: Vec<Tensor> = Vec::with_capacity(streams.n_batches());
            for i in 0..streams.n_batches() {
                let fa = streams.fp_acts(ctx.runtime, bp, i)?;
                let qa = streams.q_acts(
                    ctx.runtime, bp, bqp, i, ctx.rank, ctx.spec.group,
                    ctx.spec.bits as f32, ctx.scale,
                )?;
                xs.push(fa.input_for(stage[0])?);
                xqs.push(qa.input_for(stage[0])?);
            }
            for lin in stage.iter() {
                let (d_in, d_out) = ctx.cfg.linear_shape(*lin);
                let name = format!(
                    "lw_calib_{}_{}x{}_r{}_g{}",
                    ctx.cfg.name, d_in, d_out, ctx.rank, ctx.spec.group
                );
                let w = bp.require(lin.as_str())?;
                let mut qp = bqp.view(&format!("{}.", lin.as_str()));
                let mut m = qp.zeros_like();
                let mut v = qp.zeros_like();
                let mut step = 0f32;
                for _epoch in 0..self.hyper.epochs {
                    for i in 0..streams.n_batches() {
                        step += 1.0;
                        let bind = Bindings::new()
                            .tensor("w", w)
                            .group("qp", &qp)
                            .group("m", &m)
                            .group("v", &v)
                            .tensor("x", &xs[i])
                            .tensor("xq", &xqs[i])
                            .scalar("t", step)
                            .scalar("lr_ab", self.lr_ab())
                            .scalar("lr_gb", self.hyper.lr_gb)
                            .scalar("wd_ab", self.hyper.wd_ab)
                            .scalar("wd_gb", self.hyper.wd_gb)
                            .scalar("bits", ctx.spec.bits as f32)
                            .scalar("scale", ctx.scale);
                        let out = ctx.runtime.run(&name, &bind)?;
                        qp = out.group("qp");
                        m = out.group("m");
                        v = out.group("v");
                        last_loss = out.scalar("loss")?;
                    }
                }
                bqp.absorb(&format!("{}.", lin.as_str()), &qp);
            }
        }
        Ok(last_loss)
    }
}

impl Quantizer for ApiQ {
    fn name(&self) -> String {
        match (self.mode, self.omniquant, self.dora) {
            (_, true, _) => "omniquant".into(),
            (ApiQMode::LayerWise, _, _) => "apiq-lw".into(),
            (ApiQMode::BlockWise, _, false) => "apiq-bw".into(),
            (ApiQMode::BlockWise, _, true) => "apiq-bw-dora".into(),
        }
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        // Paper init: γ = β = 4, A ~ Kaiming, B = 0 (+ DoRA mag = ‖W‖col).
        let mut qparams = ctx.cfg.init_qparams(ctx.spec, ctx.rank, self.dora, ctx.seed ^ 0xA919);
        if self.dora {
            for b in 0..ctx.cfg.n_layers {
                for lin in crate::model::LINEAR_NAMES {
                    let w = ctx.params.require(&ctx.cfg.weight_key(b, lin))?;
                    let (d_in, d_out) = ctx.cfg.linear_shape(lin);
                    let mut mag = Tensor::zeros(&[d_out]);
                    for c in 0..d_out {
                        let mut s = 0.0f32;
                        for r in 0..d_in {
                            s += w.at2(r, c) * w.at2(r, c);
                        }
                        mag.data_mut()[c] = s.sqrt();
                    }
                    qparams.insert(format!("{}mag", ctx.cfg.qparam_prefix(b, lin)), mag);
                }
            }
        }

        let mut streams = init_streams(ctx)?;
        for b in 0..ctx.cfg.n_layers {
            let prefix = format!("blocks.{b}.");
            let bp = ctx.params.view(&prefix);
            let mut bqp = qparams.view(&prefix);
            let loss = match self.mode {
                ApiQMode::BlockWise => self.calibrate_block_bw(ctx, &streams, &bp, &mut bqp)?,
                ApiQMode::LayerWise => self.calibrate_block_lw(ctx, &streams, &bp, &mut bqp)?,
            };
            qparams.absorb(&prefix, &bqp);
            // Advance both streams past this block (quantized stream uses
            // the freshly calibrated parameters).
            streams.advance_q(
                ctx.runtime, &bp, &bqp, ctx.rank, ctx.spec.group,
                ctx.spec.bits as f32, ctx.scale,
            )?;
            streams.advance_fp(ctx.runtime, &bp)?;
            if ctx.verbose {
                eprintln!("[{}] block {b}: final calib loss {loss:.6}", self.name());
            }
        }

        Ok(QuantResult {
            method: self.name(),
            params: ctx.params.clone(),
            qparams,
            eval_bits: ctx.spec.bits as f32,
            wall_secs: 0.0,
        })
    }
}
