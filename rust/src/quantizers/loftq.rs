//! LoftQ (Li et al., 2023) — alternating quantization / SVD low-rank fit.
//!
//! Solves the paper's Eq. (2),  argmin_{Q,A,B} ‖W − (Q + A·Bᵀ)‖_F,  by
//! the reference alternating scheme (§3.3):
//!
//!   A^(t), B^(t) <- SVD_r(W − Q^(t−1))
//!   Q^(t)        <- nf_quant(W − A^(t)·B^(t)ᵀ)
//!
//! NF quantization (like the original; paper footnote 2).  This is the
//! *weight-preserving* baseline: no calibration data, per-layer
//! independent, hence no mitigation of cross-layer error propagation —
//! the gap ApiQ targets (§3.2).

use crate::error::Result;
use crate::model::LINEAR_NAMES;
use crate::quant::nf_fakequant;
use crate::quantizers::{default_adapter_qparams, QuantResult, QuantizeCtx, Quantizer};
use crate::tensor::{svd_topk, Rng, Tensor};

pub struct LoftQ {
    /// Alternating iterations T (the reference default is small).
    pub iters: usize,
    /// Power-iteration steps inside the truncated SVD.
    pub svd_iters: usize,
}

impl Default for LoftQ {
    fn default() -> Self {
        LoftQ { iters: 5, svd_iters: 24 }
    }
}

impl LoftQ {
    /// One layer: returns (Q dequantized, A, B) with W ≈ Q + A·Bᵀ.
    pub fn decompose(
        &self,
        w: &Tensor,
        bits: u32,
        group: usize,
        rank: usize,
        rng: &mut Rng,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let (d_in, d_out) = (w.rows(), w.cols());
        let mut q = nf_fakequant(w, bits, group)?;
        let mut a = Tensor::zeros(&[d_in, rank]);
        let mut b = Tensor::zeros(&[d_out, rank]);
        for _ in 0..self.iters {
            // low-rank fit of the residual
            let resid = w.sub(&q)?;
            let (u, s, v) = svd_topk(&resid, rank, self.svd_iters, rng)?;
            // A = U sqrt(S), B = V sqrt(S)
            let mut a2 = u;
            let mut b2 = v;
            for j in 0..rank.min(s.len()) {
                let sq = s[j].max(0.0).sqrt();
                for i in 0..d_in {
                    let val = a2.at2(i, j) * sq;
                    a2.set2(i, j, val);
                }
                for i in 0..d_out {
                    let val = b2.at2(i, j) * sq;
                    b2.set2(i, j, val);
                }
            }
            a = a2;
            b = b2;
            // requantize what the low-rank part doesn't explain
            let ab = a.matmul(&b.transpose()?)?;
            q = nf_fakequant(&w.sub(&ab)?, bits, group)?;
        }
        Ok((q, a, b))
    }
}

impl Quantizer for LoftQ {
    fn name(&self) -> String {
        "loftq".into()
    }

    fn quantize(&self, ctx: &QuantizeCtx) -> Result<QuantResult> {
        let mut params = ctx.params.clone();
        let mut qparams = default_adapter_qparams(ctx, true);
        let mut rng = Rng::new(ctx.seed ^ 0x10F7);
        for i in 0..ctx.cfg.n_layers {
            for lin in LINEAR_NAMES {
                let key = ctx.cfg.weight_key(i, lin);
                let w = params.require(&key)?;
                let (q, a, b) = self.decompose(
                    w,
                    ctx.spec.bits,
                    ctx.spec.group,
                    ctx.rank,
                    &mut rng,
                )?;
                // the adapter term enters the model as scale * A Bᵀ; fold
                // the calibrated scale in so W' == Q + A Bᵀ exactly
                let a = if ctx.scale != 1.0 { a.scale(1.0 / ctx.scale) } else { a };
                params.insert(key, q);
                let p = ctx.cfg.qparam_prefix(i, lin);
                qparams.insert(format!("{p}lora_a"), a);
                qparams.insert(format!("{p}lora_b"), b);
            }
            if ctx.verbose {
                eprintln!("[loftq] block {i} done");
            }
        }
        Ok(QuantResult {
            method: self.name(),
            params,
            qparams,
            eval_bits: 16.0,
            wall_secs: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loftq_reduces_weight_error_vs_plain_quant() {
        // The paper's Fig. 3 (left): LoftQ's ||W - (Q + ABᵀ)|| is far
        // below plain quantization's ||W - Q|| at 2 bits.
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[128, 64], 0.2, &mut rng);
        let plain = nf_fakequant(&w, 2, 64).unwrap();
        let e_plain = w.sub(&plain).unwrap().fro_norm();
        let (q, a, b) = LoftQ::default().decompose(&w, 2, 64, 16, &mut rng).unwrap();
        let eff = q.add(&a.matmul(&b.transpose().unwrap()).unwrap()).unwrap();
        let e_loftq = w.sub(&eff).unwrap().fro_norm();
        assert!(
            e_loftq < 0.75 * e_plain,
            "loftq {e_loftq} vs plain {e_plain}"
        );
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[128, 64], 0.2, &mut rng);
        let mut last = f32::INFINITY;
        for rank in [2usize, 8, 32] {
            let (q, a, b) = LoftQ::default().decompose(&w, 2, 64, rank, &mut rng).unwrap();
            let eff = q.add(&a.matmul(&b.transpose().unwrap()).unwrap()).unwrap();
            let e = w.sub(&eff).unwrap().fro_norm();
            assert!(e < last, "rank {rank}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn iterations_monotone_improve() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[128, 64], 0.2, &mut rng);
        let err_at = |iters: usize, rng: &mut Rng| {
            let (q, a, b) = LoftQ { iters, svd_iters: 24 }
                .decompose(&w, 2, 64, 8, rng)
                .unwrap();
            let eff = q.add(&a.matmul(&b.transpose().unwrap()).unwrap()).unwrap();
            w.sub(&eff).unwrap().fro_norm()
        };
        let e1 = err_at(1, &mut rng);
        let e5 = err_at(5, &mut rng);
        assert!(e5 <= e1 * 1.02, "iter5 {e5} vs iter1 {e1}");
    }
}
