//! Downstream task generators — the GSM8K / GLUE / commonsense stand-ins.
//!
//! Every task produces `TaskSample`s: a token sequence, a target mask
//! (1.0 on positions whose *prediction* is scored/trained, matching the
//! shifted-loss convention of `model.next_token_loss`), and the answer
//! span for accuracy scoring.
//!
//! Task roster (paper experiment -> generator):
//!   GSM8K        -> ArithTask::add (2-digit addition word problems)
//!   SVAMP        -> ArithTask::sub (subtraction, result >= 0)
//!   MAWPS        -> ArithTask::mul1 (single-digit products)
//!   AQuA         -> McTask::arith_mc (arithmetic multiple choice)
//!   GLUE-*       -> ClassifyTask (k-way Markov-style classification)
//!   commonsense  -> McTask::pattern (pattern-completion MC, 8 variants)

use crate::data::corpus::ZipfMarkovCorpus;
use crate::data::vocab;
use crate::tensor::Rng;

/// One training/eval instance.
#[derive(Clone, Debug)]
pub struct TaskSample {
    /// Token ids, padded to the caller's sequence length with PAD.
    pub tokens: Vec<i32>,
    /// Loss/score mask aligned to `tokens` (1.0 where the *target* at that
    /// position is trained/scored).
    pub mask: Vec<f32>,
    /// Positions (indices into `tokens`) holding the answer tokens.
    pub answer_pos: Vec<usize>,
    /// The correct answer tokens at those positions.
    pub answer: Vec<i32>,
    /// For MC tasks: candidate answer tokens (first is NOT necessarily
    /// correct; `answer` holds the correct one). Empty for generative.
    pub choices: Vec<i32>,
}

/// Kinds of tasks in the suite (used by the pipeline/CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    ArithAdd,
    ArithSub,
    ArithMul,
    ArithMc,
    Classify(usize),
    PatternMc(u64),
}

impl TaskKind {
    pub fn name(&self) -> String {
        match self {
            TaskKind::ArithAdd => "arith_add(gsm8k)".into(),
            TaskKind::ArithSub => "arith_sub(svamp)".into(),
            TaskKind::ArithMul => "arith_mul(mawps)".into(),
            TaskKind::ArithMc => "arith_mc(aqua)".into(),
            TaskKind::Classify(k) => format!("classify{k}(glue)"),
            TaskKind::PatternMc(v) => format!("pattern_mc{v}(commonsense)"),
        }
    }
}

/// Common interface: generate one sample of at most `seq_len` tokens.
pub trait Task {
    fn sample(&self, seq_len: usize, rng: &mut Rng) -> TaskSample;
    fn kind(&self) -> TaskKind;
}

fn pad_to(mut tokens: Vec<i32>, mut mask: Vec<f32>, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    tokens.truncate(seq_len);
    mask.truncate(seq_len);
    while tokens.len() < seq_len {
        tokens.push(vocab::PAD);
        mask.push(0.0);
    }
    (tokens, mask)
}

// ---------------------------------------------------------------------------
// Arithmetic (generative): context words, "a OP b = c"
// ---------------------------------------------------------------------------

/// Templated arithmetic word problems.
#[derive(Clone, Debug)]
pub struct ArithTask {
    pub kind: TaskKind,
    corpus: ZipfMarkovCorpus,
}

impl ArithTask {
    pub fn add(vocab_size: usize, seed: u64) -> Self {
        ArithTask { kind: TaskKind::ArithAdd, corpus: ZipfMarkovCorpus::new(vocab_size, seed) }
    }

    pub fn sub(vocab_size: usize, seed: u64) -> Self {
        ArithTask { kind: TaskKind::ArithSub, corpus: ZipfMarkovCorpus::new(vocab_size, seed) }
    }

    pub fn mul1(vocab_size: usize, seed: u64) -> Self {
        ArithTask { kind: TaskKind::ArithMul, corpus: ZipfMarkovCorpus::new(vocab_size, seed) }
    }

    fn operands(&self, rng: &mut Rng) -> (u32, u32, u32, i32) {
        match self.kind {
            TaskKind::ArithAdd => {
                let a = rng.below(50) as u32;
                let b = rng.below(50) as u32;
                (a, b, a + b, vocab::PLUS)
            }
            TaskKind::ArithSub => {
                let a = rng.below(50) as u32;
                let b = rng.below((a + 1) as usize) as u32;
                (a, b, a - b, vocab::MINUS)
            }
            TaskKind::ArithMul => {
                let a = rng.below(10) as u32;
                let b = rng.below(10) as u32;
                (a, b, a * b, vocab::TIMES)
            }
            _ => unreachable!(),
        }
    }
}

impl Task for ArithTask {
    fn sample(&self, seq_len: usize, rng: &mut Rng) -> TaskSample {
        let (a, b, c, op) = self.operands(rng);
        // "word problem" dressing: a few corpus words before the equation
        let dress = 3 + rng.below(5);
        let mut tokens = vec![vocab::BOS];
        let ctx = self.corpus.sequence(dress + 1, rng);
        tokens.extend(&ctx[1..]); // skip its BOS
        tokens.extend(vocab::number_tokens(a));
        tokens.push(op);
        tokens.extend(vocab::number_tokens(b));
        tokens.push(vocab::EQ);
        let ans = vocab::number_tokens(c);
        let ans_start = tokens.len();
        tokens.extend(&ans);
        tokens.push(vocab::SEP);
        let mut mask = vec![0.0f32; tokens.len()];
        let answer_pos: Vec<usize> = (ans_start..ans_start + ans.len()).collect();
        for &p in &answer_pos {
            mask[p] = 1.0; // trains/scores the prediction OF this position
        }
        let (tokens, mask) = pad_to(tokens, mask, seq_len);
        TaskSample { tokens, mask, answer_pos, answer: ans, choices: vec![] }
    }

    fn kind(&self) -> TaskKind {
        self.kind
    }
}

// ---------------------------------------------------------------------------
// Classification (GLUE-analogue): k Markov styles, predict the style label
// ---------------------------------------------------------------------------

/// k-way sequence classification: each class is a differently-seeded
/// Markov source; the model must predict the class token after QMARK.
#[derive(Clone, Debug)]
pub struct ClassifyTask {
    pub classes: usize,
    sources: Vec<ZipfMarkovCorpus>,
}

impl ClassifyTask {
    pub fn new(vocab_size: usize, classes: usize, seed: u64) -> Self {
        assert!(classes <= 8);
        let sources = (0..classes)
            .map(|c| ZipfMarkovCorpus::new(vocab_size, seed.wrapping_add(1000 * c as u64 + 1)))
            .collect();
        ClassifyTask { classes, sources }
    }
}

impl Task for ClassifyTask {
    fn sample(&self, seq_len: usize, rng: &mut Rng) -> TaskSample {
        let cls = rng.below(self.classes);
        let body_len = (seq_len - 4).min(24 + rng.below(16));
        let body = self.sources[cls].sequence(body_len + 1, rng);
        let mut tokens = vec![vocab::BOS];
        tokens.extend(&body[1..]);
        tokens.push(vocab::QMARK);
        let ans_pos = tokens.len();
        let label = vocab::label(cls);
        tokens.push(label);
        tokens.push(vocab::SEP);
        let mut mask = vec![0.0f32; tokens.len()];
        mask[ans_pos] = 1.0;
        let (tokens, mask) = pad_to(tokens, mask, seq_len);
        TaskSample {
            tokens,
            mask,
            answer_pos: vec![ans_pos],
            answer: vec![label],
            choices: (0..self.classes).map(vocab::label).collect(),
        }
    }

    fn kind(&self) -> TaskKind {
        TaskKind::Classify(self.classes)
    }
}

// ---------------------------------------------------------------------------
// Multiple choice (commonsense / AQuA analogue)
// ---------------------------------------------------------------------------

/// Pattern-completion multiple choice: the context establishes a periodic
/// word pattern; the correct choice continues it, distractors don't.
/// `variant` seeds a distinct task "flavor" (period 2/3/4, offset), giving
/// the eight commonsense-suite stand-ins.
#[derive(Clone, Debug)]
pub struct McTask {
    pub variant: u64,
    vocab_size: usize,
    arith: bool,
}

impl McTask {
    pub fn pattern(vocab_size: usize, variant: u64) -> Self {
        McTask { variant, vocab_size, arith: false }
    }

    /// AQuA-analogue: arithmetic with MC answers.
    pub fn arith_mc(vocab_size: usize, variant: u64) -> Self {
        McTask { variant, vocab_size, arith: true }
    }

    fn n_words(&self) -> i32 {
        self.vocab_size as i32 - vocab::WORD0
    }
}

impl Task for McTask {
    fn sample(&self, seq_len: usize, rng: &mut Rng) -> TaskSample {
        if self.arith {
            // a + b = ? with 4 digit-pair choices
            let a = rng.below(30) as u32;
            let b = rng.below(30) as u32;
            let c = a + b;
            let mut tokens = vec![vocab::BOS];
            tokens.extend(vocab::number_tokens(a));
            tokens.push(vocab::PLUS);
            tokens.extend(vocab::number_tokens(b));
            tokens.push(vocab::EQ);
            tokens.push(vocab::QMARK);
            let ans_pos = tokens.len();
            // single-token answer: tens digit of c (keeps MC single-token)
            let correct = vocab::digit(c / 10);
            tokens.push(correct);
            tokens.push(vocab::SEP);
            let mut mask = vec![0.0f32; tokens.len()];
            mask[ans_pos] = 1.0;
            let mut choices = vec![correct];
            while choices.len() < 4 {
                let d = vocab::digit(rng.below(10) as u32);
                if !choices.contains(&d) {
                    choices.push(d);
                }
            }
            rng.shuffle(&mut choices[..]);
            let (tokens, mask) = pad_to(tokens, mask, seq_len);
            return TaskSample {
                tokens,
                mask,
                answer_pos: vec![ans_pos],
                answer: vec![correct],
                choices,
            };
        }

        // pattern completion: period p in {2,3,4} derived from variant
        let p = 2 + (self.variant % 3) as usize;
        let mut motif: Vec<i32> = Vec::with_capacity(p);
        while motif.len() < p {
            let w = vocab::WORD0 + rng.below(self.n_words() as usize) as i32;
            if !motif.contains(&w) {
                motif.push(w);
            }
        }
        let reps = 3 + rng.below(4);
        let mut tokens = vec![vocab::BOS];
        for i in 0..reps * p + (p - 1) {
            tokens.push(motif[i % p]);
        }
        tokens.push(vocab::QMARK);
        let ans_pos = tokens.len();
        let correct = motif[(reps * p + (p - 1)) % p];
        tokens.push(correct);
        tokens.push(vocab::SEP);
        let mut mask = vec![0.0f32; tokens.len()];
        mask[ans_pos] = 1.0;
        let mut choices = vec![correct];
        while choices.len() < 4 {
            let w = vocab::WORD0 + rng.below(self.n_words() as usize) as i32;
            if !choices.contains(&w) {
                choices.push(w);
            }
        }
        rng.shuffle(&mut choices[..]);
        let (tokens, mask) = pad_to(tokens, mask, seq_len);
        TaskSample {
            tokens,
            mask,
            answer_pos: vec![ans_pos],
            answer: vec![correct],
            choices,
        }
    }

    fn kind(&self) -> TaskKind {
        if self.arith {
            TaskKind::ArithMc
        } else {
            TaskKind::PatternMc(self.variant)
        }
    }
}

/// The eight commonsense-suite stand-ins (BoolQ..OBQA in the paper).
pub fn commonsense_suite(vocab_size: usize) -> Vec<McTask> {
    (0..8).map(|v| McTask::pattern(vocab_size, v)).collect()
}

/// The four arithmetic test sets of Table 7 (GSM8K, SVAMP, MAWPS, AQuA).
pub fn arithmetic_suite(vocab_size: usize, seed: u64) -> (Vec<Box<dyn Task>>, Vec<String>) {
    let tasks: Vec<Box<dyn Task>> = vec![
        Box::new(ArithTask::add(vocab_size, seed)),
        Box::new(ArithTask::sub(vocab_size, seed + 1)),
        Box::new(ArithTask::mul1(vocab_size, seed + 2)),
        Box::new(McTask::arith_mc(vocab_size, 3)),
    ];
    let names = vec!["GSM8K*".into(), "SVAMP*".into(), "MAWPS*".into(), "AQuA*".into()];
    (tasks, names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arith_answer_is_correct_sum() {
        let t = ArithTask::add(512, 1);
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let s = t.sample(128, &mut rng);
            // locate EQ; digits after it (until SEP) must equal answer
            let eq = s.tokens.iter().position(|&x| x == vocab::EQ).unwrap();
            let mut ans = Vec::new();
            for &tok in &s.tokens[eq + 1..] {
                if tok == vocab::SEP {
                    break;
                }
                ans.push(tok);
            }
            assert_eq!(ans, s.answer);
            // mask exactly covers answer positions
            let on: Vec<usize> = s
                .mask
                .iter()
                .enumerate()
                .filter(|(_, &m)| m > 0.0)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(on, s.answer_pos);
        }
    }

    #[test]
    fn sub_never_negative() {
        let t = ArithTask::sub(512, 3);
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let s = t.sample(64, &mut rng);
            assert!(!s.answer.is_empty());
        }
    }

    #[test]
    fn classify_label_in_range() {
        let t = ClassifyTask::new(512, 3, 5);
        let mut rng = Rng::new(6);
        for _ in 0..30 {
            let s = t.sample(128, &mut rng);
            assert!(s.answer[0] >= vocab::LABEL0 && s.answer[0] < vocab::LABEL0 + 3);
            assert_eq!(s.choices.len(), 3);
        }
    }

    #[test]
    fn classify_styles_differ() {
        // Samples of different classes should have different token stats.
        let t = ClassifyTask::new(512, 2, 5);
        let mut rng = Rng::new(7);
        let (mut c0, mut c1) = (Vec::new(), Vec::new());
        for _ in 0..200 {
            let s = t.sample(64, &mut rng);
            let sum: i64 = s.tokens.iter().map(|&x| x as i64).sum();
            if s.answer[0] == vocab::label(0) {
                c0.push(sum);
            } else {
                c1.push(sum);
            }
        }
        let m0 = c0.iter().sum::<i64>() as f64 / c0.len() as f64;
        let m1 = c1.iter().sum::<i64>() as f64 / c1.len() as f64;
        assert!((m0 - m1).abs() > 1.0, "class styles indistinguishable");
    }

    #[test]
    fn mc_correct_choice_present_and_unique() {
        let t = McTask::pattern(512, 2);
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let s = t.sample(64, &mut rng);
            assert_eq!(s.choices.len(), 4);
            assert_eq!(s.choices.iter().filter(|&&c| c == s.answer[0]).count(), 1);
        }
    }

    #[test]
    fn mc_pattern_is_deducible() {
        // The correct answer must actually continue the motif: token at
        // answer_pos - p equals the answer (period p).
        let t = McTask::pattern(512, 0); // period 2
        let mut rng = Rng::new(9);
        let s = t.sample(64, &mut rng);
        let p = 2;
        assert_eq!(s.tokens[s.answer_pos[0] - p - 1], s.answer[0]); // -1 skips QMARK
    }

    #[test]
    fn padding_is_masked() {
        let t = ArithTask::add(512, 1);
        let mut rng = Rng::new(10);
        let s = t.sample(128, &mut rng);
        assert_eq!(s.tokens.len(), 128);
        assert_eq!(s.mask.len(), 128);
        for (tok, m) in s.tokens.iter().zip(&s.mask) {
            if *tok == vocab::PAD {
                assert_eq!(*m, 0.0);
            }
        }
    }
}
