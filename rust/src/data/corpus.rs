//! Zipf-Markov synthetic corpus — the WikiText-2 / C4 stand-in.
//!
//! Token stream with (a) Zipf-distributed unigram marginals (natural-
//! language-like frequency profile), (b) order-2 Markov structure (each
//! (w_{t-2}, w_{t-1}) context restricts the successor set), and (c)
//! sentence segmentation with SEP tokens.  The result is *learnable*:
//! a trained model reaches substantially lower perplexity than the
//! unigram entropy, which is what the perplexity experiments need —
//! quantization-induced forgetting shows up as a ppl gap.

use crate::data::vocab;
use crate::tensor::Rng;

/// Corpus generator. Cheap to construct; sequences are produced on demand.
#[derive(Clone, Debug)]
pub struct ZipfMarkovCorpus {
    vocab_size: usize,
    /// Per-context successor candidates (hash-derived, not materialized).
    branch: usize,
    /// Zipf exponent for unigram skew.
    zipf_s: f32,
    seed: u64,
    /// Cumulative Zipf weights over word ids, for sentence starts.
    zipf_cum: Vec<f32>,
}

impl ZipfMarkovCorpus {
    pub fn new(vocab_size: usize, seed: u64) -> Self {
        let n_words = vocab_size - vocab::WORD0 as usize;
        let zipf_s = 1.1f32;
        let mut cum = Vec::with_capacity(n_words);
        let mut acc = 0.0f32;
        for i in 0..n_words {
            acc += 1.0 / ((i + 1) as f32).powf(zipf_s);
            cum.push(acc);
        }
        ZipfMarkovCorpus { vocab_size, branch: 6, zipf_s, seed, zipf_cum: cum }
    }

    fn n_words(&self) -> usize {
        self.vocab_size - vocab::WORD0 as usize
    }

    /// Zipf-distributed word id in [WORD0, vocab).
    fn zipf_word(&self, rng: &mut Rng) -> i32 {
        let total = *self.zipf_cum.last().unwrap();
        let u = rng.next_f32() * total;
        // binary search the cumulative table
        let idx = self.zipf_cum.partition_point(|&c| c < u);
        vocab::WORD0 + idx.min(self.n_words() - 1) as i32
    }

    /// Deterministic successor candidate j of context (a, b).
    fn successor(&self, a: i32, b: i32, j: usize) -> i32 {
        // mix context into a hash; derive a Zipf-ranked candidate so that
        // successors are themselves frequency-skewed
        let h = (self.seed ^ (a as u64).wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add((b as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add((j as u64).wrapping_mul(0x94D049BB133111EB));
        let mut x = h | 1;
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        // skew candidate ranks toward frequent (low-rank) words: rank =
        // n * u^3 puts ~(k/n)^(1/3) of the mass on the top-k head,
        // approximating the Zipf profile of the sentence-start draws
        let n = self.n_words() as f64;
        let u = (x >> 11) as f64 / (1u64 << 53) as f64;
        let rank = ((u * u * u) * n) as usize;
        vocab::WORD0 + rank.min(self.n_words() - 1) as i32
    }

    /// Sample the next token given the 2-token context.
    fn next_token(&self, a: i32, b: i32, rng: &mut Rng) -> i32 {
        // geometric-ish preference over the branch candidates
        let mut w = Vec::with_capacity(self.branch);
        let mut p = 1.0f32;
        for _ in 0..self.branch {
            w.push(p);
            p *= 0.55;
        }
        let j = rng.categorical(&w);
        self.successor(a, b, j)
    }

    /// One sequence of exactly `len` tokens: BOS, then sentences of
    /// 8-24 words separated by SEP.
    pub fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        out.push(vocab::BOS);
        let mut sent_left = 8 + rng.below(17);
        let (mut a, mut b) = (vocab::BOS, self.zipf_word(rng));
        out.push(b);
        while out.len() < len {
            if sent_left == 0 {
                out.push(vocab::SEP);
                sent_left = 8 + rng.below(17);
                a = vocab::SEP;
                b = self.zipf_word(rng);
                if out.len() < len {
                    out.push(b);
                }
                continue;
            }
            let t = self.next_token(a, b, rng);
            out.push(t);
            a = b;
            b = t;
            sent_left -= 1;
        }
        out.truncate(len);
        out
    }

    /// A batch of sequences with an all-ones target mask (pure LM).
    pub fn batch(&self, batch: usize, len: usize, rng: &mut Rng) -> (Vec<i32>, Vec<f32>) {
        let mut toks = Vec::with_capacity(batch * len);
        for _ in 0..batch {
            toks.extend(self.sequence(len, rng));
        }
        let mask = vec![1.0f32; batch * len];
        (toks, mask)
    }

    /// Unigram entropy upper bound in nats (ppl of a unigram-optimal
    /// model); used by tests to verify learnability headroom.
    pub fn unigram_entropy(&self) -> f32 {
        let total = *self.zipf_cum.last().unwrap();
        let mut h = 0.0f32;
        let mut prev = 0.0f32;
        for &c in &self.zipf_cum {
            let p = (c - prev) / total;
            prev = c;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn sequence_length_and_range() {
        let c = ZipfMarkovCorpus::new(512, 1);
        let mut rng = Rng::new(2);
        let s = c.sequence(128, &mut rng);
        assert_eq!(s.len(), 128);
        assert_eq!(s[0], vocab::BOS);
        assert!(s.iter().all(|&t| t >= 0 && (t as usize) < 512));
    }

    #[test]
    fn deterministic_given_seed() {
        let c = ZipfMarkovCorpus::new(512, 7);
        let s1 = c.sequence(64, &mut Rng::new(3));
        let s2 = c.sequence(64, &mut Rng::new(3));
        assert_eq!(s1, s2);
    }

    #[test]
    fn unigram_is_skewed() {
        let c = ZipfMarkovCorpus::new(512, 1);
        let mut rng = Rng::new(9);
        let mut counts: HashMap<i32, usize> = HashMap::new();
        for _ in 0..200 {
            for t in c.sequence(128, &mut rng) {
                *counts.entry(t).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // top-10 tokens should cover a large fraction (Zipf head)
        let total: usize = freqs.iter().sum();
        let head: usize = freqs.iter().take(10).sum();
        assert!(head as f32 / total as f32 > 0.2, "head fraction too small");
    }

    #[test]
    fn markov_structure_is_predictable() {
        // Given a context, the successor distribution must be concentrated:
        // repeated draws from the same context should hit few distinct tokens.
        let c = ZipfMarkovCorpus::new(512, 1);
        let mut rng = Rng::new(5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(c.next_token(100, 200, &mut rng));
        }
        assert!(seen.len() <= c.branch, "{} successors", seen.len());
    }

    #[test]
    fn entropy_headroom_exists() {
        let c = ZipfMarkovCorpus::new(512, 1);
        // unigram entropy should be well below ln(V) (=6.24 for 512) and
        // the Markov structure pushes the true conditional entropy lower
        // still -- so a model has something to learn at every level
        let h = c.unigram_entropy();
        assert!(h < (512f32).ln());
        assert!(h > 2.0);
    }
}
