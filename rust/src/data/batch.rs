//! Batching: collect sequences / task samples into the fixed (B, T)
//! buffers the AOT artifacts expect.

use crate::data::tasks::{Task, TaskSample};
use crate::data::ZipfMarkovCorpus;
use crate::tensor::{IntTensor, Rng, Tensor};

/// A (tokens, mask) pair shaped (B, T), plus the per-sample metadata
/// needed for accuracy scoring.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: IntTensor,
    pub mask: Tensor,
    pub samples: Vec<TaskSample>,
}

/// Produces batches from a corpus or task with the artifact's (B, T).
pub struct Batcher {
    pub batch: usize,
    pub seq_len: usize,
}

impl Batcher {
    pub fn new(batch: usize, seq_len: usize) -> Self {
        Batcher { batch, seq_len }
    }

    /// LM batch from the corpus (mask = 1 everywhere; the shifted loss
    /// ignores position 0 by construction).
    pub fn lm_batch(&self, corpus: &ZipfMarkovCorpus, rng: &mut Rng) -> Batch {
        let (toks, mask) = corpus.batch(self.batch, self.seq_len, rng);
        Batch {
            tokens: IntTensor::new(vec![self.batch, self.seq_len], toks).unwrap(),
            mask: Tensor::new(vec![self.batch, self.seq_len], mask).unwrap(),
            samples: Vec::new(),
        }
    }

    /// Task batch: B independent samples.
    pub fn task_batch(&self, task: &dyn Task, rng: &mut Rng) -> Batch {
        let mut toks = Vec::with_capacity(self.batch * self.seq_len);
        let mut mask = Vec::with_capacity(self.batch * self.seq_len);
        let mut samples = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let s = task.sample(self.seq_len, rng);
            toks.extend(&s.tokens);
            mask.extend(&s.mask);
            samples.push(s);
        }
        Batch {
            tokens: IntTensor::new(vec![self.batch, self.seq_len], toks).unwrap(),
            mask: Tensor::new(vec![self.batch, self.seq_len], mask).unwrap(),
            samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::ArithTask;

    #[test]
    fn lm_batch_shapes() {
        let c = ZipfMarkovCorpus::new(512, 1);
        let b = Batcher::new(4, 32).lm_batch(&c, &mut Rng::new(2));
        assert_eq!(b.tokens.shape(), &[4, 32]);
        assert_eq!(b.mask.shape(), &[4, 32]);
    }

    #[test]
    fn task_batch_keeps_samples() {
        let t = ArithTask::add(512, 1);
        let b = Batcher::new(3, 64).task_batch(&t, &mut Rng::new(4));
        assert_eq!(b.samples.len(), 3);
        assert_eq!(b.tokens.data().len(), 3 * 64);
        // row i of tokens == samples[i].tokens
        for (i, s) in b.samples.iter().enumerate() {
            assert_eq!(&b.tokens.data()[i * 64..(i + 1) * 64], &s.tokens[..]);
        }
    }
}
