//! Synthetic data substrates (DESIGN.md §3 substitutions).
//!
//! The paper evaluates on WikiText-2 / C4 (language modeling), GSM8K /
//! Math10K (arithmetic), GLUE (classification) and eight commonsense
//! suites.  None of these can ship inside this image, so each is replaced
//! by a *generator* producing the same task shape over the TinyLlama
//! vocabularies: a Zipf-Markov corpus for LM, templated arithmetic word
//! problems, Markov-style classification, and pattern-completion
//! multiple choice.  All generators are deterministic from a seed.

pub mod batch;
pub mod corpus;
pub mod tasks;

pub use batch::{Batch, Batcher};
pub use corpus::ZipfMarkovCorpus;
pub use tasks::{ArithTask, ClassifyTask, McTask, Task, TaskKind, TaskSample};

/// Reserved token ids shared by all generators (vocab >= 64 assumed).
pub mod vocab {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const SEP: i32 = 2;
    pub const EQ: i32 = 3;
    pub const PLUS: i32 = 4;
    pub const MINUS: i32 = 5;
    pub const TIMES: i32 = 6;
    pub const QMARK: i32 = 7;
    pub const ANS: i32 = 8;
    /// Digits 0..=9 at ids 10..=19.
    pub const DIGIT0: i32 = 10;
    /// Class labels at ids 20..=27 (8 classes max).
    pub const LABEL0: i32 = 20;
    /// Multiple-choice markers A..D at ids 28..=31.
    pub const CHOICE0: i32 = 28;
    /// First "word" id; words occupy [WORD0, vocab).
    pub const WORD0: i32 = 32;

    pub fn digit(d: u32) -> i32 {
        DIGIT0 + d as i32
    }

    pub fn label(c: usize) -> i32 {
        LABEL0 + c as i32
    }

    /// Render a non-negative number as digit tokens (most significant first).
    pub fn number_tokens(mut n: u32) -> Vec<i32> {
        if n == 0 {
            return vec![digit(0)];
        }
        let mut ds = Vec::new();
        while n > 0 {
            ds.push(digit(n % 10));
            n /= 10;
        }
        ds.reverse();
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::vocab::*;

    #[test]
    fn number_tokens_render() {
        assert_eq!(number_tokens(0), vec![digit(0)]);
        assert_eq!(number_tokens(7), vec![digit(7)]);
        assert_eq!(number_tokens(42), vec![digit(4), digit(2)]);
        assert_eq!(number_tokens(130), vec![digit(1), digit(3), digit(0)]);
    }
}
