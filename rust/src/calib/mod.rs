//! Calibration machinery: the dual activation streams of ApiQ.
//!
//! The paper's key mechanism (§4.1) is that the quantized model is
//! calibrated against the *full-precision* model's activations while its
//! own inputs come from the *quantized* stream:
//!
//! ```text
//! X   — output of the previous full-precision block   (target side)
//! X^q — output of the previous *quantized* block      (input side)
//! ```
//!
//! so each block/layer learns to undo the error accumulated upstream.
//! `CalibStreams` owns both streams (one pair per calibration batch) and
//! advances them block by block through the `block_inputs_{fp,q}`
//! artifacts, exposing the per-linear input activations Algorithm 1 needs
//! and the Fig. 4 activation-error probes.

use crate::data::Batch;
use crate::error::Result;
use crate::model::{LinearKind, ModelConfig, ParamStore};
use crate::runtime::{Bindings, Runtime};
use crate::tensor::Tensor;

/// Collected per-linear activations of one block execution.
#[derive(Clone, Debug)]
pub struct BlockActs {
    /// Input to wq/wk/wv (post attn-norm), (B, T, d).
    pub attn_in: Tensor,
    /// Input to wo, (B, T, d).
    pub o_in: Tensor,
    /// Input to wgate/wup (post ffn-norm), (B, T, d).
    pub ffn_in: Tensor,
    /// Input to wdown, (B, T, ffn).
    pub down_in: Tensor,
    /// Block output, (B, T, d).
    pub out: Tensor,
}

impl BlockActs {
    /// The input activation feeding a given linear, flattened to
    /// (B*T, d_in) as the lw-calibration artifacts expect.
    pub fn input_for(&self, lin: LinearKind) -> Result<Tensor> {
        let t = match lin.input_activation() {
            "attn_in" => &self.attn_in,
            "o_in" => &self.o_in,
            "ffn_in" => &self.ffn_in,
            "down_in" => &self.down_in,
            other => unreachable!("unknown activation {other}"),
        };
        let s = t.shape();
        t.clone().reshape(&[s[0] * s[1], s[2]])
    }
}

/// The dual streams over a fixed set of calibration batches.
pub struct CalibStreams {
    pub cfg: ModelConfig,
    /// Embedded inputs per batch for the fp stream, (B, T, d).
    pub x_fp: Vec<Tensor>,
    /// Same for the quantized stream.
    pub x_q: Vec<Tensor>,
}

impl CalibStreams {
    /// Embed the calibration token batches (both streams start equal —
    /// the embedding layer is not quantized, as in the paper).
    pub fn init(runtime: &Runtime, cfg: ModelConfig, params: &ParamStore, batches: &[Batch]) -> Result<Self> {
        let name = format!("embed_fwd_{}", cfg.name);
        let embed = params.require("embed")?;
        let mut x_fp = Vec::with_capacity(batches.len());
        for b in batches {
            let bind = Bindings::new().tensor("embed", embed).int("tokens", &b.tokens);
            let mut out = runtime.run(&name, &bind)?;
            x_fp.push(out.take("x")?);
        }
        let x_q = x_fp.clone();
        Ok(CalibStreams { cfg, x_fp, x_q })
    }

    /// Run `block_inputs_fp` for batch `i` of the fp stream.
    pub fn fp_acts(&self, runtime: &Runtime, bp: &ParamStore, i: usize) -> Result<BlockActs> {
        let name = format!("block_inputs_fp_{}", self.cfg.name);
        let bind = Bindings::new().group("bp", bp).tensor("x", &self.x_fp[i]);
        let mut out = runtime.run(&name, &bind)?;
        Ok(BlockActs {
            attn_in: out.take("attn_in")?,
            o_in: out.take("o_in")?,
            ffn_in: out.take("ffn_in")?,
            down_in: out.take("down_in")?,
            out: out.take("out")?,
        })
    }

    /// Run `block_inputs_q` for batch `i` of the quantized stream with the
    /// current block qparams.
    #[allow(clippy::too_many_arguments)]
    pub fn q_acts(
        &self,
        runtime: &Runtime,
        bp: &ParamStore,
        bqp: &ParamStore,
        i: usize,
        rank: usize,
        group: usize,
        bits: f32,
        scale: f32,
    ) -> Result<BlockActs> {
        let name = format!("block_inputs_q_{}_r{rank}_g{group}", self.cfg.name);
        let bind = Bindings::new()
            .group("bp", bp)
            .group("bqp", bqp)
            .tensor("x", &self.x_q[i])
            .scalar("bits", bits)
            .scalar("scale", scale);
        let mut out = runtime.run(&name, &bind)?;
        Ok(BlockActs {
            attn_in: out.take("attn_in")?,
            o_in: out.take("o_in")?,
            ffn_in: out.take("ffn_in")?,
            down_in: out.take("down_in")?,
            out: out.take("out")?,
        })
    }

    /// Advance the fp stream past a block.
    pub fn advance_fp(&mut self, runtime: &Runtime, bp: &ParamStore) -> Result<()> {
        for i in 0..self.x_fp.len() {
            let acts = self.fp_acts(runtime, bp, i)?;
            self.x_fp[i] = acts.out;
        }
        Ok(())
    }

    /// Advance the quantized stream past a block with final qparams.
    #[allow(clippy::too_many_arguments)]
    pub fn advance_q(
        &mut self,
        runtime: &Runtime,
        bp: &ParamStore,
        bqp: &ParamStore,
        rank: usize,
        group: usize,
        bits: f32,
        scale: f32,
    ) -> Result<()> {
        for i in 0..self.x_q.len() {
            let acts = self.q_acts(runtime, bp, bqp, i, rank, group, bits, scale)?;
            self.x_q[i] = acts.out;
        }
        Ok(())
    }

    /// Mirror the fp stream into the q stream (used by weight-error-only
    /// baselines whose "quantized stream" is the fp one).
    pub fn sync_q_to_fp(&mut self) {
        self.x_q = self.x_fp.clone();
    }

    pub fn n_batches(&self) -> usize {
        self.x_fp.len()
    }
}
