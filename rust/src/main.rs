//! `repro` — the ApiQ reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands mirror the experiment pipeline stages:
//!
//!   repro pretrain  --size small --steps 300
//!   repro quantize  --size small --method apiq-bw --bits 2
//!   repro eval      --size small --method apiq-bw --bits 2
//!   repro finetune  --size small --method apiq-bw --bits 2 --data corpus
//!   repro report memory
//!   repro artifacts
//!
//! The per-paper-table drivers live in `examples/` (see DESIGN.md §5).

use std::sync::Arc;

use repro::benchharness::Bench;
use repro::config::args::Args;
use repro::data::tasks::{ArithTask, ClassifyTask};
use repro::data::{Batcher, ZipfMarkovCorpus};
use repro::infer::{generate_greedy, PackedModel};
use repro::kernels;
use repro::metrics::{MemoryModel, TableBuilder};
use repro::model::{checkpoint, ModelConfig, ParamStore};
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::quant::{PackedLinear, QuantSpec};
use repro::tensor::Tensor;
use repro::quantizers::{by_name, QuantResult, QuantizeCtx, Quantizer};
use repro::serve::decode::{generate, generate_recompute};
use repro::serve::loadgen::{run_load, LoadOptions};
use repro::serve::{SamplingParams, SchedConfig, ServeOptions};
use repro::tensor::Rng;
use repro::train::{FinetuneData, LoraPosition, Pretrainer};

const USAGE: &str = "\
repro — ApiQ (EMNLP 2024) reproduction coordinator

USAGE: repro <command> [--flags]

COMMANDS
  pretrain   --size S --steps N                      pretrain + save checkpoint
  quantize   --size S --method M --bits B            quantize, save qparams
  eval       --size S --method M --bits B            PTQ perplexity vs fp
  finetune   --size S --method M --bits B --data D   quantize + adapter finetune
  generate   --size S --method M --bits B            native KV-cached decoding
                                                     (no artifacts required)
  bench-infer --size S --bits B                      native packed-vs-dense
                                                     inference benchmark
  bench-gemm --size S --bits B [--require-simd]      kernel microbench: dense
                                                     GEMM + fused dequant
                                                     GFLOP/s per layer shape;
                                                     --require-simd fails when
                                                     the dispatcher runs scalar
  pack-ckpt  --size S --method M --bits B [--out P]  save the 2-bit serving
                                                     payload (packed codes +
                                                     scales + zeros + adapters)
  pack-adapter --size S --method M [--name N]        save the adapter-ONLY
               [--out P]                             sidecar (APIQADPT) for
                                                     multi-adapter serving
  serve      [--packed P | --size S --method M]      long-lived token server
                                                     (newline-JSON over TCP,
                                                     continuous batching)
  bench-serve --addr A --clients N                   concurrent load generator
                                                     against a running server
  bench-kv   --size S --method M                     paged-KV perplexity +
                                                     throughput + memory sweep
                                                     across kv-bits {16,8,4};
                                                     merges a `kv_quant`
                                                     section into
                                                     BENCH_serve.json
  trace-report --trace P                             summarize a serve
                                                     --trace-log tick journal
  report     memory|params                           analytic reports
  artifacts                                          list compiled artifacts

COMMON FLAGS
  --artifacts DIR   (default: artifacts)
  --seed N          (default: 17)
  --rank R          (default: 16)      --group G     (default: 64)
  --pretrain-steps N (default: 300)

GENERATE / BENCH-INFER FLAGS
  --new-tokens N    (default: 32)      --prompt-len N (default: 16)
  --gen-batch N     (default: 4)       --packed P     (generate: load payload)
  --temperature T   (default: 0 = greedy; generate only)
  --top-k K / --top-p P                sampling filters (with --temperature)

SERVE FLAGS
  --addr A          (default: 127.0.0.1:7878; port 0 = ephemeral)
  --max-batch N     (default: 8)       --max-new-cap N (default: 512)
  --max-prompt N    (default: 1024)    --no-remote-shutdown
  --kv-block N      (default: 32)      KV page size in positions
  --kv-blocks-total N (default: auto)  KV page budget; admission backs
                                       off when the pool is exhausted
  --kv-bits B       (default: 16)      KV page storage width: 16 = f32
                                       (the bitwise oracle), 8 or 4 =
                                       group-wise affine-quantized
                                       sealed pages (~4x/8x more
                                       sequences per block budget; see
                                       README \"KV memory\")
  --kv-mem-mb MB                       derive --kv-blocks-total from a
                                       memory budget in MB at the active
                                       layout's block size (rejects an
                                       explicit --kv-blocks-total)
  --kv-spill PATH                      second KV tier: spill pages to an
                                       append/recycle file instead of
                                       rejecting under block exhaustion;
                                       also enables session
                                       suspend/resume over the wire
                                       (README \"Tiered KV\")
  --kv-spill-blocks N (default: 0 = unbounded)  spill-slot budget
  --prefix-store                       content-keyed persistent prefix
                                       pages: admissions whose prompt
                                       matches a stored prefix fork from
                                       disk instead of re-prefilling
                                       (needs --kv-spill)
  --speculate K     (default: 0 = off) speculative decoding: draft K
                                       tokens/cycle, verify in one pass;
                                       output bits are unchanged
  --draft-layers N  (default: half)    self-draft = first N layers of
                                       the serving model
  --draft-config P                     draft from a packed checkpoint
                                       (must share the vocab)
  --draft-kv-blocks-total N (default: auto) draft-side KV page budget
  --adapter NAME=PATH                  register a packed adapter sidecar
                                       at boot (repeatable); requests
                                       route with \"adapter\":\"NAME\"
  --metrics-addr A                     serve Prometheus text exposition
                                       at GET /metrics on this address
                                       (port 0 = ephemeral; bound addr
                                       is printed as `serve: metrics on`)
  --trace-log P                        append one JSON line per
                                       scheduler tick (trace-report
                                       summarizes it)
  --trace-cap N     (default: 1024)    in-memory tick-trace ring size
                                       (the {\"cmd\":\"trace\"} window)
  --profile                            per-kernel time/GFLOP/s + pool
                                       lane accounting (also REPRO_PROF=1);
                                       output bits are unchanged
  --max-pending N   (default: 1024; 0 = unbounded)  admission-queue
                                       bound; submissions past it are
                                       refused with an `overloaded`
                                       error frame + retry_after_ms
  --deadline-ms N   (default: 0 = off)  default per-request deadline;
                                       requests that outlive it finish
                                       with \"finish\":\"deadline\"
                                       (a request's own deadline_ms
                                       field overrides the default)
  --out-queue N     (default: 1024)    per-connection output queue in
                                       frames; overflow spills to an
                                       engine-side backlog
  --slow-reader-ms N (default: 2000)   evict a connection whose output
                                       has stalled this long; its
                                       sequences are cancelled and
                                       their KV pages reclaimed
  --max-line N      (default: 1048576) request-line byte cap; longer
                                       lines get a bad_request frame
  --fault SPEC                         deterministic fault injection:
                                       point:rate:seed clauses (also
                                       REPRO_FAULT; see README
                                       \"Fault tolerance\")
BENCH-SERVE FLAGS
  --clients N       (default: 4)      --requests N    (per client, default 2)
  --common-prefix N (default: 0)      first N prompt tokens identical
                                      across ALL requests (KV sharing)
  --adapter-mix A:B:...                round-robin client i -> adapter
                                       (\"-\" = baseline, no adapter)
  --churn-adapter NAME=PATH            load/unload NAME mid-run over a
                                       side connection (registry churn)
  --sample-ms N     (default: 50; 0 = off) poll {\"cmd\":\"stats\"} mid-run
                    every N ms: batch-size / queue / KV-occupancy series
  --bench-out P     (default: BENCH_serve.json)
  --transcript P    (write sorted per-request token transcripts —
                     byte-comparable across runs/speculation settings)
  --shutdown        (send {\"cmd\":\"shutdown\"} when done)
  --deadline-ms N   (default: 0 = none) attach deadline_ms to every
                                       request
  --request-timeout-ms N (default: 0)  client-side socket read timeout
  --retries N       (default: 4)       per-request retry budget for
                                       overloaded / transport errors
  --sessions N      (default: 0)       session clients: stream half the
                                       token budget under a \"session\"
                                       id, hang up, rejoin after
                                       --rejoin-ms and continue from the
                                       server's parked KV; resume
                                       latency + zero-re-prefill counts
                                       land in the JSON
  --rejoin-ms N     (default: 100)     session disconnect gap before the
                                       rejoin
  --allow-failures  exit 0 even when some requests end rejected or
                    failed (every request must still reach a terminal
                    outcome — used by the CI chaos job)
BENCH-KV FLAGS
  --streams N       (default: 4)       independent token streams
  --stream-len N    (default: 256)     tokens per stream
  --chunk N         (default: 32)      teacher-forcing chunk; committed
                                       pages seal at chunk boundaries
  --kv-block N      (default: 16)      KV page size in positions
  --kv-bits B       (only B instead of the full {16,8,4} sweep)
  --bench-out P     (default: BENCH_serve.json)

METHODS: rtn qlora gptq awq loftq omniquant apiq-lw apiq-bw apiq-bw-dora
(generate also accepts `fp`; calibration-based methods need the artifact
runtime, so generate/serve/pack-ckpt support fp/rtn/qlora/loftq out of
the box — or serve any method from a saved --packed payload)
";

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.command.is_empty() || args.flag("help") {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> repro::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let seed = args.u64_or("seed", 17)?;
    let rank = args.usize_or("rank", DEFAULT_RANK)?;
    let group = args.usize_or("group", DEFAULT_GROUP)?;
    let bits = args.u32_or("bits", 2)?;
    let size = args.str_or("size", "tiny");
    let method = args.str_or("method", "apiq-bw");
    let pretrain_steps = args.usize_or("pretrain-steps", 300)?;

    match args.command.as_str() {
        "pretrain" => {
            let steps = args.usize_or("steps", 300)?;
            let runtime = repro::runtime::Runtime::new(&artifacts)?;
            let cfg = ModelConfig::by_name(&size)?;
            let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed);
            let mut params = cfg.init_params(seed);
            let trainer = Pretrainer::new(&runtime, cfg, steps);
            let report = trainer.train(&mut params, &corpus, steps, seed ^ 0x7EA1)?;
            let path = checkpoint::pretrained_path(cfg.name, steps, seed);
            checkpoint::save(&params, &path)?;
            println!(
                "pretrained {} for {} steps: loss {:.4} -> {:.4} ({:.1}s); saved {}",
                cfg.name,
                steps,
                report.losses.first().copied().unwrap_or(f32::NAN),
                report.tail_mean(10),
                report.wall_secs,
                path.display()
            );
        }
        "quantize" => {
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let r = env.quantize(&method, bits, group, rank)?;
            let path = format!("checkpoints/qparams_{size}_{method}_{bits}b_r{rank}_g{group}.ckpt");
            checkpoint::save(&r.qparams, &path)?;
            println!(
                "quantized {size} with {method} at {bits}-bit in {:.1}s; qparams -> {path}",
                r.wall_secs
            );
        }
        "eval" => {
            let eval_batches = args.usize_or("eval-batches", 8)?;
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let fp = env.ppl_fp(eval_batches)?;
            let r = env.quantize(&method, bits, group, rank)?;
            let q = env.ppl(&r, rank, group, eval_batches)?;
            let mut t = TableBuilder::new(format!("PTQ perplexity ({size}, {bits}-bit, g{group})"))
                .header(&["model", "ppl"]);
            t.row(vec!["fp32".into(), TableBuilder::num(fp)]);
            t.row(vec![method.clone(), TableBuilder::num(q)]);
            println!("{}", t.markdown());
        }
        "finetune" => {
            let data = args.str_or("data", "corpus");
            let steps = args.usize_or("steps", 100)?;
            let lr = args.f32_or("lr", 1e-3)?;
            let position = args.str_or("position", "all");
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let mut r = env.quantize(&method, bits, group, rank)?;
            let arith = ArithTask::add(env.cfg.vocab, seed ^ 0xA17);
            let clf = ClassifyTask::new(env.cfg.vocab, 3, seed ^ 0xC1F);
            let ft_data = match data.as_str() {
                "arith" => FinetuneData::Task(&arith),
                "classify" => FinetuneData::Task(&clf),
                _ => FinetuneData::Corpus(&env.corpus),
            };
            let pos = LoraPosition::parse(&position);
            let report = env.finetune(&mut r, rank, group, &ft_data, steps, lr, pos)?;
            let ppl = env.ppl(&r, rank, group, 8)?;
            println!(
                "finetuned {method} {bits}-bit on {data} for {steps} steps (loss {:.4} -> {:.4}); eval ppl {:.3}",
                report.losses.first().copied().unwrap_or(f32::NAN),
                report.tail_mean(10),
                ppl
            );
            if data == "arith" {
                let acc = env.task_accuracy(&r, rank, group, &arith, 8, false)?;
                println!("arith accuracy: {:.1}%", acc * 100.0);
            }
        }
        "generate" => {
            let new_tokens = args.usize_or("new-tokens", 32)?;
            let prompt_len = args.usize_or("prompt-len", 16)?.max(1);
            let gen_batch = args.usize_or("gen-batch", 4)?.max(1);
            let model = match args.get("packed") {
                Some(path) => {
                    eprintln!("[generate] loading packed checkpoint {path}");
                    checkpoint::load_packed(path)?
                }
                None => {
                    let cfg = ModelConfig::by_name(&size)?;
                    let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
                    build_native_model(&artifacts, cfg, &params, &method, bits, group, rank, seed)?
                }
            };
            let cfg = model.cfg;
            let temperature = args.f32_or("temperature", 0.0)?;
            let top_k = args.usize_or("top-k", 0)?;
            let top_p = args.f32_or("top-p", 1.0)?;
            let sampling = (temperature > 0.0)
                .then_some(SamplingParams { temperature, top_k, top_p, seed });
            let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed ^ 0x6E6);
            let prompt = Batcher::new(gen_batch, prompt_len)
                .lm_batch(&corpus, &mut Rng::new(seed ^ 0x9E77))
                .tokens;
            let report = generate(&model, &prompt, new_tokens, sampling.as_ref())?;
            for (i, row) in report.tokens.iter().enumerate().take(2) {
                let (p, g) = row.split_at(report.prompt_len);
                println!(
                    "seq {i}: prompt {:?} -> generated {:?}",
                    &p[..p.len().min(8)],
                    g
                );
            }
            println!(
                "generated {} x {} tokens in {:.3}s — {:.1} tokens/s",
                gen_batch, new_tokens, report.wall_secs,
                report.tokens_per_sec()
            );
            println!(
                "resident weights: {:.2} MB measured ({:.3} effective bits/weight); \
                 analytic model: {:.2} MB",
                report_resident_mb(&model),
                model.effective_bits(),
                analytic_resident_mb(&cfg, &model, rank),
            );
        }
        "bench-infer" => {
            let cfg = ModelConfig::by_name(&size)?;
            let new_tokens = args.usize_or("new-tokens", 32)?;
            let prompt_len = args.usize_or("prompt-len", 16)?.max(1);
            let gen_batch = args.usize_or("gen-batch", 4)?.max(1);
            let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
            let packed = build_native_model(
                &artifacts, cfg, &params, "rtn", bits, group, rank, seed,
            )?;
            let dense = PackedModel::build(cfg, &params, None, QuantSpec::new(16, group), 1.0)?;
            let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed ^ 0x6E6);
            let prompt = Batcher::new(gen_batch, prompt_len)
                .lm_batch(&corpus, &mut Rng::new(seed ^ 0x9E77))
                .tokens;
            let prefill_toks = (gen_batch * prompt_len) as f64;
            let mut bench = Bench::new();
            let packed_mean = bench
                .run("prefill_packed", 1, 5, || {
                    std::hint::black_box(packed.logits(&prompt).unwrap());
                })
                .mean_s;
            bench.note(format!("packed prefill: {:.0} tokens/s", prefill_toks / packed_mean));
            let dense_mean = bench
                .run("prefill_dense_fp", 1, 5, || {
                    std::hint::black_box(dense.logits(&prompt).unwrap());
                })
                .mean_s;
            bench.note(format!("dense fp prefill: {:.0} tokens/s", prefill_toks / dense_mean));
            let rep = generate_greedy(&packed, &prompt, new_tokens)?;
            let cached_tps = rep.tokens_per_sec();
            bench.note(format!(
                "packed KV-cached greedy decode ({gen_batch} x {new_tokens}): {cached_tps:.1} tokens/s"
            ));
            let rep = generate_recompute(&packed, &prompt, new_tokens, None)?;
            bench.note(format!(
                "packed full-recompute decode ({gen_batch} x {new_tokens}): {:.1} tokens/s \
                 ({:.2}x speedup from the KV cache)",
                rep.tokens_per_sec(),
                cached_tps / rep.tokens_per_sec().max(1e-9)
            ));
            let rep = generate_greedy(&dense, &prompt, new_tokens)?;
            bench.note(format!(
                "dense fp greedy decode ({gen_batch} x {new_tokens}): {:.1} tokens/s",
                rep.tokens_per_sec()
            ));
            bench.note(format!(
                "resident: packed {:.2} MB ({:.3} bits/weight) vs dense {:.2} MB",
                report_resident_mb(&packed),
                packed.effective_bits(),
                report_resident_mb(&dense),
            ));
            bench.finish("bench-infer");
        }
        "bench-gemm" => {
            let cfg = ModelConfig::by_name(&size)?;
            let prefill_rows = args.usize_or("prefill-rows", 16)?.max(1);
            println!(
                "kernel: {} (simd_supported: {}), threads: {}",
                kernels::active().name(),
                kernels::simd_supported(),
                kernels::pool::pool_threads()
            );
            if args.flag("require-simd") && kernels::active() != kernels::Kernel::Avx2 {
                return Err(repro::Error::config(format!(
                    "--require-simd: dispatcher selected '{}' (simd_supported: {}) — \
                     refusing to run the scalar kernel on a SIMD-capable runner",
                    kernels::active().name(),
                    kernels::simd_supported()
                )));
            }
            let spec = QuantSpec::new(bits.clamp(1, 8), group);
            let mut bench = Bench::new();
            let shapes = [
                ("attn_proj", cfg.d_model, cfg.d_model),
                ("ffn_up", cfg.d_model, cfg.d_ffn),
                ("ffn_down", cfg.d_ffn, cfg.d_model),
                ("lm_head", cfg.d_model, cfg.vocab),
            ];
            for (label, d_in, d_out) in shapes {
                let pl = random_packed(d_in, d_out, spec, seed)?;
                for rows in [1usize, prefill_rows] {
                    let x = Tensor::randn(&[rows, d_in], 1.0, &mut Rng::new(seed ^ 0xBE7));
                    let flops = (2 * rows * d_in * d_out) as f64;
                    let iters = if rows == 1 { 20 } else { 5 };
                    let mean = bench
                        .run(&format!("fused_{label}_{rows}tok"), 2, iters, || {
                            let y = if rows <= PackedLinear::MATVEC_MAX_ROWS {
                                pl.matvec_fused(&x).unwrap()
                            } else {
                                pl.matmul_fused(&x).unwrap()
                            };
                            std::hint::black_box(y);
                        })
                        .mean_s;
                    bench.note(format!(
                        "fused {label} ({rows} x {d_in} x {d_out}, {}-bit): {:.2} GFLOP/s",
                        spec.bits,
                        flops / mean / 1e9
                    ));
                }
                let w = Tensor::randn(&[d_in, d_out], 0.1, &mut Rng::new(seed ^ 0xD3));
                let x = Tensor::randn(&[prefill_rows, d_in], 1.0, &mut Rng::new(seed ^ 0xE4));
                let flops = (2 * prefill_rows * d_in * d_out) as f64;
                let mean = bench
                    .run(&format!("dense_{label}_{prefill_rows}tok"), 2, 5, || {
                        std::hint::black_box(x.matmul(&w).unwrap());
                    })
                    .mean_s;
                bench.note(format!(
                    "dense {label} ({prefill_rows} x {d_in} x {d_out}): {:.2} GFLOP/s",
                    flops / mean / 1e9
                ));
            }
            bench.finish("bench-gemm");
        }
        "pack-ckpt" => {
            let cfg = ModelConfig::by_name(&size)?;
            let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
            let model = build_native_model(
                &artifacts, cfg, &params, &method, bits, group, rank, seed,
            )?;
            let out = match args.get("out") {
                Some(p) => std::path::PathBuf::from(p),
                None => checkpoint::packed_path(&size, &method, bits, group),
            };
            checkpoint::save_packed(&model, &out)?;
            println!(
                "packed {size}/{method} {bits}-bit -> {} ({:.2} MB serving payload, \
                 {:.3} bits/weight)",
                out.display(),
                report_resident_mb(&model),
                model.effective_bits()
            );
        }
        "pack-adapter" => {
            let cfg = ModelConfig::by_name(&size)?;
            let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
            let model = build_native_model(
                &artifacts, cfg, &params, &method, bits, group, rank, seed,
            )?;
            let set = model.default_adapter.as_deref().ok_or_else(|| {
                repro::Error::config(format!(
                    "method '{method}' carries no adapters — pack-adapter wants an \
                     adapter-bearing method (e.g. qlora or loftq)"
                ))
            })?;
            let mut set = set.clone();
            set.name = args.str_or("name", &format!("{method}-r{rank}"));
            let out = match args.get("out") {
                Some(p) => std::path::PathBuf::from(p),
                None => checkpoint::adapter_path(&size, &method, rank, seed),
            };
            checkpoint::save_adapter(&set, model.cfg.name, &out)?;
            println!(
                "packed adapter '{}' for base {} (rank {}, {} adapted linears, \
                 {:.2} MB) -> {}",
                set.name,
                model.cfg.name,
                set.rank(),
                set.n_adapted(),
                set.resident_bytes() as f64 / 1e6,
                out.display()
            );
        }
        "serve" => {
            let addr = args.str_or("addr", "127.0.0.1:7878");
            let mut sched = SchedConfig {
                max_batch: args.usize_or("max-batch", 8)?.max(1),
                max_new_cap: args.usize_or("max-new-cap", 512)?.max(1),
                max_prompt: args.usize_or("max-prompt", 1024)?.max(1),
                kv_block: args.usize_or("kv-block", 32)?.max(1),
                kv_blocks_total: args.usize_or("kv-blocks-total", 0)?,
                speculate: args.usize_or("speculate", 0)?,
                draft_kv_blocks_total: args.usize_or("draft-kv-blocks-total", 0)?,
                max_pending: args.usize_or("max-pending", 1024)?,
                deadline_ms: args.u64_or("deadline-ms", 0)?,
                kv_bits: parse_kv_bits(&args)?,
            };
            let model = match args.get("packed") {
                Some(path) => {
                    eprintln!("[serve] loading packed checkpoint {path}");
                    checkpoint::load_packed(path)?
                }
                None => {
                    let cfg = ModelConfig::by_name(&size)?;
                    let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
                    build_native_model(&artifacts, cfg, &params, &method, bits, group, rank, seed)?
                }
            };
            let draft = if sched.speculate > 0 {
                let d = match args.get("draft-config") {
                    Some(path) => {
                        eprintln!("[serve] loading draft checkpoint {path}");
                        checkpoint::load_packed(path)?
                    }
                    None => {
                        let n = args
                            .usize_or("draft-layers", (model.cfg.n_layers / 2).max(1))?
                            .max(1);
                        model.prefix_cut(n)?
                    }
                };
                if d.cfg.vocab != model.cfg.vocab {
                    return Err(repro::Error::config(format!(
                        "draft vocab {} != target vocab {} — the draft must share the \
                         tokenizer/vocabulary",
                        d.cfg.vocab, model.cfg.vocab
                    )));
                }
                println!(
                    "serve: speculative decoding: k={} per cycle, draft {} ({} layers, \
                     {:.2} MB resident); emitted streams are bit-identical to --speculate 0",
                    sched.speculate,
                    d.cfg.name,
                    d.cfg.n_layers,
                    report_resident_mb(&d)
                );
                Some(Arc::new(d))
            } else {
                None
            };
            // Same formula the pool reports in stats frames (sealed size
            // under a quantized layout).
            let cfg_ref = &model.cfg;
            let probe = repro::serve::BlockPool::with_layout(
                cfg_ref.n_layers,
                cfg_ref.d_model,
                sched.kv_block,
                0,
                sched.kv_layout(cfg_ref.d_model / cfg_ref.n_heads),
            );
            let kv_block_bytes = probe.block_bytes();
            if args.get("kv-mem-mb").is_some() {
                if args.get("kv-blocks-total").is_some() {
                    return Err(repro::Error::config(
                        "--kv-mem-mb and --kv-blocks-total both set the KV budget; \
                         pass only one",
                    ));
                }
                let mb = args.f32_or("kv-mem-mb", 0.0)?;
                if mb <= 0.0 {
                    return Err(repro::Error::config(format!(
                        "--kv-mem-mb {mb}: wants a positive megabyte budget"
                    )));
                }
                sched.kv_blocks_total =
                    (((mb as f64) * 1e6 / kv_block_bytes as f64).floor() as usize).max(1);
                println!(
                    "serve: --kv-mem-mb {mb}: {} blocks of {} bytes at the active KV layout",
                    sched.kv_blocks_total, kv_block_bytes
                );
            }
            println!(
                "serve: model {} ({:.2} MB resident, {:.3} bits/weight), max batch {}",
                model.cfg.name,
                report_resident_mb(&model),
                model.effective_bits(),
                sched.max_batch
            );
            println!(
                "serve: paged KV: {} blocks x {} positions ({:.2} MB ceiling, prefix \
                 sharing + on-demand growth)",
                sched.blocks_total(),
                sched.kv_block,
                (sched.blocks_total() * kv_block_bytes) as f64 / 1e6
            );
            if sched.kv_bits != 16 {
                println!(
                    "serve: quantized KV pages: {}-bit group-wise affine (sealed pages \
                     {:.2}x f32; 16-bit stays the bitwise oracle)",
                    sched.kv_bits,
                    kv_block_bytes as f64 / probe.f32_block_bytes() as f64
                );
            }
            let adapters = args
                .all("adapter")
                .into_iter()
                .map(|spec| {
                    spec.split_once('=')
                        .map(|(n, p)| (n.to_string(), p.to_string()))
                        .ok_or_else(|| {
                            repro::Error::config(format!(
                                "--adapter '{spec}': expected NAME=PATH"
                            ))
                        })
                })
                .collect::<repro::Result<Vec<_>>>()?;
            let opts = ServeOptions {
                addr,
                sched,
                allow_remote_shutdown: !args.flag("no-remote-shutdown"),
                adapters,
                metrics_addr: args.get("metrics-addr").map(String::from),
                trace_log: args.get("trace-log").map(String::from),
                profile: args.flag("profile"),
                trace_cap: args.usize_or("trace-cap", repro::obs::DEFAULT_TRACE_CAP)?.max(1),
                fault: args.get("fault").map(String::from),
                max_line: args
                    .usize_or("max-line", repro::serve::server::DEFAULT_MAX_LINE)?
                    .max(1),
                out_queue: args
                    .usize_or("out-queue", repro::serve::server::DEFAULT_OUT_QUEUE)?
                    .max(1),
                slow_reader_ms: args
                    .u64_or("slow-reader-ms", repro::serve::server::DEFAULT_SLOW_READER_MS)?,
                kv_spill: args.get("kv-spill").map(String::from),
                kv_spill_blocks: args.usize_or("kv-spill-blocks", 0)?,
                prefix_store: args.flag("prefix-store"),
            };
            repro::serve::server::run(Arc::new(model), draft, opts)?;
        }
        "bench-serve" => {
            let o = LoadOptions {
                addr: args.str_or("addr", "127.0.0.1:7878"),
                clients: args.usize_or("clients", 4)?.max(1),
                requests_per_client: args.usize_or("requests", 2)?.max(1),
                prompt_len: args.usize_or("prompt-len", 16)?.max(1),
                max_new: args.usize_or("new-tokens", 32)?.max(1),
                vocab: ModelConfig::by_name(&size)?.vocab,
                common_prefix: args.usize_or("common-prefix", 0)?,
                temperature: args.f32_or("temperature", 0.0)?,
                seed,
                shutdown_after: args.flag("shutdown"),
                transcript: args.get("transcript").map(String::from),
                adapter_mix: args
                    .get("adapter-mix")
                    .map(|m| {
                        m.split(':')
                            .filter(|s| !s.is_empty())
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_default(),
                churn_adapter: match args.get("churn-adapter") {
                    Some(spec) => Some(
                        spec.split_once('=')
                            .map(|(n, p)| (n.to_string(), p.to_string()))
                            .ok_or_else(|| {
                                repro::Error::config(format!(
                                    "--churn-adapter '{spec}': expected NAME=PATH"
                                ))
                            })?,
                    ),
                    None => None,
                },
                sample_ms: args.u64_or("sample-ms", 50)?,
                deadline_ms: args.u64_or("deadline-ms", 0)?,
                request_timeout_ms: args.u64_or("request-timeout-ms", 0)?,
                max_retries: args.usize_or("retries", 4)?,
                sessions: args.usize_or("sessions", 0)?,
                rejoin_ms: args.u64_or("rejoin-ms", 100)?,
            };
            let rep = run_load(&o)?;
            println!(
                "bench-serve: {}/{} requests completed, {} tokens in {:.2}s \
                 ({:.1} tokens/s aggregate)",
                rep.completed,
                rep.requests,
                rep.total_tokens,
                rep.wall_secs,
                rep.tokens_per_sec()
            );
            if rep.rejected + rep.deadline + rep.retried + rep.failed > 0 {
                println!(
                    "  robustness: {} rejected (overloaded), {} deadline, {} retried, {} failed",
                    rep.rejected, rep.deadline, rep.retried, rep.failed
                );
            }
            println!("  time-to-first-token: {}", rep.ttft.fmt_ms());
            println!("  request latency:     {}", rep.total.fmt_ms());
            println!("  peak concurrent streams: {}", rep.peak_concurrent_streams);
            if let Some(kv) = &rep.kv {
                println!(
                    "  peak resident KV: {} blocks of {} ({:.2} MB)",
                    kv.peak_resident_blocks,
                    kv.block_size,
                    kv.peak_resident_bytes as f64 / 1e6
                );
                println!("  peak shared blocks: {}", kv.peak_shared_blocks);
                if kv.kv_bits != 0 && kv.kv_bits != 16 {
                    println!(
                        "  quantized KV: {}-bit pages, peak resident {:.3}x the f32 cost",
                        kv.kv_bits,
                        kv.peak_resident_ratio()
                    );
                }
            }
            if let Some(s) = &rep.spec {
                println!(
                    "  spec: k={} accepted {} of {} proposed ({:.1}% acceptance), \
                     {} cycles, {} fallbacks, peak draft KV {} blocks",
                    s.k,
                    s.accepted,
                    s.proposed,
                    s.acceptance() * 100.0,
                    s.cycles,
                    s.fallbacks,
                    s.draft_peak_resident_blocks
                );
            }
            if o.sessions > 0 {
                println!(
                    "  sessions: {}/{} resumed ({} with zero re-prefill), \
                     resume time-to-first-token: {}",
                    rep.sessions_resumed,
                    o.sessions,
                    rep.resume_zero_prefill,
                    rep.resume_latency.fmt_ms()
                );
            }
            if let Some(t) = &rep.tier {
                println!(
                    "  tier: {} blocks on disk ({:.2} MB), {} preemptions / {} resumes, \
                     {} session resumes, {} restore failures",
                    t.spilled_blocks,
                    t.spilled_bytes as f64 / 1e6,
                    t.preemptions,
                    t.resumes,
                    t.session_resumes,
                    t.restore_failures
                );
                if t.prefix_hits + t.prefix_misses > 0 {
                    println!(
                        "  prefix store: {} pages, {} hits / {} misses ({:.1}% hit rate), \
                         {} promotes",
                        t.prefix_pages,
                        t.prefix_hits,
                        t.prefix_misses,
                        t.prefix_hit_rate() * 100.0,
                        t.promotes
                    );
                }
            }
            if !rep.tokens_by_route.is_empty() && !o.adapter_mix.is_empty() {
                for (route, toks) in &rep.tokens_by_route {
                    println!(
                        "  route {route}: {toks} tokens ({:.1} tokens/s)",
                        *toks as f64 / rep.wall_secs.max(1e-9)
                    );
                }
            }
            for a in &rep.adapters {
                println!(
                    "  adapter {}: rank {}, {} server-counted tokens, \
                     delta-GEMM overhead {:.2}% of base FLOPs",
                    a.name,
                    a.rank,
                    a.tokens,
                    a.delta_overhead * 100.0
                );
            }
            if !rep.adapters.is_empty() || rep.baseline_tokens > 0 {
                println!("  baseline (no-adapter) tokens: {}", rep.baseline_tokens);
            }
            if o.churn_adapter.is_some() {
                println!("  adapter churn: {} load/unload cycles mid-run", rep.churn_cycles);
            }
            if !rep.samples.is_empty() {
                println!(
                    "  sampled every {}ms ({} polls): batch peak {} / p50 {}, \
                     peak KV occupancy {:.1}%",
                    o.sample_ms,
                    rep.samples.len(),
                    rep.batch_peak(),
                    rep.batch_p50(),
                    rep.kv_occupancy_peak() * 100.0
                );
            }
            if let Some(path) = &o.transcript {
                println!("  wrote transcript {path}");
            }
            let out = args.str_or("bench-out", "BENCH_serve.json");
            write_bench_serve(&out, &o, &rep)?;
            println!("  wrote {out}");
            // `deadline` double-counts streams that finished with
            // "finish":"deadline" (they are also `completed`), so this is
            // a >=-style terminality check, not an exact partition.
            let terminal = rep.completed + rep.rejected + rep.failed + rep.deadline;
            if terminal < rep.requests {
                return Err(repro::Error::config(format!(
                    "{} of {} requests never reached a terminal outcome",
                    rep.requests - terminal,
                    rep.requests
                )));
            }
            if rep.completed != rep.requests && !args.flag("allow-failures") {
                return Err(repro::Error::config(format!(
                    "{} of {} requests did not complete (rejected {}, deadline {}, \
                     failed {}) — pass --allow-failures to accept terminal \
                     non-completion",
                    rep.requests - rep.completed,
                    rep.requests,
                    rep.rejected,
                    rep.deadline,
                    rep.failed
                )));
            }
        }
        "bench-kv" => {
            use repro::eval::ppl::perplexity_paged;
            use repro::serve::json::Json;
            use repro::serve::KvLayout;
            let cfg = ModelConfig::by_name(&size)?;
            let params = load_or_init_params(&cfg, pretrain_steps, seed)?;
            let model =
                build_native_model(&artifacts, cfg, &params, &method, bits, group, rank, seed)?;
            let n_streams = args.usize_or("streams", 4)?.max(1);
            let stream_len = args.usize_or("stream-len", 256)?.max(2);
            let chunk = args.usize_or("chunk", 32)?.max(1);
            let kv_block = args.usize_or("kv-block", 16)?.max(1);
            let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed ^ 0x5EED);
            let mut rng = Rng::new(seed ^ 0xBE9C);
            let streams: Vec<Vec<i32>> = (0..n_streams)
                .map(|_| {
                    Batcher::new(1, stream_len)
                        .lm_batch(&corpus, &mut rng)
                        .tokens
                        .data()
                        .to_vec()
                })
                .collect();
            // Streams run sequentially through one pool, so the budget
            // only has to cover a single stream (+1 for rounding).
            let blocks_total = stream_len.div_ceil(kv_block) + 1;
            let hd = cfg.d_model / cfg.n_heads;
            let sweep: Vec<u32> = match args.get("kv-bits") {
                Some(_) => vec![parse_kv_bits(&args)?],
                None => vec![16, 8, 4],
            };
            let total_preds: usize = streams.iter().map(|s| s.len() - 1).sum();
            let mut f32_ppl = f64::NAN;
            let mut f32_peak = 0usize;
            let mut entries: Vec<Json> = Vec::new();
            println!(
                "bench-kv: {} ({}), {} streams x {} tokens, chunk {}, page {}",
                cfg.name, method, n_streams, stream_len, chunk, kv_block
            );
            for kv_bits in sweep {
                let layout = match kv_bits {
                    16 => KvLayout::F32,
                    b => KvLayout::Quant { bits: b, group: hd },
                };
                let t0 = std::time::Instant::now();
                let (ppl, kv) =
                    perplexity_paged(&model, &streams, chunk, kv_block, blocks_total, layout)?;
                let secs = t0.elapsed().as_secs_f64().max(1e-9);
                let tps = total_preds as f64 / secs;
                if kv_bits == 16 {
                    f32_ppl = ppl;
                    f32_peak = kv.peak_resident_bytes;
                }
                // Single-bits runs have no in-run f32 baseline; report a
                // zero delta / unit ratio rather than NaN in the JSON.
                let delta = if f32_ppl.is_finite() { ppl - f32_ppl } else { 0.0 };
                let ratio = if f32_peak > 0 {
                    kv.peak_resident_bytes as f64 / f32_peak as f64
                } else {
                    1.0
                };
                println!(
                    "  kv-bits {kv_bits:>2}: ppl {ppl:.4} (delta {delta:+.4}), \
                     {tps:.0} tok/s, peak resident KV {} bytes ({ratio:.3}x f32)",
                    kv.peak_resident_bytes
                );
                entries.push(Json::Obj(vec![
                    ("kv_bits".to_string(), Json::from(kv_bits as usize)),
                    ("ppl".to_string(), Json::Num((ppl * 1e6).round() / 1e6)),
                    ("ppl_delta_vs_f32".to_string(), Json::Num((delta * 1e6).round() / 1e6)),
                    ("tokens_per_sec".to_string(), Json::Num((tps * 10.0).round() / 10.0)),
                    (
                        "peak_resident_kv_bytes".to_string(),
                        Json::from(kv.peak_resident_bytes),
                    ),
                    (
                        "resident_ratio_vs_f32".to_string(),
                        Json::Num((ratio * 1e4).round() / 1e4),
                    ),
                ]));
            }
            let out = args.str_or("bench-out", "BENCH_serve.json");
            merge_kv_quant_into_bench_serve(&out, entries)?;
            println!("  merged kv_quant section into {out}");
        }
        "trace-report" => {
            let path = args
                .get("trace")
                .map(String::from)
                .or_else(|| args.positionals.first().map(String::from))
                .ok_or_else(|| {
                    repro::Error::config("trace-report wants --trace PATH (a serve --trace-log file)")
                })?;
            run_trace_report(&path)?;
        }
        "report" => match args.positionals.first().map(String::as_str) {
            Some("memory") => print_memory_report(),
            Some("params") => print_param_report(),
            other => eprintln!("unknown report {other:?} (try: memory, params)"),
        },
        "artifacts" => {
            let dir = std::path::Path::new(&artifacts);
            let mut names: Vec<String> = std::fs::read_dir(dir)
                .map_err(|e| repro::Error::io(format!("{}: {e}", dir.display())))?
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    e.file_name()
                        .to_str()
                        .and_then(|n| n.strip_suffix(".hlo.txt").map(String::from))
                })
                .collect();
            names.sort();
            for n in &names {
                println!("{n}");
            }
            println!("{} artifacts", names.len());
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Load the pretrained checkpoint if one exists, else fall back to fresh
/// random init (so `generate`/`bench-infer` run on a clean checkout with
/// no artifacts and no pretraining — the output is then structurally
/// correct but linguistically untrained).
fn load_or_init_params(
    cfg: &ModelConfig,
    pretrain_steps: usize,
    seed: u64,
) -> repro::Result<ParamStore> {
    let ckpt = checkpoint::pretrained_path(cfg.name, pretrain_steps, seed);
    if ckpt.exists() {
        eprintln!("[generate] loading checkpoint {}", ckpt.display());
        checkpoint::load(&ckpt)
    } else {
        eprintln!(
            "[generate] no checkpoint at {} — using random init (run `repro pretrain` \
             with the xla feature for a trained model)",
            ckpt.display()
        );
        Ok(cfg.init_params(seed))
    }
}

/// Quantize host-side and build the native serving model.  Only methods
/// that need no calibration activations run without the artifact runtime.
#[allow(clippy::too_many_arguments)]
fn build_native_model(
    artifacts: &str,
    cfg: ModelConfig,
    params: &ParamStore,
    method: &str,
    bits: u32,
    group: usize,
    rank: usize,
    seed: u64,
) -> repro::Result<PackedModel> {
    if method == "fp" {
        return PackedModel::build(cfg, params, None, QuantSpec::new(16, group), 1.0);
    }
    if !matches!(method, "rtn" | "qlora" | "loftq") {
        return Err(repro::Error::config(format!(
            "method '{method}' needs the artifact runtime for calibration; \
             use one of fp/rtn/qlora/loftq, or run `repro quantize` with --features xla \
             and serve the saved qparams"
        )));
    }
    let runtime = repro::runtime::Runtime::new(artifacts)?;
    let ctx = QuantizeCtx {
        runtime: &runtime,
        cfg,
        params,
        spec: QuantSpec::new(bits, group),
        rank,
        scale: 1.0,
        calib: &[],
        seed,
        verbose: false,
    };
    let r: QuantResult = by_name(method)?.run(&ctx)?;
    PackedModel::from_quant_result(cfg, &r, group, 1.0)
}

/// Synthetic packed layer for the kernel microbench: random codes +
/// small random scales, mid-range zero-points.
fn random_packed(
    d_in: usize,
    d_out: usize,
    spec: QuantSpec,
    seed: u64,
) -> repro::Result<PackedLinear> {
    if spec.group == 0 || d_in % spec.group != 0 {
        return Err(repro::Error::config(format!(
            "bench-gemm: group {} must divide d_in {d_in}",
            spec.group
        )));
    }
    let mut rng = Rng::new(seed);
    let mask = (1u32 << spec.bits) - 1;
    let codes: Vec<u32> = (0..d_in * d_out).map(|_| rng.next_u64() as u32 & mask).collect();
    let n_groups = d_in / spec.group;
    let scales = Tensor::randn(&[n_groups, d_out], 0.01, &mut rng);
    let zeros = Tensor::full(&[n_groups, d_out], (mask / 2) as f32);
    PackedLinear::from_codes(&codes, scales, zeros, d_in, d_out, spec)
}

fn report_resident_mb(model: &PackedModel) -> f64 {
    model.resident_bytes() as f64 / 1e6
}

/// Machine-readable serving trajectory artifact: throughput + latency
/// percentiles + the paged-KV memory peaks scraped from the server.
/// Sits next to `BENCH_kernels.json` in the perf trajectory.
fn write_bench_serve(
    path: &str,
    o: &LoadOptions,
    rep: &repro::serve::loadgen::LoadReport,
) -> repro::Result<()> {
    use repro::serve::json::Json;
    let ms = |s: f64| Json::Num((s * 1e6).round() / 1e3);
    let mut fields = vec![
        ("bench".to_string(), Json::from("serve")),
        ("clients".to_string(), Json::from(o.clients)),
        ("requests".to_string(), Json::from(rep.requests)),
        ("completed".to_string(), Json::from(rep.completed)),
        ("rejected".to_string(), Json::from(rep.rejected)),
        ("deadline".to_string(), Json::from(rep.deadline)),
        ("retried".to_string(), Json::from(rep.retried)),
        ("failed".to_string(), Json::from(rep.failed)),
        ("prompt_len".to_string(), Json::from(o.prompt_len)),
        ("new_tokens".to_string(), Json::from(o.max_new)),
        ("common_prefix".to_string(), Json::from(o.common_prefix)),
        ("total_tokens".to_string(), Json::from(rep.total_tokens)),
        ("wall_secs".to_string(), Json::Num((rep.wall_secs * 1e3).round() / 1e3)),
        (
            "tokens_per_sec".to_string(),
            Json::Num((rep.tokens_per_sec() * 10.0).round() / 10.0),
        ),
        ("ttft_p50_ms".to_string(), ms(rep.ttft.p50_s)),
        ("ttft_p99_ms".to_string(), ms(rep.ttft.p99_s)),
        ("latency_p50_ms".to_string(), ms(rep.total.p50_s)),
        ("latency_p99_ms".to_string(), ms(rep.total.p99_s)),
        (
            "peak_concurrent_streams".to_string(),
            Json::from(rep.peak_concurrent_streams),
        ),
    ];
    if let Some(kv) = &rep.kv {
        fields.extend([
            ("kv_block_size".to_string(), Json::from(kv.block_size)),
            ("kv_blocks_total".to_string(), Json::from(kv.blocks_total)),
            (
                "peak_resident_kv_blocks".to_string(),
                Json::from(kv.peak_resident_blocks),
            ),
            (
                "peak_resident_kv_bytes".to_string(),
                Json::from(kv.peak_resident_bytes),
            ),
            (
                "peak_shared_kv_blocks".to_string(),
                Json::from(kv.peak_shared_blocks),
            ),
            ("kv_bits".to_string(), Json::from(kv.kv_bits)),
            ("f32_block_bytes".to_string(), Json::from(kv.f32_block_bytes)),
            (
                "peak_resident_kv_ratio".to_string(),
                Json::Num((kv.peak_resident_ratio() * 1e4).round() / 1e4),
            ),
        ]);
    }
    if let Some(s) = &rep.spec {
        fields.extend([
            ("spec_k".to_string(), Json::from(s.k)),
            ("spec_proposed".to_string(), Json::from(s.proposed)),
            ("spec_accepted".to_string(), Json::from(s.accepted)),
            (
                "spec_acceptance".to_string(),
                Json::Num((s.acceptance() * 1000.0).round() / 1000.0),
            ),
            ("spec_fallbacks".to_string(), Json::from(s.fallbacks)),
            (
                "peak_resident_draft_kv_blocks".to_string(),
                Json::from(s.draft_peak_resident_blocks),
            ),
        ]);
    }
    // Per-adapter serving accounting: server-side token counts and the
    // low-rank delta-GEMM FLOP overhead, plus client-observed per-route
    // throughput.  Always present so consumers can rely on the key.
    let adapters: Vec<Json> = rep
        .adapters
        .iter()
        .map(|a| {
            Json::Obj(vec![
                ("name".to_string(), Json::from(a.name.as_str())),
                ("rank".to_string(), Json::from(a.rank)),
                ("tokens".to_string(), Json::from(a.tokens)),
                (
                    "tokens_per_sec".to_string(),
                    Json::Num(
                        (a.tokens as f64 / rep.wall_secs.max(1e-9) * 10.0).round() / 10.0,
                    ),
                ),
                (
                    "delta_overhead".to_string(),
                    Json::Num((a.delta_overhead * 1e6).round() / 1e6),
                ),
            ])
        })
        .collect();
    fields.push(("adapters".to_string(), Json::Arr(adapters)));
    fields.push(("baseline_tokens".to_string(), Json::from(rep.baseline_tokens)));
    if o.churn_adapter.is_some() {
        fields.push(("adapter_churn_cycles".to_string(), Json::from(rep.churn_cycles)));
    }
    // Mid-run stats sampling: summaries + the raw series.  Keys are
    // always present (empty/zero when --sample-ms 0) so consumers can
    // rely on them.
    fields.push(("sample_ms".to_string(), Json::from(o.sample_ms as usize)));
    fields.push(("batch_size_peak".to_string(), Json::from(rep.batch_peak())));
    fields.push(("batch_size_p50".to_string(), Json::from(rep.batch_p50())));
    fields.push((
        "kv_occupancy_peak".to_string(),
        Json::Num((rep.kv_occupancy_peak() * 1000.0).round() / 1000.0),
    ));
    let samples: Vec<Json> = rep
        .samples
        .iter()
        .map(|s| {
            Json::Obj(vec![
                ("t_secs".to_string(), Json::Num((s.t_secs * 1e3).round() / 1e3)),
                ("active".to_string(), Json::from(s.active)),
                ("pending".to_string(), Json::from(s.pending)),
                ("kv_resident_blocks".to_string(), Json::from(s.kv_resident_blocks)),
            ])
        })
        .collect();
    fields.push(("samples".to_string(), Json::Arr(samples)));
    // Session suspend/resume scenario: present whenever session clients
    // ran, whether or not the server could actually park them.
    if o.sessions > 0 {
        fields.push((
            "sessions".to_string(),
            Json::Obj(vec![
                ("clients".to_string(), Json::from(o.sessions)),
                ("rejoin_ms".to_string(), Json::from(o.rejoin_ms as usize)),
                ("resumed".to_string(), Json::from(rep.sessions_resumed)),
                ("zero_prefill".to_string(), Json::from(rep.resume_zero_prefill)),
                ("resume_ttft_p50_ms".to_string(), ms(rep.resume_latency.p50_s)),
                ("resume_ttft_p99_ms".to_string(), ms(rep.resume_latency.p99_s)),
            ]),
        ));
    }
    // Tiered-KV scrape: present only when the server ran with --kv-spill.
    if let Some(t) = &rep.tier {
        fields.push((
            "tier".to_string(),
            Json::Obj(vec![
                ("spilled_blocks".to_string(), Json::from(t.spilled_blocks)),
                ("spilled_bytes".to_string(), Json::from(t.spilled_bytes)),
                ("slots_resident".to_string(), Json::from(t.slots_resident)),
                ("slots_total".to_string(), Json::from(t.slots_total)),
                ("preemptions".to_string(), Json::from(t.preemptions)),
                ("resumes".to_string(), Json::from(t.resumes)),
                ("block_restores".to_string(), Json::from(t.block_restores)),
                ("restore_failures".to_string(), Json::from(t.restore_failures)),
                ("sessions_stored".to_string(), Json::from(t.sessions_stored)),
                ("session_resumes".to_string(), Json::from(t.session_resumes)),
                ("prefix_pages".to_string(), Json::from(t.prefix_pages)),
                ("prefix_hits".to_string(), Json::from(t.prefix_hits)),
                ("prefix_misses".to_string(), Json::from(t.prefix_misses)),
                ("promotes".to_string(), Json::from(t.promotes)),
                (
                    "prefix_hit_rate".to_string(),
                    Json::Num((t.prefix_hit_rate() * 1000.0).round() / 1000.0),
                ),
            ]),
        ));
    }
    // `cargo bench --bench decode` merges a per-k "spec" sweep array and
    // `repro bench-kv` a "kv_quant" array into the same artifact; carry
    // both across a bench-serve rewrite.
    if let Ok(old) = std::fs::read_to_string(path) {
        if let Ok(Json::Obj(prev)) = Json::parse(old.trim()) {
            for kept in prev.into_iter().filter(|(k, _)| k == "spec" || k == "kv_quant") {
                fields.push(kept);
            }
        }
    }
    let body = Json::Obj(fields).render();
    std::fs::write(path, body + "\n")
        .map_err(|e| repro::Error::io(format!("write {path}: {e}")))
}

/// `--kv-bits` with the {16,8,4} width check shared by serve / bench-kv.
fn parse_kv_bits(args: &Args) -> repro::Result<u32> {
    let kv_bits = args.u32_or("kv-bits", 16)?;
    if !matches!(kv_bits, 16 | 8 | 4) {
        return Err(repro::Error::config(format!(
            "--kv-bits {kv_bits}: supported widths are 16 (f32 oracle), 8, 4"
        )));
    }
    Ok(kv_bits)
}

/// Merge the `repro bench-kv` sweep into `BENCH_serve.json`: existing
/// fields are kept, any previous "kv_quant" array is replaced.  Creates
/// a minimal artifact when none exists yet.
fn merge_kv_quant_into_bench_serve(
    path: &str,
    entries: Vec<repro::serve::json::Json>,
) -> repro::Result<()> {
    use repro::serve::json::Json;
    let mut fields: Vec<(String, Json)> = match std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(s.trim()).ok())
    {
        Some(Json::Obj(prev)) => prev.into_iter().filter(|(k, _)| k != "kv_quant").collect(),
        _ => vec![("bench".to_string(), Json::from("serve"))],
    };
    fields.push(("kv_quant".to_string(), Json::Arr(entries)));
    std::fs::write(path, Json::Obj(fields).render() + "\n")
        .map_err(|e| repro::Error::io(format!("write {path}: {e}")))
}

/// `repro trace-report`: aggregate a `serve --trace-log` newline-JSON
/// tick journal into per-phase and per-kernel tables plus a batch-size
/// sketch — the offline view of the same records `{"cmd":"trace"}`
/// serves live.
fn run_trace_report(path: &str) -> repro::Result<()> {
    use repro::metrics::Histogram;
    use repro::obs::{TickRecord, PHASE_NAMES};
    use repro::serve::json::Json;
    let text = std::fs::read_to_string(path)
        .map_err(|e| repro::Error::io(format!("read {path}: {e}")))?;
    let mut ticks: Vec<TickRecord> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let parsed = Json::parse(line).and_then(|j| TickRecord::from_json(&j));
        ticks.push(parsed.map_err(|e| repro::Error::config(format!("{path}:{}: {e}", ln + 1)))?);
    }
    if ticks.is_empty() {
        return Err(repro::Error::config(format!("{path}: no tick records")));
    }
    let n = ticks.len();
    let tokens: usize = ticks.iter().map(|t| t.tokens).sum();
    let finished: usize = ticks.iter().map(|t| t.finished).sum();
    let admitted: usize = ticks.iter().map(|t| t.admitted).sum();
    let span = (ticks.last().unwrap().at_secs - ticks.first().unwrap().at_secs).max(0.0);
    println!(
        "trace-report: {n} ticks over {span:.2}s — {admitted} admitted, {finished} finished, \
         {tokens} tokens ({:.1} tokens/s)",
        if span > 0.0 { tokens as f64 / span } else { 0.0 }
    );

    let mut phase_tot = [0u64; PHASE_NAMES.len()];
    for t in &ticks {
        for (acc, &ns) in phase_tot.iter_mut().zip(t.phase_ns.iter()) {
            *acc += ns;
        }
    }
    let all_ns: u64 = phase_tot.iter().sum();
    let mut tb = TableBuilder::new(format!("Tick phases ({n} ticks)"))
        .header(&["phase", "total ms", "share", "mean us/tick"]);
    for (name, &ns) in PHASE_NAMES.iter().zip(phase_tot.iter()) {
        tb.row(vec![
            name.to_string(),
            format!("{:.2}", ns as f64 / 1e6),
            TableBuilder::pct(ns as f64 / all_ns.max(1) as f64),
            format!("{:.1}", ns as f64 / 1e3 / n as f64),
        ]);
    }
    println!("{}", tb.markdown());

    let mut kernels: std::collections::BTreeMap<String, (u64, u64, u64)> = Default::default();
    for t in &ticks {
        for k in &t.kernels {
            let e = kernels.entry(k.kind.clone()).or_insert((0, 0, 0));
            e.0 += k.calls;
            e.1 += k.ns;
            e.2 += k.flops;
        }
    }
    if kernels.is_empty() {
        println!("(no kernel samples — run serve with --profile or REPRO_PROF=1)\n");
    } else {
        let mut tb = TableBuilder::new("Profiled kernels")
            .header(&["kind", "calls", "total ms", "GFLOP/s"]);
        for (kind, (calls, ns, flops)) in &kernels {
            let gflops = if *ns == 0 { 0.0 } else { *flops as f64 / *ns as f64 };
            tb.row(vec![
                kind.clone(),
                calls.to_string(),
                format!("{:.2}", *ns as f64 / 1e6),
                format!("{gflops:.2}"),
            ]);
        }
        println!("{}", tb.markdown());
    }

    let batches: Vec<f32> = ticks.iter().map(|t| t.batch as f32).collect();
    println!("batch size per tick:\n{}", Histogram::auto(&batches, 16).render(40));
    let proposed: usize = ticks.iter().map(|t| t.spec_proposed).sum();
    let accepted: usize = ticks.iter().map(|t| t.spec_accepted).sum();
    if proposed > 0 {
        println!(
            "speculation: {accepted}/{proposed} draft tokens accepted ({:.1}%)",
            accepted as f64 / proposed as f64 * 100.0
        );
    }
    let kv_peak = ticks.iter().map(|t| t.kv_resident).max().unwrap_or(0);
    println!("peak KV resident blocks: {kv_peak}");
    Ok(())
}

/// Analytic serving-memory prediction for the same architecture, keyed
/// off the model's *actual* serving form (weight-override baselines like
/// qlora/loftq serve dense f32 even when quantized at low bits, and the
/// fp reference carries no adapters).
fn analytic_resident_mb(cfg: &ModelConfig, model: &PackedModel, rank: usize) -> f64 {
    use repro::metrics::memory::ArchShape;
    let m = MemoryModel::new(ArchShape::from_config(cfg));
    let spec = if model.spec.bits <= 8 { Some(model.spec) } else { None };
    let rank = if model.has_adapters() { rank } else { 0 };
    m.inference_weights(spec, rank) as f64 / 1e6
}

/// Fig. 2 regeneration: memory accounting for the Llama-2-7B shape.
fn print_memory_report() {
    use repro::metrics::memory::{ArchShape, MemoryBreakdown, Regime};
    let mut t = TableBuilder::new("Fig. 2 — finetuning memory (GB), Llama-2-7B shape")
        .header(&["regime", "weights", "optimizer", "gradients", "activations", "total"]);
    let m = MemoryModel::new(ArchShape::llama2_7b());
    for (name, regime) in [
        ("Full FT (bf16+Adam)", Regime::FullFt),
        ("LoRA r=64", Regime::Lora { rank: 64 }),
        ("QLoRA 4-bit r=64", Regime::QLora { rank: 64, spec: QuantSpec::new(4, 64) }),
        ("QLoRA 2-bit r=64", Regime::QLora { rank: 64, spec: QuantSpec::new(2, 64) }),
    ] {
        let b = m.breakdown(regime);
        t.row(vec![
            name.into(),
            format!("{:.1}", MemoryBreakdown::gb(b.weights)),
            format!("{:.1}", MemoryBreakdown::gb(b.optimizer)),
            format!("{:.1}", MemoryBreakdown::gb(b.gradients)),
            format!("{:.1}", MemoryBreakdown::gb(b.activations)),
            format!("{:.1}", MemoryBreakdown::gb(b.total())),
        ]);
    }
    println!("{}", t.markdown());
}

fn print_param_report() {
    let mut t =
        TableBuilder::new("Model family").header(&["size", "params", "layers", "d_model", "vocab"]);
    for size in ["tiny", "small", "base"] {
        let cfg = ModelConfig::by_name(size).unwrap();
        t.row(vec![
            size.into(),
            format!("{:.1}M", cfg.n_params() as f64 / 1e6),
            cfg.n_layers.to_string(),
            cfg.d_model.to_string(),
            cfg.vocab.to_string(),
        ]);
    }
    println!("{}", t.markdown());
}
