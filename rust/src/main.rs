//! `repro` — the ApiQ reproduction CLI (L3 leader entrypoint).
//!
//! Subcommands mirror the experiment pipeline stages:
//!
//!   repro pretrain  --size small --steps 300
//!   repro quantize  --size small --method apiq-bw --bits 2
//!   repro eval      --size small --method apiq-bw --bits 2
//!   repro finetune  --size small --method apiq-bw --bits 2 --data corpus
//!   repro report memory
//!   repro artifacts
//!
//! The per-paper-table drivers live in `examples/` (see DESIGN.md §5).

use repro::config::args::Args;
use repro::data::tasks::{ArithTask, ClassifyTask};
use repro::data::ZipfMarkovCorpus;
use repro::metrics::{MemoryModel, TableBuilder};
use repro::model::{checkpoint, ModelConfig};
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::quant::QuantSpec;
use repro::train::{FinetuneData, LoraPosition, Pretrainer};

const USAGE: &str = "\
repro — ApiQ (EMNLP 2024) reproduction coordinator

USAGE: repro <command> [--flags]

COMMANDS
  pretrain   --size S --steps N                      pretrain + save checkpoint
  quantize   --size S --method M --bits B            quantize, save qparams
  eval       --size S --method M --bits B            PTQ perplexity vs fp
  finetune   --size S --method M --bits B --data D   quantize + adapter finetune
  report     memory|params                           analytic reports
  artifacts                                          list compiled artifacts

COMMON FLAGS
  --artifacts DIR   (default: artifacts)
  --seed N          (default: 17)
  --rank R          (default: 16)      --group G     (default: 64)
  --pretrain-steps N (default: 300)

METHODS: rtn qlora gptq awq loftq omniquant apiq-lw apiq-bw apiq-bw-dora
";

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.command.is_empty() || args.flag("help") {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> repro::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let seed = args.u64_or("seed", 17)?;
    let rank = args.usize_or("rank", DEFAULT_RANK)?;
    let group = args.usize_or("group", DEFAULT_GROUP)?;
    let bits = args.u32_or("bits", 2)?;
    let size = args.str_or("size", "tiny");
    let method = args.str_or("method", "apiq-bw");
    let pretrain_steps = args.usize_or("pretrain-steps", 300)?;

    match args.command.as_str() {
        "pretrain" => {
            let steps = args.usize_or("steps", 300)?;
            let runtime = repro::runtime::Runtime::new(&artifacts)?;
            let cfg = ModelConfig::by_name(&size)?;
            let corpus = ZipfMarkovCorpus::new(cfg.vocab, seed);
            let mut params = cfg.init_params(seed);
            let trainer = Pretrainer::new(&runtime, cfg, steps);
            let report = trainer.train(&mut params, &corpus, steps, seed ^ 0x7EA1)?;
            let path = format!("checkpoints/pretrained_{}_{}_{}.ckpt", cfg.name, steps, seed);
            checkpoint::save(&params, &path)?;
            println!(
                "pretrained {} for {} steps: loss {:.4} -> {:.4} ({:.1}s); saved {path}",
                cfg.name,
                steps,
                report.losses.first().copied().unwrap_or(f32::NAN),
                report.tail_mean(10),
                report.wall_secs
            );
        }
        "quantize" => {
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let r = env.quantize(&method, bits, group, rank)?;
            let path = format!("checkpoints/qparams_{size}_{method}_{bits}b_r{rank}_g{group}.ckpt");
            checkpoint::save(&r.qparams, &path)?;
            println!(
                "quantized {size} with {method} at {bits}-bit in {:.1}s; qparams -> {path}",
                r.wall_secs
            );
        }
        "eval" => {
            let eval_batches = args.usize_or("eval-batches", 8)?;
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let fp = env.ppl_fp(eval_batches)?;
            let r = env.quantize(&method, bits, group, rank)?;
            let q = env.ppl(&r, rank, group, eval_batches)?;
            let mut t = TableBuilder::new(format!("PTQ perplexity ({size}, {bits}-bit, g{group})"))
                .header(&["model", "ppl"]);
            t.row(vec!["fp32".into(), TableBuilder::num(fp)]);
            t.row(vec![method.clone(), TableBuilder::num(q)]);
            println!("{}", t.markdown());
        }
        "finetune" => {
            let data = args.str_or("data", "corpus");
            let steps = args.usize_or("steps", 100)?;
            let lr = args.f32_or("lr", 1e-3)?;
            let position = args.str_or("position", "all");
            let env = Env::prepare(&artifacts, &size, pretrain_steps, seed)?;
            let mut r = env.quantize(&method, bits, group, rank)?;
            let arith = ArithTask::add(env.cfg.vocab, seed ^ 0xA17);
            let clf = ClassifyTask::new(env.cfg.vocab, 3, seed ^ 0xC1F);
            let ft_data = match data.as_str() {
                "arith" => FinetuneData::Task(&arith),
                "classify" => FinetuneData::Task(&clf),
                _ => FinetuneData::Corpus(&env.corpus),
            };
            let pos = LoraPosition::parse(&position);
            let report = env.finetune(&mut r, rank, group, &ft_data, steps, lr, pos)?;
            let ppl = env.ppl(&r, rank, group, 8)?;
            println!(
                "finetuned {method} {bits}-bit on {data} for {steps} steps (loss {:.4} -> {:.4}); eval ppl {:.3}",
                report.losses.first().copied().unwrap_or(f32::NAN),
                report.tail_mean(10),
                ppl
            );
            if data == "arith" {
                let acc = env.task_accuracy(&r, rank, group, &arith, 8, false)?;
                println!("arith accuracy: {:.1}%", acc * 100.0);
            }
        }
        "report" => match args.positionals.first().map(String::as_str) {
            Some("memory") => print_memory_report(),
            Some("params") => print_param_report(),
            other => eprintln!("unknown report {other:?} (try: memory, params)"),
        },
        "artifacts" => {
            let dir = std::path::Path::new(&artifacts);
            let mut names: Vec<String> = std::fs::read_dir(dir)
                .map_err(|e| repro::Error::io(format!("{}: {e}", dir.display())))?
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    e.file_name()
                        .to_str()
                        .and_then(|n| n.strip_suffix(".hlo.txt").map(String::from))
                })
                .collect();
            names.sort();
            for n in &names {
                println!("{n}");
            }
            println!("{} artifacts", names.len());
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Fig. 2 regeneration: memory accounting for the Llama-2-7B shape.
fn print_memory_report() {
    use repro::metrics::memory::{ArchShape, MemoryBreakdown, Regime};
    let mut t = TableBuilder::new("Fig. 2 — finetuning memory (GB), Llama-2-7B shape")
        .header(&["regime", "weights", "optimizer", "gradients", "activations", "total"]);
    let m = MemoryModel::new(ArchShape::llama2_7b());
    for (name, regime) in [
        ("Full FT (bf16+Adam)", Regime::FullFt),
        ("LoRA r=64", Regime::Lora { rank: 64 }),
        ("QLoRA 4-bit r=64", Regime::QLora { rank: 64, spec: QuantSpec::new(4, 64) }),
        ("QLoRA 2-bit r=64", Regime::QLora { rank: 64, spec: QuantSpec::new(2, 64) }),
    ] {
        let b = m.breakdown(regime);
        t.row(vec![
            name.into(),
            format!("{:.1}", MemoryBreakdown::gb(b.weights)),
            format!("{:.1}", MemoryBreakdown::gb(b.optimizer)),
            format!("{:.1}", MemoryBreakdown::gb(b.gradients)),
            format!("{:.1}", MemoryBreakdown::gb(b.activations)),
            format!("{:.1}", MemoryBreakdown::gb(b.total())),
        ]);
    }
    println!("{}", t.markdown());
}

fn print_param_report() {
    let mut t =
        TableBuilder::new("Model family").header(&["size", "params", "layers", "d_model", "vocab"]);
    for size in ["tiny", "small", "base"] {
        let cfg = ModelConfig::by_name(size).unwrap();
        t.row(vec![
            size.into(),
            format!("{:.1}M", cfg.n_params() as f64 / 1e6),
            cfg.n_layers.to_string(),
            cfg.d_model.to_string(),
            cfg.vocab.to_string(),
        ]);
    }
    println!("{}", t.markdown());
}
