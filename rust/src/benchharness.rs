//! Minimal criterion-style benchmark harness (the offline registry has no
//! `criterion`; see Cargo.toml note).  Provides warmup + timed iterations
//! with mean / stddev / min / p50 reporting and a stable text format that
//! `cargo bench` prints and EXPERIMENTS.md quotes.

use std::time::Instant;

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} iters={:<4} mean={} std={} min={} p50={}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            fmt_time(self.p50_s),
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// The harness: collects results, prints a summary at the end.
#[derive(Default)]
pub struct Bench {
    results: Vec<BenchResult>,
    /// Extra free-form lines (throughput numbers etc.) echoed in the summary.
    notes: Vec<String>,
}

impl Bench {
    pub fn new() -> Self {
        Bench::default()
    }

    /// Time `f` for `iters` iterations after `warmup` unmeasured calls.
    pub fn run(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..warmup {
            f();
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: times[0],
            p50_s: times[times.len() / 2],
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Record a derived metric line (e.g. tokens/s).
    pub fn note(&mut self, line: impl Into<String>) {
        let line = line.into();
        println!("note  {line}");
        self.notes.push(line);
    }

    /// Print the final summary block (what `cargo bench` output captures).
    pub fn finish(&self, suite: &str) {
        println!("\n==== bench suite: {suite} ====");
        for r in &self.results {
            println!("{}", r.report());
        }
        for n in &self.notes {
            println!("note  {n}");
        }
        println!("==== end {suite} ====");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_orders() {
        let mut b = Bench::new();
        let r = b.run("sleepless", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.p50_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn formats_times() {
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(fmt_time(0.002).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
    }
}
