//! Per-tick phase tracing and per-request lifecycle spans.
//!
//! Every scheduler step produces one [`TickRecord`]: where the tick's
//! wall time went (phase nanos), how big the batch was, how the KV pool
//! moved, and what speculation achieved.  Records live in a
//! fixed-capacity [`TraceRing`] (oldest drops, the monotonic total keeps
//! counting) served over `{"cmd":"trace"}` and appended as newline-JSON
//! by `serve --trace-log` for `repro trace-report`.
//!
//! [`RequestSpan`] is the single home for one sequence's wall-clock
//! lifecycle (queued -> admitted/prefilled -> decoding -> finished); the
//! scheduler's `RequestStats` is rendered FROM the span at eviction
//! instead of being hand-kept field by field.

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::serve::json::Json;

/// Tick phases, in pipeline order.  `admit` is queue triage (validation,
/// adapter resolution, block-budget reservation); `prefill` is the
/// batched prompt pass including first-token sampling; `draft`/`verify`
/// are the speculative cycle's two model passes; `decode` is the plain
/// batched step (per-sequence page growth + forward); `sample` covers
/// next-token picks and speculative acceptance walks; `emit` is event
/// packaging, per-adapter accounting, and eviction; `tier` is the disk
/// tier's tick work — resuming suspended sequences from the spill file
/// and publishing sealed prefix pages (preempt spills, session restores,
/// and prefix promotions happen inside admission and land in `admit`).
pub const PHASE_NAMES: [&str; 8] =
    ["admit", "prefill", "draft", "verify", "decode", "sample", "emit", "tier"];

/// Number of tick phases (`phase_ns` length).
pub const N_PHASES: usize = PHASE_NAMES.len();

pub const PH_ADMIT: usize = 0;
pub const PH_PREFILL: usize = 1;
pub const PH_DRAFT: usize = 2;
pub const PH_VERIFY: usize = 3;
pub const PH_DECODE: usize = 4;
pub const PH_SAMPLE: usize = 5;
pub const PH_EMIT: usize = 6;
pub const PH_TIER: usize = 7;

/// Per-kernel-kind accumulation attributed to one tick (present only
/// when profiling is enabled; see [`crate::obs::profile`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KernelTickDelta {
    pub kind: String,
    pub calls: u64,
    pub ns: u64,
    pub flops: u64,
}

/// One scheduler tick's trace record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TickRecord {
    /// Monotonic tick number (assigned by [`crate::obs::Telemetry`]).
    pub seq: u64,
    /// Seconds since the engine's telemetry started.
    pub at_secs: f64,
    /// Nanoseconds per phase, indexed like [`PHASE_NAMES`].
    pub phase_ns: [u64; N_PHASES],
    /// Active sequences after this tick's admissions.
    pub batch: usize,
    /// Requests still queued after admission.
    pub pending: usize,
    /// Requests admitted this tick.
    pub admitted: usize,
    /// Requests finished (evicted) this tick.
    pub finished: usize,
    /// Tokens emitted this tick.
    pub tokens: usize,
    /// Target-pool resident KV pages at end of tick.
    pub kv_resident: usize,
    /// Resident-page delta across the tick (admissions grow it,
    /// evictions shrink it).
    pub kv_delta: i64,
    /// Draft tokens proposed this tick (0 when not speculating).
    pub spec_proposed: usize,
    /// Proposals accepted this tick.
    pub spec_accepted: usize,
    /// Per-kernel-kind deltas for this tick; empty unless profiling.
    pub kernels: Vec<KernelTickDelta>,
}

impl TickRecord {
    pub fn total_ns(&self) -> u64 {
        self.phase_ns.iter().sum()
    }

    /// One newline-JSON trace-log record (no trailing newline).
    pub fn to_json(&self) -> Json {
        let phases = Json::Obj(
            PHASE_NAMES
                .iter()
                .zip(self.phase_ns.iter())
                .map(|(name, &ns)| (name.to_string(), Json::Num(ns as f64)))
                .collect(),
        );
        let mut fields = vec![
            ("seq".to_string(), Json::Num(self.seq as f64)),
            ("t".to_string(), Json::Num((self.at_secs * 1e6).round() / 1e6)),
            ("batch".to_string(), Json::from(self.batch)),
            ("pending".to_string(), Json::from(self.pending)),
            ("admitted".to_string(), Json::from(self.admitted)),
            ("finished".to_string(), Json::from(self.finished)),
            ("tokens".to_string(), Json::from(self.tokens)),
            ("kv_resident".to_string(), Json::from(self.kv_resident)),
            ("kv_delta".to_string(), Json::Num(self.kv_delta as f64)),
            ("spec_proposed".to_string(), Json::from(self.spec_proposed)),
            ("spec_accepted".to_string(), Json::from(self.spec_accepted)),
            ("phase_ns".to_string(), phases),
        ];
        if !self.kernels.is_empty() {
            fields.push((
                "kernels".to_string(),
                Json::Arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            Json::Obj(vec![
                                ("kind".to_string(), Json::from(k.kind.as_str())),
                                ("calls".to_string(), Json::Num(k.calls as f64)),
                                ("ns".to_string(), Json::Num(k.ns as f64)),
                                ("flops".to_string(), Json::Num(k.flops as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::Obj(fields)
    }

    /// Parse one trace-log record (`repro trace-report`).
    pub fn from_json(j: &Json) -> Result<TickRecord> {
        let u = |name: &str| {
            j.get(name)
                .and_then(Json::as_i64)
                .ok_or_else(|| Error::config(format!("trace record lacks '{name}'")))
        };
        let mut phase_ns = [0u64; N_PHASES];
        let phases = j
            .get("phase_ns")
            .ok_or_else(|| Error::config("trace record lacks 'phase_ns'"))?;
        for (i, name) in PHASE_NAMES.iter().enumerate() {
            phase_ns[i] = phases.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
        }
        let kernels = match j.get("kernels").and_then(Json::as_arr) {
            None => Vec::new(),
            Some(arr) => arr
                .iter()
                .map(|k| {
                    let n = |name: &str| k.get(name).and_then(Json::as_i64).unwrap_or(0).max(0);
                    KernelTickDelta {
                        kind: k.get("kind").and_then(Json::as_str).unwrap_or("?").to_string(),
                        calls: n("calls") as u64,
                        ns: n("ns") as u64,
                        flops: n("flops") as u64,
                    }
                })
                .collect(),
        };
        Ok(TickRecord {
            seq: u("seq")?.max(0) as u64,
            at_secs: j.get("t").and_then(Json::as_f64).unwrap_or(0.0),
            phase_ns,
            batch: u("batch")?.max(0) as usize,
            pending: u("pending")?.max(0) as usize,
            admitted: u("admitted")?.max(0) as usize,
            finished: u("finished")?.max(0) as usize,
            tokens: u("tokens")?.max(0) as usize,
            kv_resident: u("kv_resident")?.max(0) as usize,
            kv_delta: u("kv_delta")?,
            spec_proposed: u("spec_proposed")?.max(0) as usize,
            spec_accepted: u("spec_accepted")?.max(0) as usize,
            kernels,
        })
    }
}

/// Fixed-capacity ring of the most recent tick records.  `total` keeps
/// counting monotonically after old records drop.
pub struct TraceRing {
    cap: usize,
    buf: VecDeque<TickRecord>,
    total: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceRing { cap, buf: VecDeque::with_capacity(cap), total: 0 }
    }

    pub fn push(&mut self, rec: TickRecord) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(rec);
        self.total += 1;
    }

    /// Ticks ever recorded (not just retained).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// The last `n` records, oldest-first.
    pub fn last(&self, n: usize) -> Vec<TickRecord> {
        let skip = self.buf.len().saturating_sub(n);
        self.buf.iter().skip(skip).cloned().collect()
    }
}

/// One request's wall-clock lifecycle, from submission to completion.
/// The scheduler keeps exactly one per active sequence; everything the
/// protocol's `done.stats` object reports is derived from here.
#[derive(Clone, Copy, Debug)]
pub struct RequestSpan {
    pub queued_at: Instant,
    pub admitted_at: Instant,
    /// The batched prefill pass this request rode in (model time only).
    pub prefill_secs: f64,
    /// Prompt positions mapped from a donor's pages instead of computed.
    pub shared_prefix_tokens: usize,
    /// Generated tokens so far (the prefill's first token counts).
    pub emitted: usize,
    pub last_token_at: Instant,
    /// Worst gap between consecutive emitted tokens.
    pub max_gap_secs: f64,
    pub spec_proposed: usize,
    pub spec_accepted: usize,
}

impl RequestSpan {
    /// Open the span at admission: the prefill emitted the first token
    /// at `now`.
    pub fn admitted(
        queued_at: Instant,
        admitted_at: Instant,
        prefill_secs: f64,
        shared_prefix_tokens: usize,
        now: Instant,
    ) -> Self {
        RequestSpan {
            queued_at,
            admitted_at,
            prefill_secs,
            shared_prefix_tokens,
            emitted: 1,
            last_token_at: now,
            max_gap_secs: 0.0,
            spec_proposed: 0,
            spec_accepted: 0,
        }
    }

    /// Record one emitted token.  The gap to the previous token feeds
    /// the inter-token high-water mark; the first generated token after
    /// prefill starts the clock without contributing a gap.
    pub fn note_token(&mut self, now: Instant) {
        self.emitted += 1;
        let gap = now.duration_since(self.last_token_at).as_secs_f64();
        if self.emitted > 1 && gap > self.max_gap_secs {
            self.max_gap_secs = gap;
        }
        self.last_token_at = now;
    }

    pub fn queue_secs(&self) -> f64 {
        self.admitted_at.duration_since(self.queued_at).as_secs_f64()
    }

    pub fn total_secs(&self, done_at: Instant) -> f64 {
        done_at.duration_since(self.admitted_at).as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64) -> TickRecord {
        TickRecord { seq, batch: seq as usize % 5, tokens: 2, ..Default::default() }
    }

    #[test]
    fn ring_drops_oldest_and_counts_monotonically() {
        let mut ring = TraceRing::new(4);
        for i in 0..10 {
            ring.push(rec(i));
        }
        assert_eq!(ring.total(), 10);
        assert_eq!(ring.len(), 4);
        let last = ring.last(100);
        assert_eq!(last.len(), 4);
        assert_eq!(last[0].seq, 6, "oldest retained record");
        assert_eq!(last[3].seq, 9);
        assert_eq!(ring.last(2).iter().map(|r| r.seq).collect::<Vec<_>>(), vec![8, 9]);
    }

    #[test]
    fn tick_record_json_roundtrip() {
        let mut r = TickRecord {
            seq: 42,
            at_secs: 1.5,
            batch: 3,
            pending: 1,
            admitted: 2,
            finished: 1,
            tokens: 7,
            kv_resident: 12,
            kv_delta: -3,
            spec_proposed: 8,
            spec_accepted: 6,
            kernels: vec![KernelTickDelta {
                kind: "fused_panel".to_string(),
                calls: 96,
                ns: 123456,
                flops: 1 << 30,
            }],
            ..Default::default()
        };
        r.phase_ns[PH_PREFILL] = 1_000_000;
        r.phase_ns[PH_EMIT] = 500;
        let line = r.to_json().render();
        let back = TickRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn span_tracks_gaps_and_counts() {
        let t0 = Instant::now();
        let mut span = RequestSpan::admitted(t0, t0, 0.01, 4, t0);
        assert_eq!(span.emitted, 1);
        span.note_token(t0 + std::time::Duration::from_millis(5));
        span.note_token(t0 + std::time::Duration::from_millis(30));
        assert_eq!(span.emitted, 3);
        assert!(span.max_gap_secs >= 0.024, "worst inter-token gap recorded");
        assert!(span.total_secs(t0 + std::time::Duration::from_millis(30)) >= 0.029);
    }
}
