//! Process-wide kernel profiling accumulators, gated behind
//! `serve --profile` / `REPRO_PROF=1`.
//!
//! The hooks live inside the hottest code in the crate (`gemm_accum`,
//! the fused 2-bit panel matmul, the fused gemv, and the pool's task
//! claim loop), so the OFF path must cost exactly one relaxed atomic
//! load and nothing else — no `Instant::now`, no branch on env vars.
//! Once enabled the switch is sticky for the life of the process:
//! profiling only ever times and counts around compute, so enabling it
//! cannot change any numeric result (the bitwise A/B test in
//! `tests/obs.rs` pins this).
//!
//! Two views accumulate:
//!
//! * per-kernel-kind `{calls, busy ns, flops}` — enough to derive
//!   achieved GFLOP/s per kind for `/metrics` and `repro trace-report`;
//! * per-pool-lane busy nanoseconds (lane 0 is the caller thread, lanes
//!   `1..n` the `repro-kernel-*` workers) — the lane-utilization data
//!   the ROADMAP sharding work needs before it can split layers.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Kernel kinds with dedicated accumulators, in [`KIND_NAMES`] order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Dense f32 GEMM (`kernels::gemm_accum` — LoRA paths, dense ref).
    DenseGemm = 0,
    /// Fused dequant+matmul over packed 2-bit panels (prefill/batched).
    FusedPanel = 1,
    /// Fused dequant+gemv for skinny decode batches.
    MatvecFused = 2,
}

pub const N_KINDS: usize = 3;
pub const KIND_NAMES: [&str; N_KINDS] = ["dense_gemm", "fused_panel", "matvec_fused"];

/// Highest pool lane with a dedicated busy-ns cell (lane 0 = caller).
pub const MAX_LANES: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_CHECKED: AtomicBool = AtomicBool::new(false);

struct KindCells {
    calls: AtomicU64,
    ns: AtomicU64,
    flops: AtomicU64,
}

fn kind_cells() -> &'static [KindCells; N_KINDS] {
    static CELLS: OnceLock<[KindCells; N_KINDS]> = OnceLock::new();
    CELLS.get_or_init(|| {
        std::array::from_fn(|_| KindCells {
            calls: AtomicU64::new(0),
            ns: AtomicU64::new(0),
            flops: AtomicU64::new(0),
        })
    })
}

fn lane_cells() -> &'static Vec<AtomicU64> {
    static LANES: OnceLock<Vec<AtomicU64>> = OnceLock::new();
    LANES.get_or_init(|| (0..MAX_LANES).map(|_| AtomicU64::new(0)).collect())
}

thread_local! {
    /// This thread's pool lane (0 = a caller thread participating in a
    /// pool batch; workers set `1..n` once at spawn).
    static LANE: Cell<usize> = const { Cell::new(0) };
}

/// Is profiling on?  One relaxed load — this is the whole cost of every
/// kernel hook when profiling is disabled.  The first call folds in the
/// `REPRO_PROF` environment variable.
#[inline]
pub fn enabled() -> bool {
    if !ENV_CHECKED.load(Ordering::Relaxed) {
        let on = std::env::var("REPRO_PROF").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
        if on {
            ENABLED.store(true, Ordering::Relaxed);
        }
        ENV_CHECKED.store(true, Ordering::Relaxed);
    }
    ENABLED.load(Ordering::Relaxed)
}

/// Turn profiling on for the rest of the process (`serve --profile`).
/// Sticky by design: accumulators are process-global, and a half-profiled
/// window is worse than a longer one.
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
    ENV_CHECKED.store(true, Ordering::Relaxed);
}

/// Start a kernel timer — `Some` only when profiling is on, so the off
/// path never reads the clock.
#[inline]
pub fn timer() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Credit one kernel invocation to its kind.
#[inline]
pub fn record(kind: KernelKind, ns: u64, flops: u64) {
    let c = &kind_cells()[kind as usize];
    c.calls.fetch_add(1, Ordering::Relaxed);
    c.ns.fetch_add(ns, Ordering::Relaxed);
    c.flops.fetch_add(flops, Ordering::Relaxed);
}

/// Bind the calling thread to a pool lane (workers call this once at
/// spawn; caller threads keep the default lane 0).
pub fn set_lane(lane: usize) {
    LANE.with(|l| l.set(lane.min(MAX_LANES - 1)));
}

/// Credit busy nanoseconds to the calling thread's lane.
#[inline]
pub fn record_lane(ns: u64) {
    let lane = LANE.with(Cell::get);
    lane_cells()[lane].fetch_add(ns, Ordering::Relaxed);
}

/// Accumulated totals for one kernel kind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelCounts {
    pub calls: u64,
    pub ns: u64,
    pub flops: u64,
}

impl KernelCounts {
    /// Achieved throughput over the busy window (0 when nothing ran).
    pub fn gflops(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.flops as f64 / self.ns as f64
        }
    }
}

/// Read all per-kind accumulators, indexed like [`KIND_NAMES`].
pub fn snapshot() -> [KernelCounts; N_KINDS] {
    let cells = kind_cells();
    std::array::from_fn(|i| KernelCounts {
        calls: cells[i].calls.load(Ordering::Relaxed),
        ns: cells[i].ns.load(Ordering::Relaxed),
        flops: cells[i].flops.load(Ordering::Relaxed),
    })
}

/// Busy nanoseconds per pool lane, truncated to the first `n` lanes.
pub fn lane_snapshot(n: usize) -> Vec<u64> {
    lane_cells()
        .iter()
        .take(n.min(MAX_LANES))
        .map(|c| c.load(Ordering::Relaxed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_per_kind() {
        let before = snapshot();
        record(KernelKind::FusedPanel, 1_000, 2_048);
        record(KernelKind::FusedPanel, 500, 1_024);
        record(KernelKind::MatvecFused, 10, 64);
        let after = snapshot();
        let fp = KernelKind::FusedPanel as usize;
        let mv = KernelKind::MatvecFused as usize;
        assert_eq!(after[fp].calls - before[fp].calls, 2);
        assert_eq!(after[fp].ns - before[fp].ns, 1_500);
        assert_eq!(after[fp].flops - before[fp].flops, 3_072);
        assert_eq!(after[mv].calls - before[mv].calls, 1);
        let g = KernelCounts { calls: 1, ns: 1_000, flops: 2_000 };
        assert!((g.gflops() - 2.0).abs() < 1e-12, "flops/ns == GFLOP/s");
    }

    #[test]
    fn lanes_accumulate_per_thread() {
        let before = lane_snapshot(MAX_LANES);
        record_lane(100); // this thread: lane 0 by default
        let t = std::thread::spawn(|| {
            set_lane(3);
            record_lane(250);
            record_lane(250);
        });
        t.join().unwrap();
        let after = lane_snapshot(MAX_LANES);
        assert!(after[0] - before[0] >= 100);
        assert_eq!(after[3] - before[3], 500);
    }
}
