//! Deterministic fault injection for the serve path.
//!
//! A [`FaultPlan`] is parsed from a spec string (`REPRO_FAULT` env var or
//! `serve --fault`) and threaded as an `Arc` through the engine: the
//! block pool, the scheduler tick, the adapter loader, and the
//! per-connection writer threads each consult one injection point.  The
//! decision at every point is a pure function of `(seed, evaluation
//! counter)` — re-running the same workload with the same spec fires the
//! same faults in the same places, which is what lets `tests/robustness.rs`
//! and the CI chaos job assert exact recovery behaviour instead of
//! sampling it.
//!
//! Spec grammar (comma-separated clauses):
//!
//! ```text
//! spec      := clause ("," clause)*
//! clause    := point ":" rate ":" seed
//! point     := "alloc" | "adapter_io" | "tick_panic" | "conn_write" | "spill_io"
//! rate      := FLOAT          -- independent probability per evaluation
//!            | "1/" N         -- every Nth evaluation fires
//!            | "@" N          -- exactly the Nth evaluation fires (one-shot)
//! ```
//!
//! Examples: `alloc:0.05:7` (5% of pool allocations fail),
//! `tick_panic:@4:1` (the 4th per-sequence tick checkpoint panics, once),
//! `conn_write:1/50:9` (every 50th connection write breaks the socket).
//!
//! Injection points:
//!
//! * `alloc` — [`BlockPool::try_alloc`](crate::serve::BlockPool) returns
//!   `None` as if the pool were exhausted (exercises admission backoff
//!   and mid-decode capacity finishes).
//! * `adapter_io` — runtime `{"cmd":"adapter","op":"load"}` fails with an
//!   I/O error before touching the sidecar file.
//! * `tick_panic` — a per-sequence checkpoint inside `Scheduler::step`
//!   panics with a [`SeqPanic`] payload naming the sequence, exercising
//!   the engine's `catch_unwind` + quarantine path.
//! * `conn_write` — a connection writer thread drops its socket,
//!   exercising dead-connection cancellation and page reclamation.
//! * `spill_io` — a tiered-KV spill-file slot read fails as if the
//!   stored CRC did not match, exercising the restore-failure path
//!   (`internal` finish for that sequence only, never engine poison).
//!
//! A plan with a clause for one point leaves all other points off; the
//! off path is a single branch on a plain enum (no atomics touched), so
//! running with a partial plan does not perturb untouched subsystems.

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Injection points, indexable into [`FaultPlan::points`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// KV block-pool allocation.
    Alloc = 0,
    /// Runtime adapter-load I/O.
    AdapterIo = 1,
    /// Per-sequence scheduler tick checkpoint (panics).
    TickPanic = 2,
    /// Per-connection output write.
    ConnWrite = 3,
    /// Tiered-KV spill-file slot read (restore path).
    SpillIo = 4,
}

const N_POINTS: usize = 5;
const POINT_NAMES: [&str; N_POINTS] =
    ["alloc", "adapter_io", "tick_panic", "conn_write", "spill_io"];

/// How often one injection point fires.
#[derive(Clone, Copy, Debug)]
enum Rate {
    /// Never (point absent from the spec).
    Off,
    /// Independent probability per evaluation, as a threshold over the
    /// full `u64` range of the per-evaluation hash.
    Prob(u64),
    /// Every `n`th evaluation (1-indexed: `1/3` fires on 3, 6, 9, ...).
    Every(u64),
    /// Exactly the `n`th evaluation (1-indexed), once.
    Once(u64),
}

struct PointState {
    rate: Rate,
    seed: u64,
    /// Evaluations so far (monotonic, shared across threads).
    n: AtomicU64,
}

/// A parsed fault-injection plan.  Cheap to consult: points not present
/// in the spec cost one enum branch.
pub struct FaultPlan {
    points: [PointState; N_POINTS],
    /// Faults fired so far, across all points (`faults_injected_total`).
    fired: AtomicU64,
}

/// Panic payload raised by the `tick_panic` point: names the sequence
/// being processed so the engine can quarantine exactly that sequence.
pub struct SeqPanic {
    pub key: u64,
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn parse_rate(s: &str) -> Result<Rate> {
    if let Some(n) = s.strip_prefix('@') {
        let n: u64 = n
            .parse()
            .map_err(|_| Error::config(format!("fault spec: bad one-shot rate '@{n}'")))?;
        if n == 0 {
            return Err(Error::config("fault spec: '@N' is 1-indexed, N must be >= 1"));
        }
        return Ok(Rate::Once(n));
    }
    if let Some(n) = s.strip_prefix("1/") {
        let n: u64 = n
            .parse()
            .map_err(|_| Error::config(format!("fault spec: bad period rate '1/{n}'")))?;
        if n == 0 {
            return Err(Error::config("fault spec: '1/N' requires N >= 1"));
        }
        return Ok(Rate::Every(n));
    }
    let p: f64 = s
        .parse()
        .map_err(|_| Error::config(format!("fault spec: bad probability '{s}'")))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(Error::config(format!(
            "fault spec: probability {p} outside [0, 1]"
        )));
    }
    if p == 0.0 {
        return Ok(Rate::Off);
    }
    if p >= 1.0 {
        return Ok(Rate::Every(1));
    }
    Ok(Rate::Prob((p * u64::MAX as f64) as u64))
}

impl FaultPlan {
    /// An empty plan: every point off.
    pub fn none() -> FaultPlan {
        FaultPlan {
            points: std::array::from_fn(|_| PointState {
                rate: Rate::Off,
                seed: 0,
                n: AtomicU64::new(0),
            }),
            fired: AtomicU64::new(0),
        }
    }

    /// Parse a spec string (grammar in the module docs).  A point named
    /// twice keeps the last clause.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let mut parts = clause.splitn(3, ':');
            let (name, rate, seed) = match (parts.next(), parts.next(), parts.next()) {
                (Some(a), Some(b), Some(c)) => (a, b, c),
                _ => {
                    return Err(Error::config(format!(
                        "fault spec: clause '{clause}' is not point:rate:seed"
                    )))
                }
            };
            let idx = POINT_NAMES
                .iter()
                .position(|p| *p == name)
                .ok_or_else(|| {
                    Error::config(format!(
                        "fault spec: unknown point '{name}' (expected one of {})",
                        POINT_NAMES.join(", ")
                    ))
                })?;
            let seed: u64 = seed
                .parse()
                .map_err(|_| Error::config(format!("fault spec: bad seed '{seed}'")))?;
            plan.points[idx] = PointState {
                rate: parse_rate(rate)?,
                seed,
                n: AtomicU64::new(0),
            };
        }
        Ok(plan)
    }

    /// Evaluate one injection point: advances the point's counter and
    /// returns whether the fault fires on this evaluation.
    pub fn fires(&self, point: FaultPoint) -> bool {
        let st = &self.points[point as usize];
        let hit = match st.rate {
            Rate::Off => return false,
            Rate::Prob(threshold) => {
                let n = st.n.fetch_add(1, Ordering::Relaxed);
                splitmix64(st.seed ^ splitmix64(n)) < threshold
            }
            Rate::Every(k) => {
                let n = st.n.fetch_add(1, Ordering::Relaxed);
                (n + 1) % k == 0
            }
            Rate::Once(k) => {
                let n = st.n.fetch_add(1, Ordering::Relaxed);
                n + 1 == k
            }
        };
        if hit {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Total faults fired so far across all points (feeds the
    /// `repro_serve_faults_injected_total` metric).
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// True if any point can ever fire (used to skip arming entirely).
    pub fn is_armed(&self) -> bool {
        self.points.iter().any(|p| !matches!(p.rate, Rate::Off))
    }
}

/// Evaluate the `tick_panic` point for sequence `key`; panics with a
/// [`SeqPanic`] payload if it fires.  The payload (not a string) lets
/// the engine's `catch_unwind` attribute the panic to one sequence.
pub fn maybe_tick_panic(plan: &FaultPlan, key: u64) {
    if plan.fires(FaultPoint::TickPanic) {
        std::panic::panic_any(SeqPanic { key });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_rate_forms() {
        let p = FaultPlan::parse("alloc:0.5:7,adapter_io:1/3:1,tick_panic:@2:9").unwrap();
        assert!(p.is_armed());
        // 1/3 fires on evaluations 3, 6, ...
        assert!(!p.fires(FaultPoint::AdapterIo));
        assert!(!p.fires(FaultPoint::AdapterIo));
        assert!(p.fires(FaultPoint::AdapterIo));
        assert!(!p.fires(FaultPoint::AdapterIo));
        // @2 fires exactly on the second evaluation.
        assert!(!p.fires(FaultPoint::TickPanic));
        assert!(p.fires(FaultPoint::TickPanic));
        assert!(!p.fires(FaultPoint::TickPanic));
        // conn_write absent -> off.
        assert!(!p.fires(FaultPoint::ConnWrite));
        assert_eq!(p.fired(), 2, "one adapter_io hit + one tick_panic hit");
    }

    #[test]
    fn probability_is_deterministic_and_plausible() {
        let a = FaultPlan::parse("alloc:0.25:42").unwrap();
        let b = FaultPlan::parse("alloc:0.25:42").unwrap();
        let fires_a: Vec<bool> = (0..1000).map(|_| a.fires(FaultPoint::Alloc)).collect();
        let fires_b: Vec<bool> = (0..1000).map(|_| b.fires(FaultPoint::Alloc)).collect();
        assert_eq!(fires_a, fires_b, "same seed must fire identically");
        let hits = fires_a.iter().filter(|f| **f).count();
        assert!((150..350).contains(&hits), "0.25 rate fired {hits}/1000");
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("alloc:0.5").is_err());
        assert!(FaultPlan::parse("bogus:0.5:1").is_err());
        assert!(FaultPlan::parse("alloc:2.0:1").is_err());
        assert!(FaultPlan::parse("alloc:@0:1").is_err());
        assert!(FaultPlan::parse("alloc:1/0:1").is_err());
        assert!(FaultPlan::parse("").unwrap().is_armed() == false);
    }

    #[test]
    fn zero_probability_is_off() {
        let p = FaultPlan::parse("alloc:0:1").unwrap();
        assert!(!p.is_armed());
        assert!((0..100).all(|_| !p.fires(FaultPoint::Alloc)));
    }
}
