//! Lock-light metrics registry: atomic counters/gauges + fixed-bucket
//! histograms.
//!
//! Handles are `Arc`s to plain atomic cells — updating one is a single
//! relaxed RMW, safe from any thread (scheduler, connection handlers,
//! kernel-pool lanes) with no lock.  The registry's `Mutex` guards only
//! the entry LIST, taken at registration and snapshot time; the serve
//! hot path registers everything up front and never touches it again.
//!
//! Histogram sums are accumulated in fixed-point nanounits (1e-9) so a
//! concurrent `observe` is one bucket RMW plus one sum RMW with no
//! compare-and-swap loop; `f64` values round to the nearest nanounit,
//! which is far below the resolution of anything we time.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram with explicit upper bounds (Prometheus `le`
/// semantics: a value lands in the first bucket whose bound is >= it;
/// one implicit overflow bucket catches the rest).
pub struct Histo {
    bounds: Vec<f64>,
    /// Per-bucket (NON-cumulative) counts; `len == bounds.len() + 1`.
    buckets: Vec<AtomicU64>,
    sum_nanos: AtomicU64,
}

impl Histo {
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histo {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum_nanos: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        let nanos = if v.is_finite() && v > 0.0 { (v * 1e9).round() as u64 } else { 0 };
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// One consistent read of the per-bucket counts (oldest-to-overflow).
    fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histo(Arc<Histo>),
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    help: String,
    metric: Metric,
}

/// A point-in-time reading of one registered metric.
#[derive(Clone, Debug)]
pub struct MetricSnapshot {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub help: String,
    pub value: MetricValue,
}

#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    /// Non-cumulative bucket counts aligned with `bounds` plus one
    /// trailing overflow (+Inf) bucket; `count` is their sum at snapshot
    /// time, `sum` the accumulated observed total.
    Histo { bounds: Vec<f64>, buckets: Vec<u64>, count: u64, sum: f64 },
}

/// The metric registry.  Registration is idempotent per
/// `(name, labels)`: re-registering returns the existing handle (kinds
/// must match — a kind clash is a programming error and panics).
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

fn own_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

impl Registry {
    pub fn counter(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Arc<Counter> {
        let labels = own_labels(labels);
        let mut entries = self.entries.lock().expect("registry poisoned");
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            match &e.metric {
                Metric::Counter(c) => return Arc::clone(c),
                _ => panic!("metric '{name}' re-registered with a different kind"),
            }
        }
        let c = Arc::new(Counter::default());
        entries.push(Entry {
            name: name.to_string(),
            labels,
            help: help.to_string(),
            metric: Metric::Counter(Arc::clone(&c)),
        });
        c
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Arc<Gauge> {
        let labels = own_labels(labels);
        let mut entries = self.entries.lock().expect("registry poisoned");
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            match &e.metric {
                Metric::Gauge(g) => return Arc::clone(g),
                _ => panic!("metric '{name}' re-registered with a different kind"),
            }
        }
        let g = Arc::new(Gauge::default());
        entries.push(Entry {
            name: name.to_string(),
            labels,
            help: help.to_string(),
            metric: Metric::Gauge(Arc::clone(&g)),
        });
        g
    }

    pub fn histogram(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        bounds: &[f64],
    ) -> Arc<Histo> {
        let labels = own_labels(labels);
        let mut entries = self.entries.lock().expect("registry poisoned");
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            match &e.metric {
                Metric::Histo(h) => return Arc::clone(h),
                _ => panic!("metric '{name}' re-registered with a different kind"),
            }
        }
        let h = Arc::new(Histo::new(bounds));
        entries.push(Entry {
            name: name.to_string(),
            labels,
            help: help.to_string(),
            metric: Metric::Histo(Arc::clone(&h)),
        });
        h
    }

    /// Read every metric, in registration order (families stay
    /// contiguous because each family's labeled children register
    /// back-to-back).
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let entries = self.entries.lock().expect("registry poisoned");
        entries
            .iter()
            .map(|e| MetricSnapshot {
                name: e.name.clone(),
                labels: e.labels.clone(),
                help: e.help.clone(),
                value: match &e.metric {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histo(h) => {
                        let buckets = h.bucket_counts();
                        let count = buckets.iter().sum();
                        MetricValue::Histo {
                            bounds: h.bounds.clone(),
                            buckets,
                            count,
                            sum: h.sum(),
                        }
                    }
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_basics() {
        let reg = Registry::default();
        let c = reg.counter("hits_total", &[], "hits");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = reg.gauge("depth", &[], "queue depth");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
        // re-registration returns the SAME cell
        let c2 = reg.counter("hits_total", &[], "hits");
        c2.inc();
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histo::new(&[0.1, 1.0, 10.0]);
        for v in [0.05, 0.5, 0.5, 5.0, 50.0] {
            h.observe(v);
        }
        assert_eq!(h.bucket_counts(), vec![1, 2, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 56.05).abs() < 1e-6);
    }

    #[test]
    fn label_sets_are_distinct_children() {
        let reg = Registry::default();
        let a = reg.counter("done_total", &[("reason", "length")], "done");
        let b = reg.counter("done_total", &[("reason", "stop")], "done");
        a.add(2);
        b.add(3);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(matches!(snap[0].value, MetricValue::Counter(2)));
        assert!(matches!(snap[1].value, MetricValue::Counter(3)));
    }
}
