//! Prometheus text exposition (format 0.0.4) for `serve --metrics-addr`.
//!
//! Renders one [`crate::obs::Telemetry`] snapshot: every registered
//! metric family (`# HELP` / `# TYPE` once per family, one sample line
//! per labeled child), histograms as cumulative `_bucket{le=...}` series
//! ending in `le="+Inf"` plus `_sum`/`_count`, and the always-present
//! process families — kernel profiling accumulators, pool-lane busy
//! seconds, uptime, and a `build_info` pseudo-gauge.

use std::fmt::Write as _;

use super::profile;
use super::registry::{MetricSnapshot, MetricValue};
use super::Telemetry;

fn escape_label(v: &str, out: &mut String) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

/// `{k1="v1",k2="v2"}`, or nothing for an unlabeled sample.
fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<(&str, &str)> =
        labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    if let Some(kv) = extra {
        parts.push(kv);
    }
    if parts.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in parts.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        escape_label(v, &mut out);
        out.push('"');
    }
    out.push('}');
    out
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn render_snapshot(s: &MetricSnapshot, out: &mut String) {
    match &s.value {
        MetricValue::Counter(v) => {
            let _ = writeln!(out, "{}{} {v}", s.name, label_block(&s.labels, None));
        }
        MetricValue::Gauge(v) => {
            let _ = writeln!(out, "{}{} {v}", s.name, label_block(&s.labels, None));
        }
        MetricValue::Histo { bounds, buckets, count, sum } => {
            // exposition buckets are CUMULATIVE; the registry stores
            // per-bucket counts, so running-sum here
            let mut cum = 0u64;
            for (i, n) in buckets.iter().enumerate() {
                cum += n;
                let le = if i < bounds.len() { fmt_f64(bounds[i]) } else { "+Inf".to_string() };
                let _ = writeln!(
                    out,
                    "{}_bucket{} {cum}",
                    s.name,
                    label_block(&s.labels, Some(("le", &le)))
                );
            }
            let _ =
                writeln!(out, "{}_sum{} {}", s.name, label_block(&s.labels, None), fmt_f64(*sum));
            let _ = writeln!(out, "{}_count{} {count}", s.name, label_block(&s.labels, None));
        }
    }
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render the full exposition document.
pub fn render(obs: &Telemetry) -> String {
    let mut out = String::with_capacity(8 * 1024);
    let snaps = obs.registry.snapshot();
    let mut i = 0;
    // families stay contiguous in registration order; emit HELP/TYPE once
    // per name run, then every labeled child
    while i < snaps.len() {
        let name = &snaps[i].name;
        let kind = match snaps[i].value {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histo { .. } => "histogram",
        };
        header(&mut out, name, kind, &snaps[i].help);
        while i < snaps.len() && snaps[i].name == *name {
            render_snapshot(&snaps[i], &mut out);
            i += 1;
        }
    }

    // kernel profiling accumulators (all zero unless --profile/REPRO_PROF)
    let kernels = profile::snapshot();
    header(&mut out, "kernel_calls_total", "counter", "Kernel invocations by kind");
    for (name, k) in profile::KIND_NAMES.iter().zip(kernels.iter()) {
        let _ = writeln!(out, "kernel_calls_total{{kind=\"{name}\"}} {}", k.calls);
    }
    header(&mut out, "kernel_time_seconds_total", "counter", "Busy time in kernels by kind");
    for (name, k) in profile::KIND_NAMES.iter().zip(kernels.iter()) {
        let _ = writeln!(
            out,
            "kernel_time_seconds_total{{kind=\"{name}\"}} {}",
            fmt_f64(k.ns as f64 / 1e9)
        );
    }
    header(&mut out, "kernel_flops_total", "counter", "Floating-point operations by kernel kind");
    for (name, k) in profile::KIND_NAMES.iter().zip(kernels.iter()) {
        let _ = writeln!(out, "kernel_flops_total{{kind=\"{name}\"}} {}", k.flops);
    }

    let build = super::build_info();
    header(
        &mut out,
        "pool_lane_busy_seconds_total",
        "counter",
        "Busy time per kernel-pool lane (lane 0 = caller thread)",
    );
    for (lane, ns) in profile::lane_snapshot(build.threads).iter().enumerate() {
        let _ = writeln!(
            out,
            "pool_lane_busy_seconds_total{{lane=\"{lane}\"}} {}",
            fmt_f64(*ns as f64 / 1e9)
        );
    }

    header(&mut out, "uptime_seconds", "gauge", "Seconds since engine start");
    let _ = writeln!(out, "uptime_seconds {}", fmt_f64(obs.uptime_secs()));

    header(&mut out, "build_info", "gauge", "Build identity (value is always 1)");
    let labels = vec![
        ("version".to_string(), build.version.to_string()),
        ("kernel".to_string(), build.kernel.to_string()),
        ("threads".to_string(), build.threads.to_string()),
        ("features".to_string(), build.features.join(",")),
    ];
    let _ = writeln!(out, "build_info{} 1", label_block(&labels, None));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Telemetry;

    #[test]
    fn exposition_has_families_and_cumulative_buckets() {
        let obs = Telemetry::new(16);
        obs.metrics.ticks_total.add(3);
        obs.metrics.kv_blocks_resident.set(12);
        obs.metrics.tick_seconds.observe(0.002);
        obs.metrics.tick_seconds.observe(0.2);
        let text = render(&obs);
        assert!(text.contains("# TYPE ticks_total counter"));
        assert!(text.contains("\nticks_total 3\n"));
        assert!(text.contains("\nkv_blocks_resident 12\n"));
        assert!(text.contains("# TYPE tick_seconds histogram"));
        assert!(text.contains("tick_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("\ntick_seconds_count 2\n"));
        assert!(text.contains("tick_phase_seconds_bucket{phase=\"prefill\",le=\"+Inf\"} 0"));
        assert!(text.contains("requests_finished_total{reason=\"length\"} 0"));
        assert!(text.contains("kernel_time_seconds_total{kind=\"fused_panel\"}"));
        assert!(text.contains("pool_lane_busy_seconds_total{lane=\"0\"}"));
        assert!(text.contains("# TYPE build_info gauge"));

        // cumulative le series: counts must never decrease along a family
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.starts_with("tick_seconds_bucket{")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= prev, "bucket series must be cumulative: {line}");
            prev = n;
        }
        assert_eq!(prev, 2, "+Inf bucket equals count");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut s = String::new();
        escape_label("a\"b\\c\nd", &mut s);
        assert_eq!(s, "a\\\"b\\\\c\\nd");
    }
}
