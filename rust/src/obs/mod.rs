//! Engine telemetry: a lock-light metrics registry, per-tick phase
//! tracing, per-request lifecycle spans, and kernel profiling hooks —
//! the observability layer under `repro serve`.
//!
//! Design constraints (pinned by `tests/obs.rs`):
//!
//! * **Near-zero when idle.** Counters and gauges are single atomic
//!   RMWs; histograms are one atomic add into a fixed bucket.  The
//!   registry's `Mutex` is touched only at registration and exposition
//!   time, never on the hot path.  Kernel hooks cost ONE relaxed atomic
//!   load when profiling is off.
//! * **Bitwise-invisible.** Telemetry only times and counts around the
//!   compute; it never touches inputs, outputs, or RNG state, so token
//!   streams with `--metrics-addr --trace-log --profile` all enabled are
//!   byte-identical to a telemetry-off run (CI `cmp`s the transcripts).
//! * **Derived views, not hand-kept fields.** The scheduler's
//!   per-request wall-clock accounting lives in one [`RequestSpan`]
//!   per sequence; `RequestStats` is rendered from the span at eviction.
//!
//! Layout:
//!
//! * [`registry`] — atomic [`Counter`]/[`Gauge`]/[`Histo`] handles behind
//!   an `Arc`-shared [`Registry`]; snapshot-based exposition.
//! * [`trace`] — the fixed-capacity [`TraceRing`] of per-tick
//!   [`TickRecord`]s (phase nanos, batch size, KV page delta, spec
//!   acceptance) plus the per-request [`RequestSpan`].
//! * [`prom`] — Prometheus text exposition for the `/metrics` listener.
//! * [`profile`] — process-wide kernel profiling accumulators (per-kind
//!   time + FLOPs, per-pool-lane busy nanos), gated behind
//!   `--profile` / `REPRO_PROF`.
//! * [`fault`] — the deterministic fault-injection harness
//!   (`--fault` / `REPRO_FAULT`) that exercises the engine's recovery
//!   paths: pool-allocation failures, adapter-load I/O errors, injected
//!   tick panics, broken connection writes, and spill-file read errors.

pub mod fault;
pub mod profile;
pub mod prom;
pub mod registry;
pub mod trace;

use std::sync::{Arc, Mutex};
use std::time::Instant;

pub use fault::{FaultPlan, FaultPoint, SeqPanic};
pub use registry::{Counter, Gauge, Histo, MetricValue, Registry};
pub use trace::{KernelTickDelta, RequestSpan, TickRecord, TraceRing, N_PHASES, PHASE_NAMES};

/// Default tick-trace ring capacity (`serve --trace-cap` overrides).
pub const DEFAULT_TRACE_CAP: usize = 1024;

/// Latency-shaped histogram bounds (seconds): 10us .. 2.5s.
pub const SECONDS_BOUNDS: &[f64] = &[
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25,
    0.5, 1.0, 2.5,
];

/// Batch-size histogram bounds (sequences per tick).
pub const BATCH_BOUNDS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// Build/runtime identity for the `stats` frame and `/metrics`:
/// crate version, selected kernel dispatch, pool width, cargo features.
#[derive(Clone, Debug)]
pub struct BuildInfo {
    pub version: &'static str,
    pub kernel: &'static str,
    pub threads: usize,
    pub features: Vec<&'static str>,
}

/// Snapshot the process build identity (kernel dispatch latches on first
/// use, same as the compute path).
pub fn build_info() -> BuildInfo {
    let mut features = Vec::new();
    if cfg!(feature = "xla") {
        features.push("xla");
    }
    BuildInfo {
        version: env!("CARGO_PKG_VERSION"),
        kernel: crate::kernels::active().name(),
        threads: crate::kernels::pool::pool_threads(),
        features,
    }
}

/// Pre-registered handles for every engine metric family.  One instance
/// per [`Telemetry`]; the scheduler/server update these directly so the
/// hot path never hashes a metric name.
pub struct EngineMetrics {
    pub ticks_total: Arc<Counter>,
    pub tick_seconds: Arc<Histo>,
    /// One histogram per phase, indexed like [`PHASE_NAMES`].
    pub tick_phase_seconds: Vec<Arc<Histo>>,
    pub batch_size: Arc<Histo>,
    pub requests_admitted_total: Arc<Counter>,
    pub requests_rejected_total: Arc<Counter>,
    /// `(reason, counter)` per [`FinishReason`] string.
    pub requests_finished: Vec<(&'static str, Arc<Counter>)>,
    pub tokens_emitted_total: Arc<Counter>,
    pub adapter_tokens_total: Arc<Counter>,
    pub baseline_tokens_total: Arc<Counter>,
    pub adapters_registered: Arc<Gauge>,
    pub queue_seconds: Arc<Histo>,
    pub request_seconds: Arc<Histo>,
    pub prefill_seconds: Arc<Histo>,
    pub kv_blocks_resident: Arc<Gauge>,
    pub kv_blocks_free: Arc<Gauge>,
    pub kv_blocks_shared: Arc<Gauge>,
    pub kv_blocks_limit: Arc<Gauge>,
    pub kv_bytes_resident: Arc<Gauge>,
    pub kv_bytes_peak: Arc<Gauge>,
    pub active_sequences: Arc<Gauge>,
    pub pending_requests: Arc<Gauge>,
    pub spec_proposed_total: Arc<Counter>,
    pub spec_accepted_total: Arc<Counter>,
    pub spec_cycles_total: Arc<Counter>,
    pub spec_fallbacks_total: Arc<Counter>,
    pub overload_rejections_total: Arc<Counter>,
    pub deadline_expirations_total: Arc<Counter>,
    pub quarantines_total: Arc<Counter>,
    pub slow_reader_evictions_total: Arc<Counter>,
    pub faults_injected_total: Arc<Counter>,
    /// Tiered-KV series (`--kv-spill`); all zero when no tier is
    /// attached.  Monotonic tallies are exposed as gauges set from the
    /// tier's own counters each tick, so the hot path stays a snapshot
    /// copy instead of per-event atomics.
    pub tier_blocks_spilled: Arc<Gauge>,
    pub tier_bytes_spilled: Arc<Gauge>,
    pub tier_spill_writes: Arc<Gauge>,
    pub tier_spill_reads: Arc<Gauge>,
    pub tier_preemptions: Arc<Gauge>,
    pub tier_resumes: Arc<Gauge>,
    pub tier_suspended: Arc<Gauge>,
    pub tier_restores: Arc<Gauge>,
    pub tier_restore_failures: Arc<Gauge>,
    pub tier_sessions_stored: Arc<Gauge>,
    pub tier_session_resumes: Arc<Gauge>,
    pub tier_prefix_pages: Arc<Gauge>,
    pub tier_prefix_hits: Arc<Gauge>,
    pub tier_prefix_misses: Arc<Gauge>,
    pub tier_promote_seconds: Arc<Histo>,
}

impl EngineMetrics {
    fn new(reg: &Registry) -> Self {
        let phase_histos = PHASE_NAMES
            .iter()
            .map(|p| {
                reg.histogram(
                    "tick_phase_seconds",
                    &[("phase", p)],
                    "Time per scheduler-tick phase",
                    SECONDS_BOUNDS,
                )
            })
            .collect();
        let finished = ["length", "stop", "capacity", "cancelled", "deadline", "internal"]
            .into_iter()
            .map(|r| {
                (
                    r,
                    reg.counter(
                        "requests_finished_total",
                        &[("reason", r)],
                        "Requests finished, by finish reason",
                    ),
                )
            })
            .collect();
        EngineMetrics {
            ticks_total: reg.counter("ticks_total", &[], "Scheduler steps executed"),
            tick_seconds: reg.histogram(
                "tick_seconds",
                &[],
                "Wall time per scheduler step",
                SECONDS_BOUNDS,
            ),
            tick_phase_seconds: phase_histos,
            batch_size: reg.histogram(
                "batch_size",
                &[],
                "Active sequences per tick (post-admission)",
                BATCH_BOUNDS,
            ),
            requests_admitted_total: reg.counter(
                "requests_admitted_total",
                &[],
                "Requests admitted into the batch",
            ),
            requests_rejected_total: reg.counter(
                "requests_rejected_total",
                &[],
                "Requests rejected before admission",
            ),
            requests_finished: finished,
            tokens_emitted_total: reg.counter(
                "tokens_emitted_total",
                &[],
                "Generated tokens streamed to clients",
            ),
            adapter_tokens_total: reg.counter(
                "adapter_tokens_total",
                &[],
                "Tokens emitted on adapter-routed sequences",
            ),
            baseline_tokens_total: reg.counter(
                "baseline_tokens_total",
                &[],
                "Tokens emitted on the default (no-adapter) path",
            ),
            adapters_registered: reg.gauge(
                "adapters_registered",
                &[],
                "Adapters currently in the runtime registry",
            ),
            queue_seconds: reg.histogram(
                "request_queue_seconds",
                &[],
                "Submission -> admission wait per request",
                SECONDS_BOUNDS,
            ),
            request_seconds: reg.histogram(
                "request_seconds",
                &[],
                "Admission -> completion wall time per request",
                SECONDS_BOUNDS,
            ),
            prefill_seconds: reg.histogram(
                "request_prefill_seconds",
                &[],
                "Batched prompt prefill time per request",
                SECONDS_BOUNDS,
            ),
            kv_blocks_resident: reg.gauge(
                "kv_blocks_resident",
                &[],
                "KV pages currently resident in the target pool",
            ),
            kv_blocks_free: reg.gauge("kv_blocks_free", &[], "KV pages free in the target pool"),
            kv_blocks_shared: reg.gauge(
                "kv_blocks_shared",
                &[],
                "KV pages shared by >1 sequence (prefix sharing)",
            ),
            kv_blocks_limit: reg.gauge("kv_blocks_limit", &[], "KV page budget of the target pool"),
            kv_bytes_resident: reg.gauge(
                "kv_bytes_resident",
                &[],
                "Bytes resident in the target KV pool (layout-aware: sealed quantized pages count packed size)",
            ),
            kv_bytes_peak: reg.gauge(
                "kv_bytes_peak",
                &[],
                "High-water resident bytes of the target KV pool",
            ),
            active_sequences: reg.gauge("active_sequences", &[], "Sequences decoding this tick"),
            pending_requests: reg.gauge("pending_requests", &[], "Requests queued for admission"),
            spec_proposed_total: reg.counter(
                "spec_proposed_total",
                &[],
                "Draft tokens proposed (speculative decoding)",
            ),
            spec_accepted_total: reg.counter(
                "spec_accepted_total",
                &[],
                "Draft tokens the target accepted",
            ),
            spec_cycles_total: reg.counter(
                "spec_cycles_total",
                &[],
                "Per-sequence draft/verify cycles run",
            ),
            spec_fallbacks_total: reg.counter(
                "spec_fallbacks_total",
                &[],
                "Sequences permanently fallen back to plain decode",
            ),
            overload_rejections_total: reg.counter(
                "overload_rejections_total",
                &[],
                "Submissions refused with an overloaded error frame",
            ),
            deadline_expirations_total: reg.counter(
                "deadline_expirations_total",
                &[],
                "Requests rejected or finished because their deadline passed",
            ),
            quarantines_total: reg.counter(
                "quarantines_total",
                &[],
                "Sequences quarantined after a scheduler-tick panic",
            ),
            slow_reader_evictions_total: reg.counter(
                "slow_reader_evictions_total",
                &[],
                "Connections evicted for staying backlogged past the budget",
            ),
            faults_injected_total: reg.counter(
                "faults_injected_total",
                &[],
                "Faults fired by the injection harness (--fault / REPRO_FAULT)",
            ),
            tier_blocks_spilled: reg.gauge(
                "tier_blocks_spilled",
                &[],
                "KV pages currently spilled to the disk tier",
            ),
            tier_bytes_spilled: reg.gauge(
                "tier_bytes_spilled",
                &[],
                "Live payload bytes in the spill file",
            ),
            tier_spill_writes: reg.gauge("tier_spill_writes", &[], "Spill-slot writes so far"),
            tier_spill_reads: reg.gauge("tier_spill_reads", &[], "Spill-slot reads so far"),
            tier_preemptions: reg.gauge(
                "tier_preemptions",
                &[],
                "Sequences preempted to the disk tier so far",
            ),
            tier_resumes: reg.gauge(
                "tier_resumes",
                &[],
                "Suspended sequences resumed from the disk tier so far",
            ),
            tier_suspended: reg.gauge(
                "tier_suspended",
                &[],
                "Sequences suspended on the disk tier right now",
            ),
            tier_restores: reg.gauge("tier_restores", &[], "KV pages restored from disk so far"),
            tier_restore_failures: reg.gauge(
                "tier_restore_failures",
                &[],
                "Failed page restores (CRC / I/O / injected faults)",
            ),
            tier_sessions_stored: reg.gauge(
                "tier_sessions_stored",
                &[],
                "Sessions parked on the disk tier right now",
            ),
            tier_session_resumes: reg.gauge(
                "tier_session_resumes",
                &[],
                "Session continuations served from spilled state",
            ),
            tier_prefix_pages: reg.gauge(
                "tier_prefix_pages",
                &[],
                "Pages published in the persistent prefix store",
            ),
            tier_prefix_hits: reg.gauge(
                "tier_prefix_hits",
                &[],
                "Admissions that matched at least one stored prefix page",
            ),
            tier_prefix_misses: reg.gauge(
                "tier_prefix_misses",
                &[],
                "Admissions that consulted the prefix store and found nothing",
            ),
            tier_promote_seconds: reg.histogram(
                "tier_promote_seconds",
                &[],
                "Prefix promotion latency (disk -> pool page run)",
                SECONDS_BOUNDS,
            ),
        }
    }

    /// The finished-requests counter for a finish-reason string.
    pub fn finished(&self, reason: &str) -> Option<&Counter> {
        self.requests_finished
            .iter()
            .find(|(r, _)| *r == reason)
            .map(|(_, c)| c.as_ref())
    }
}

/// One engine's telemetry: the metrics registry + typed handles, the
/// tick-trace ring, and the start-of-life instant (uptime).  Shared via
/// `Arc` by the scheduler (writes), the server threads (exposition), and
/// the trace-log writer.
pub struct Telemetry {
    pub registry: Registry,
    pub metrics: EngineMetrics,
    ring: Mutex<TraceRing>,
    started: Instant,
}

impl Telemetry {
    pub fn new(trace_cap: usize) -> Arc<Self> {
        let registry = Registry::default();
        let metrics = EngineMetrics::new(&registry);
        Arc::new(Telemetry {
            registry,
            metrics,
            ring: Mutex::new(TraceRing::new(trace_cap)),
            started: Instant::now(),
        })
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Stamp `rec` with its sequence number and engine-relative time and
    /// append it to the ring (oldest record drops at capacity).
    pub fn record_tick(&self, mut rec: TickRecord) {
        let mut ring = self.ring.lock().expect("trace ring poisoned");
        rec.seq = ring.total();
        rec.at_secs = self.started.elapsed().as_secs_f64();
        ring.push(rec);
    }

    /// `(total ticks ever, last n records oldest-first)`.
    pub fn last_ticks(&self, n: usize) -> (u64, Vec<TickRecord>) {
        let ring = self.ring.lock().expect("trace ring poisoned");
        (ring.total(), ring.last(n))
    }

    /// The most recent tick record, if any (trace-log appending).
    pub fn last_tick(&self) -> Option<TickRecord> {
        let ring = self.ring.lock().expect("trace ring poisoned");
        ring.last(1).pop()
    }
}
