//! Crate-wide error type. Deliberately small: everything funnels into a
//! String-carrying enum so library consumers get readable failures without
//! pulling an error-handling framework into the public API.

use std::fmt;

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the reproduction stack.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, literal conversion).
    Xla(String),
    /// Filesystem / checkpoint / artifact-IO failures.
    Io(String),
    /// Artifact manifest problems (missing key, shape mismatch, ...).
    Manifest(String),
    /// Shape or dimension mismatch in host-side tensor math.
    Shape(String),
    /// Configuration parsing / validation problems.
    Config(String),
    /// Numerical failure (non-finite loss, singular matrix, ...).
    Numeric(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Io(m) => write!(f, "io: {m}"),
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Shape(m) => write!(f, "shape: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Numeric(m) => write!(f, "numeric: {m}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Shorthand constructors used throughout the crate.
impl Error {
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn manifest(msg: impl Into<String>) -> Self {
        Error::Manifest(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn numeric(msg: impl Into<String>) -> Self {
        Error::Numeric(msg.into())
    }
    pub fn io(msg: impl Into<String>) -> Self {
        Error::Io(msg.into())
    }
}
