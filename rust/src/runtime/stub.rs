//! Stub runtime used when the `xla` cargo feature is disabled (the
//! default).  Construction always succeeds so artifact-free code paths —
//! host-side quantizers, the native inference engine, unit tests — run
//! unchanged; anything that actually needs to *execute* an artifact gets
//! a clear "artifact runtime unavailable" error instead of a link-time
//! dependency on PJRT.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::{Bindings, ExecStats, Outputs};

/// A loaded artifact (stub: manifest only, never constructed).
pub struct Artifact {
    pub spec: ArtifactSpec,
}

/// Artifact-runtime stand-in: directory bookkeeping works, execution
/// errors out with a pointer at the `xla` feature and the native engine.
pub struct Runtime {
    artifacts_dir: PathBuf,
}

fn unavailable(what: &str) -> Error {
    Error::Xla(format!(
        "artifact runtime unavailable: cannot execute '{what}' — this build has no PJRT \
         support (compiled without the `xla` cargo feature). To enable it, add the \
         vendored `xla` crate to [dependencies] in Cargo.toml, build with \
         `--features xla`, and run `make artifacts`; or use the native host engine \
         (`repro generate`, `repro bench-infer`, ModelMode::Native*)."
    ))
}

impl Runtime {
    /// Create against an artifacts directory (default `artifacts/`).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Runtime { artifacts_dir: artifacts_dir.into() })
    }

    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.artifacts_dir
    }

    /// Does the artifact exist on disk?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load + compile an artifact — always unavailable in stub builds.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        Err(unavailable(name))
    }

    /// Execute a loaded artifact — always unavailable in stub builds.
    pub fn execute(&self, artifact: &Artifact, _bindings: &Bindings) -> Result<Outputs> {
        Err(unavailable(&artifact.spec.name))
    }

    /// Load-and-execute by name — always unavailable in stub builds.
    pub fn run(&self, name: &str, _bindings: &Bindings) -> Result<Outputs> {
        Err(unavailable(name))
    }

    /// Execution statistics snapshot (always empty in stub builds).
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        HashMap::new()
    }

    /// Human-readable stats report.
    pub fn stats_report(&self) -> String {
        "artifact runtime unavailable (built without the `xla` feature)\n".to_string()
    }
}
