//! The real PJRT-backed runtime (cargo feature `xla`).
//!
//! Compiling this module requires the external `xla` crate; the feature
//! is off by default so a clean offline checkout still builds (the stub
//! sibling takes this module's place).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactSpec, DType};
use crate::runtime::{Bindings, ExecStats, Outputs, Value};
use crate::tensor::Tensor;

/// A loaded, compiled artifact.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
    verbose: bool,
}

impl Runtime {
    /// Create against an artifacts directory (default `artifacts/`).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.into(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            verbose: std::env::var("APIQ_VERBOSE").is_ok(),
        })
    }

    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.artifacts_dir
    }

    /// Does the artifact exist on disk?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load + compile an artifact (cached).
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let hlo_path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man_path = self.artifacts_dir.join(format!("{name}.manifest"));
        let spec = ArtifactSpec::parse_file(name, &man_path)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| Error::Xla(format!("parse {}: {e}", hlo_path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compile {name}: {e}")))?;
        let art = Rc::new(Artifact { spec, exe });
        if self.verbose {
            eprintln!(
                "[runtime] compiled {name} ({} args, {} outs) in {:.2}s",
                art.spec.args.len(),
                art.spec.rets.len(),
                t0.elapsed().as_secs_f64()
            );
        }
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Execute an artifact with the given bindings; returns named outputs.
    ///
    /// Inputs go host -> device via `buffer_from_host_buffer` + `execute_b`
    /// rather than `execute::<Literal>`: the xla crate's literal-based
    /// `execute` *leaks every input device buffer* (its C shim releases
    /// the buffers and never frees them), which at one training step per
    /// call exhausts host RAM in minutes.  Owned `PjRtBuffer`s drop
    /// correctly.  This also skips one host-side copy per argument.
    pub fn execute(&self, artifact: &Artifact, bindings: &Bindings) -> Result<Outputs> {
        let t_all = Instant::now();
        // Build input device buffers in manifest order, validating shapes.
        let mut buffers = Vec::with_capacity(artifact.spec.args.len());
        for arg in &artifact.spec.args {
            let buf = match (bindings.lookup(&arg.key)?, arg.dtype) {
                (Value::Scalar(v), DType::F32) => {
                    if !arg.shape.is_empty() {
                        return Err(Error::manifest(format!(
                            "{}: scalar bound to non-scalar arg {:?}",
                            arg.key, arg.shape
                        )));
                    }
                    self.client.buffer_from_host_buffer(&[v], &[], None)?
                }
                (Value::F32(t), DType::F32) => {
                    if t.shape() != arg.shape.as_slice() {
                        return Err(Error::manifest(format!(
                            "{}: bound shape {:?}, manifest wants {:?}",
                            arg.key,
                            t.shape(),
                            arg.shape
                        )));
                    }
                    self.client.buffer_from_host_buffer(t.data(), &arg.shape, None)?
                }
                (Value::I32(t), DType::I32) => {
                    if t.shape() != arg.shape.as_slice() {
                        return Err(Error::manifest(format!(
                            "{}: bound int shape {:?}, manifest wants {:?}",
                            arg.key,
                            t.shape(),
                            arg.shape
                        )));
                    }
                    self.client.buffer_from_host_buffer(t.data(), &arg.shape, None)?
                }
                (_, dt) => {
                    return Err(Error::manifest(format!(
                        "{}: binding dtype mismatch (manifest {dt:?})",
                        arg.key
                    )))
                }
            };
            buffers.push(buf);
        }
        let t_exec = Instant::now();
        let h2d = t_exec.duration_since(t_all).as_secs_f64();
        let result = artifact.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let t_d2h = Instant::now();
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        if tuple.len() != artifact.spec.rets.len() {
            return Err(Error::manifest(format!(
                "{}: {} outputs, manifest wants {}",
                artifact.spec.name,
                tuple.len(),
                artifact.spec.rets.len()
            )));
        }
        let mut map = HashMap::with_capacity(tuple.len());
        for (ret, lit) in artifact.spec.rets.iter().zip(tuple) {
            let data = match ret.dtype {
                DType::F32 => lit.to_vec::<f32>()?,
                DType::I32 => lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
            };
            map.insert(ret.key.clone(), Tensor::new(ret.shape.clone(), data)?);
        }
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(artifact.spec.name.clone()).or_default();
        s.calls += 1;
        s.total_secs += t_all.elapsed().as_secs_f64();
        s.h2d_secs += h2d;
        s.d2h_secs += t_d2h.elapsed().as_secs_f64();
        Ok(Outputs { map })
    }

    /// Convenience: load-and-execute by name.
    pub fn run(&self, name: &str, bindings: &Bindings) -> Result<Outputs> {
        let art = self.artifact(name)?;
        self.execute(&art, bindings)
    }

    /// Execution statistics snapshot (artifact name -> stats).
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    /// Human-readable stats report for the perf pass.
    pub fn stats_report(&self) -> String {
        let stats = self.stats.borrow();
        let mut rows: Vec<_> = stats.iter().collect();
        rows.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        let mut out = String::from(
            "artifact                                     calls   total(s)   h2d(s)   d2h(s)\n",
        );
        for (name, s) in rows {
            out.push_str(&format!(
                "{name:<44} {:>5} {:>9.3} {:>8.3} {:>8.3}\n",
                s.calls, s.total_secs, s.h2d_secs, s.d2h_secs
            ));
        }
        out
    }
}
