//! Artifact runtime: loads HLO-text artifacts produced by `make artifacts`,
//! compiles them once, and executes them with name-bound host tensors.
//!
//! Interchange contract (see `python/compile/aot.py`):
//!   * `artifacts/<name>.hlo.txt`  — HLO text (the 0.5.1-safe format)
//!   * `artifacts/<name>.manifest` — ordered `arg`/`ret` lines binding
//!     flat keys ("params/blocks.0.wq", "t", ...) to shapes/dtypes in
//!     exactly the lowered computation's parameter/tuple order.
//!
//! The runtime is the ONLY module that touches PJRT; everything above it
//! deals in `Tensor`s and `ParamStore`s.
//!
//! PJRT support is gated behind the off-by-default `xla` cargo feature
//! (the crate must build offline with no external dependencies).  Without
//! the feature, `Runtime` is a stub whose execution methods return a
//! clear "artifact runtime unavailable" error — the native host engine in
//! `crate::infer` serves models without any artifacts.

pub mod manifest;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{Artifact, Runtime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Artifact, Runtime};

pub use manifest::{ArtifactSpec, BufferSpec, DType};

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::model::ParamStore;
use crate::tensor::{IntTensor, Tensor};

/// Values bindable to artifact arguments.
pub enum Value<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    Scalar(f32),
}

/// Named bindings for one execution: group stores (bound by manifest key
/// prefix before '/'), whole-key tensors, and scalars.
#[derive(Default)]
pub struct Bindings<'a> {
    groups: HashMap<String, &'a ParamStore>,
    tensors: HashMap<String, &'a Tensor>,
    ints: HashMap<String, &'a IntTensor>,
    scalars: HashMap<String, f32>,
}

impl<'a> Bindings<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind a ParamStore to a manifest group ("params", "qp", "m", ...).
    pub fn group(mut self, name: &str, store: &'a ParamStore) -> Self {
        self.groups.insert(name.to_string(), store);
        self
    }

    /// Bind a whole-key f32 tensor ("x", "w", ...).
    pub fn tensor(mut self, name: &str, t: &'a Tensor) -> Self {
        self.tensors.insert(name.to_string(), t);
        self
    }

    /// Bind a whole-key i32 tensor ("tokens").
    pub fn int(mut self, name: &str, t: &'a IntTensor) -> Self {
        self.ints.insert(name.to_string(), t);
        self
    }

    /// Bind a scalar ("t", "lr", "bits", ...).
    pub fn scalar(mut self, name: &str, v: f32) -> Self {
        self.scalars.insert(name.to_string(), v);
        self
    }

    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    fn lookup(&self, key: &str) -> Result<Value<'a>> {
        if let Some((group, rest)) = key.split_once('/') {
            let store = self.groups.get(group).ok_or_else(|| {
                Error::manifest(format!("no binding for group '{group}' (key '{key}')"))
            })?;
            return Ok(Value::F32(store.require(rest)?));
        }
        if let Some(&v) = self.scalars.get(key) {
            return Ok(Value::Scalar(v));
        }
        if let Some(&t) = self.tensors.get(key) {
            return Ok(Value::F32(t));
        }
        if let Some(&t) = self.ints.get(key) {
            return Ok(Value::I32(t));
        }
        Err(Error::manifest(format!("no binding for key '{key}'")))
    }
}

/// Execution outputs keyed by manifest ret name.
#[derive(Debug, Default)]
pub struct Outputs {
    map: HashMap<String, Tensor>,
}

impl Outputs {
    pub fn get(&self, key: &str) -> Result<&Tensor> {
        self.map
            .get(key)
            .ok_or_else(|| Error::manifest(format!("no output '{key}'")))
    }

    pub fn scalar(&self, key: &str) -> Result<f32> {
        Ok(self.get(key)?.item())
    }

    /// Collect all outputs under `prefix/` into a ParamStore (stripped).
    pub fn group(&self, prefix: &str) -> ParamStore {
        let pfx = format!("{prefix}/");
        let mut ps = ParamStore::new();
        for (k, v) in &self.map {
            if let Some(rest) = k.strip_prefix(&pfx) {
                ps.insert(rest.to_string(), v.clone());
            }
        }
        ps
    }

    pub fn take(&mut self, key: &str) -> Result<Tensor> {
        self.map
            .remove(key)
            .ok_or_else(|| Error::manifest(format!("no output '{key}'")))
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

/// Cumulative execution statistics (per artifact), for the perf pass.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub h2d_secs: f64,
    pub d2h_secs: f64,
}
