//! Artifact manifest parsing.
//!
//! Line format (emitted by `python/compile/aot.py`):
//!
//!   arg params/blocks.0.wq f32 2 256 256
//!   arg t f32 0
//!   ret loss f32 0
//!
//! Order of `arg` lines == PJRT parameter order; order of `ret` lines ==
//! output tuple order.  Both orders are the jax pytree flattening
//! (sorted dict keys), which the Rust side never needs to re-derive —
//! it just binds by key.

use std::path::Path;

use crate::error::{Error, Result};

/// Element type of an artifact buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => Err(Error::manifest(format!("unknown dtype '{s}'"))),
        }
    }
}

/// One argument or return buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct BufferSpec {
    pub key: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl BufferSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest of one artifact.
#[derive(Clone, Debug, Default)]
pub struct ArtifactSpec {
    pub name: String,
    pub args: Vec<BufferSpec>,
    pub rets: Vec<BufferSpec>,
}

impl ArtifactSpec {
    pub fn parse(name: &str, text: &str) -> Result<Self> {
        let mut spec = ArtifactSpec { name: name.to_string(), ..Default::default() };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let kind = it.next().unwrap();
            let key = it
                .next()
                .ok_or_else(|| Error::manifest(format!("{name}:{lineno}: missing key")))?
                .to_string();
            let dtype = DType::parse(
                it.next()
                    .ok_or_else(|| Error::manifest(format!("{name}:{lineno}: missing dtype")))?,
            )?;
            let ndim: usize = it
                .next()
                .ok_or_else(|| Error::manifest(format!("{name}:{lineno}: missing ndim")))?
                .parse()
                .map_err(|e| Error::manifest(format!("{name}:{lineno}: bad ndim: {e}")))?;
            let shape: Vec<usize> = it
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|e| Error::manifest(format!("{name}:{lineno}: bad dim: {e}")))
                })
                .collect::<Result<_>>()?;
            if shape.len() != ndim {
                return Err(Error::manifest(format!(
                    "{name}:{lineno}: ndim {ndim} but {} dims",
                    shape.len()
                )));
            }
            let buf = BufferSpec { key, dtype, shape };
            match kind {
                "arg" => spec.args.push(buf),
                "ret" => spec.rets.push(buf),
                _ => return Err(Error::manifest(format!("{name}:{lineno}: bad kind '{kind}'"))),
            }
        }
        if spec.args.is_empty() {
            return Err(Error::manifest(format!("{name}: no args parsed")));
        }
        Ok(spec)
    }

    pub fn parse_file(name: &str, path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("{}: {e}", path.display())))?;
        Self::parse(name, &text)
    }

    /// Total input bytes per execution (for the perf model).
    pub fn input_bytes(&self) -> usize {
        self.args.iter().map(|a| a.n_elements() * 4).sum()
    }

    pub fn output_bytes(&self) -> usize {
        self.rets.iter().map(|a| a.n_elements() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
arg params/blocks.0.wq f32 2 256 256
arg t f32 0
arg tokens i32 2 8 128
ret loss f32 0
ret logits f32 3 8 128 512
";

    #[test]
    fn parses_sample() {
        let s = ArtifactSpec::parse("x", SAMPLE).unwrap();
        assert_eq!(s.args.len(), 3);
        assert_eq!(s.rets.len(), 2);
        assert_eq!(s.args[0].key, "params/blocks.0.wq");
        assert_eq!(s.args[0].shape, vec![256, 256]);
        assert_eq!(s.args[1].shape, Vec::<usize>::new());
        assert_eq!(s.args[2].dtype, DType::I32);
        assert_eq!(s.rets[1].n_elements(), 8 * 128 * 512);
    }

    #[test]
    fn rejects_bad_ndim() {
        assert!(ArtifactSpec::parse("x", "arg a f32 2 5\n").is_err());
    }

    #[test]
    fn rejects_bad_kind() {
        assert!(ArtifactSpec::parse("x", "zzz a f32 0\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(ArtifactSpec::parse("x", "").is_err());
    }

    #[test]
    fn byte_accounting() {
        let s = ArtifactSpec::parse("x", SAMPLE).unwrap();
        assert_eq!(s.input_bytes(), (256 * 256 + 1 + 8 * 128) * 4);
        assert_eq!(s.output_bytes(), (1 + 8 * 128 * 512) * 4);
    }
}
