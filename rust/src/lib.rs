//! # apiq-repro — ApiQ: Finetuning of 2-Bit Quantized Large Language Models
//!
//! A full-system reproduction of *ApiQ* (Liao et al., EMNLP 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for group-wise
//!   fake quantization with learnable clipping and the fused
//!   quantized-LoRA matmul (STE gradients via `custom_vjp`).
//! * **L2** (`python/compile/`): the TinyLlama model family plus every
//!   AOT-able step (pretrain, calibrate, finetune, eval), lowered once to
//!   HLO-text artifacts by `make artifacts`.
//! * **L3** (this crate): the coordinator — quantizer registry (RTN,
//!   GPTQ, AWQ-lite, LoftQ, OmniQuant-lite, ApiQ-lw, ApiQ-bw), the
//!   activation-stream calibration pipeline of the paper's Algorithm 1,
//!   training/evaluation drivers, synthetic data substrates, metrics, and
//!   the experiment registry mapping every paper table/figure to a
//!   runnable binary.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained, executing the HLO artifacts through PJRT.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod benchharness;
pub mod calib;
pub mod config;
pub mod data;
pub mod error;
pub mod eval;
pub mod infer;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod quant;
pub mod quantizers;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;

pub use error::{Error, Result};
