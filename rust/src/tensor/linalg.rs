//! Dense linear algebra needed by the baseline quantizers.
//!
//! * `cholesky_in_place` — for GPTQ's Hessian-inverse factorization
//!   (Frantar et al., 2022 run their column updates off a Cholesky of
//!   H^-1; we factor (H + λI) and solve).
//! * `svd_topk` — truncated SVD via subspace (block power) iteration, for
//!   LoftQ's per-iteration low-rank fit of the residual W - Q
//!   (Li et al., 2023, Eq. 2).

use crate::error::{Error, Result};
use crate::tensor::{Rng, Tensor};

/// In-place lower Cholesky factorization: A = L L^T (A must be SPD, row
/// major n x n). Returns Err on a non-positive pivot.
pub fn cholesky_in_place(a: &mut [f32], n: usize) -> Result<()> {
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 {
            return Err(Error::numeric(format!(
                "cholesky: non-positive pivot {d} at {j}"
            )));
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
        // zero the strictly-upper part for cleanliness
        for k in (j + 1)..n {
            a[j * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Solve L y = b then L^T x = y given the lower factor from
/// `cholesky_in_place` (i.e. solves (L L^T) x = b).
pub fn cholesky_solve(l: &[f32], n: usize, b: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Truncated SVD of `a` (m x n): returns (U_k: m x k, S_k: k, V_k: n x k)
/// with a ~= U_k diag(S_k) V_k^T, via subspace iteration on A^T A with
/// QR re-orthonormalization.  `iters` ~ 30 is plenty for LoftQ's use
/// (the residual spectrum decays fast).
pub fn svd_topk(a: &Tensor, k: usize, iters: usize, rng: &mut Rng) -> Result<(Tensor, Vec<f32>, Tensor)> {
    if a.rank() != 2 {
        return Err(Error::shape("svd_topk wants rank 2"));
    }
    let (m, n) = (a.rows(), a.cols());
    let k = k.min(m.min(n));
    // V: n x k orthonormal
    let mut v = Tensor::randn(&[n, k], 1.0, rng);
    orthonormalize_cols(&mut v);
    let at = a.transpose()?;
    for _ in 0..iters {
        // V <- orth(A^T (A V))
        let av = a.matmul(&v)?;          // m x k
        let mut atav = at.matmul(&av)?;  // n x k
        orthonormalize_cols(&mut atav);
        v = atav;
    }
    // U S = A V ; sigma_i = ||A v_i||, u_i = A v_i / sigma_i
    let av = a.matmul(&v)?; // m x k
    let mut sig = vec![0.0f32; k];
    let mut u = Tensor::zeros(&[m, k]);
    for j in 0..k {
        let mut s = 0.0f32;
        for i in 0..m {
            let x = av.at2(i, j);
            s += x * x;
        }
        let s = s.sqrt();
        sig[j] = s;
        if s > 1e-20 {
            for i in 0..m {
                u.set2(i, j, av.at2(i, j) / s);
            }
        }
    }
    // Order by decreasing singular value.
    let mut idx: Vec<usize> = (0..k).collect();
    idx.sort_by(|&i, &j| sig[j].partial_cmp(&sig[i]).unwrap());
    let mut u2 = Tensor::zeros(&[m, k]);
    let mut v2 = Tensor::zeros(&[n, k]);
    let mut s2 = vec![0.0f32; k];
    for (jj, &j) in idx.iter().enumerate() {
        s2[jj] = sig[j];
        for i in 0..m {
            u2.set2(i, jj, u.at2(i, j));
        }
        for i in 0..n {
            v2.set2(i, jj, v.at2(i, j));
        }
    }
    Ok((u2, s2, v2))
}

/// Modified Gram-Schmidt on the columns of `v` (in place).
fn orthonormalize_cols(v: &mut Tensor) {
    let (n, k) = (v.rows(), v.cols());
    for j in 0..k {
        for p in 0..j {
            let mut dot = 0.0f32;
            for i in 0..n {
                dot += v.at2(i, j) * v.at2(i, p);
            }
            for i in 0..n {
                let x = v.at2(i, j) - dot * v.at2(i, p);
                v.set2(i, j, x);
            }
        }
        let mut nrm = 0.0f32;
        for i in 0..n {
            nrm += v.at2(i, j) * v.at2(i, j);
        }
        let nrm = nrm.sqrt().max(1e-20);
        for i in 0..n {
            let x = v.at2(i, j) / nrm;
            v.set2(i, j, x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        cholesky_in_place(&mut a, 2).unwrap();
        assert_eq!(a, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn cholesky_known() {
        // A = [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        cholesky_in_place(&mut a, 2).unwrap();
        assert!((a[0] - 2.0).abs() < 1e-6);
        assert!((a[2] - 1.0).abs() < 1e-6);
        assert!((a[3] - 2.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_in_place(&mut a, 2).is_err());
    }

    #[test]
    fn cholesky_solve_roundtrip() {
        let mut rng = Rng::new(4);
        let n = 8;
        let g = Tensor::randn(&[n, n], 1.0, &mut rng);
        // SPD: A = G G^T + n I
        let mut a = g.matmul(&g.transpose().unwrap()).unwrap();
        for i in 0..n {
            let v = a.at2(i, i) + n as f32;
            a.set2(i, i, v);
        }
        let x_true: Vec<f32> = (0..n).map(|i| i as f32 - 3.0).collect();
        let xt = Tensor::new(vec![n, 1], x_true.clone()).unwrap();
        let b = a.matmul(&xt).unwrap();
        let mut l = a.data().to_vec();
        cholesky_in_place(&mut l, n).unwrap();
        let x = cholesky_solve(&l, n, b.data());
        for (xa, xb) in x.iter().zip(&x_true) {
            assert!((xa - xb).abs() < 1e-2, "{xa} vs {xb}");
        }
    }

    #[test]
    fn svd_reconstructs_low_rank() {
        let mut rng = Rng::new(9);
        let (m, n, r) = (24, 16, 3);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let a = u.matmul(&v).unwrap();
        let (uu, ss, vv) = svd_topk(&a, r, 40, &mut rng).unwrap();
        // reconstruct
        let mut rec = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..r {
                    s += uu.at2(i, l) * ss[l] * vv.at2(j, l);
                }
                rec.set2(i, j, s);
            }
        }
        let err = rec.sub(&a).unwrap().fro_norm() / a.fro_norm();
        assert!(err < 1e-3, "relative err {err}");
    }

    #[test]
    fn svd_singular_values_sorted() {
        let mut rng = Rng::new(10);
        let a = Tensor::randn(&[20, 20], 1.0, &mut rng);
        let (_, s, _) = svd_topk(&a, 5, 40, &mut rng).unwrap();
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
    }
}
