//! Host-side dense tensor math.
//!
//! Everything the coordinator computes *outside* the HLO artifacts lives
//! here: quantizer baselines (GPTQ Hessians, LoftQ SVD), metrics (weight /
//! activation errors, histograms), parameter initialization, and the
//! perplexity / accuracy evaluators that consume artifact logits.
//!
//! Deliberately f32-only and row-major; this is a coordinator substrate,
//! not a training framework — the heavy math runs in XLA.

pub mod linalg;
pub mod rng;

pub use linalg::{cholesky_in_place, svd_topk};
pub use rng::Rng;

use crate::error::{Error, Result};

pub use crate::kernels::gemm::GEMM_PARALLEL_MIN_FLOPS;

/// Compute-lane count of the kernel pool: `REPRO_THREADS` if set,
/// otherwise the machine's available parallelism.  (Kept as the historic
/// entry point; the sizing now lives in `kernels::pool`.)
pub fn gemm_threads() -> usize {
    crate::kernels::pool::pool_threads()
}

/// Serial reference GEMM over one row panel: `out_panel` (rows x n)
/// accumulates `a_panel` (rows x k) @ `b` (k x n) in i-k-j order.  This
/// is the bit-exact oracle the dispatched kernels in `kernels::gemm`
/// must reproduce (their tests compare against it).  Never skips zero
/// entries: 0 * NaN must stay NaN (IEEE-754 propagation).
#[cfg_attr(not(test), allow(dead_code))]
fn gemm_panel(a_panel: &[f32], b: &[f32], out_panel: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out_panel.len() / n;
    for i in 0..rows {
        let arow = &a_panel[i * k..(i + 1) * k];
        let orow = &mut out_panel[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            let brow = &b[l * n..(l + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Blocked GEMM: accumulates `a` (m x k) @ `b` (k x n) into `out`
/// (m x n).  `out` is NOT zeroed first — callers chain calls to
/// accumulate partial products.  Routes through the runtime-dispatched
/// SIMD kernels and the persistent worker pool in `kernels` (PR 1's
/// per-call `thread::scope` spawns are gone); output is bitwise
/// identical to [`gemm_panel`] at any thread count.
pub fn gemm_accum(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    crate::kernels::gemm::gemm_accum(a, b, out, m, k, n);
}

/// Row-major dense f32 tensor with dynamic rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Create from shape + data; validates element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], data }
    }

    /// Gaussian init, N(0, std^2).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Kaiming-uniform init for a (fan_in, fan_out) matrix (LoRA-A style).
    pub fn kaiming(shape: &[usize], rng: &mut Rng) -> Self {
        let fan_in = shape[0] as f32;
        let bound = (1.0_f32 / fan_in).sqrt() * 3.0_f32.sqrt();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.uniform(-bound, bound)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1);
        self.data[0]
    }

    /// Number of rows for a rank-2 tensor.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Number of cols for a rank-2 tensor.
    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape, shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Matrix product (self: m x k) @ (other: k x n) -> m x n, via the
    /// multi-threaded blocked `gemm_accum`.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.cols() != other.rows() {
            return Err(Error::shape(format!(
                "matmul {:?} @ {:?}",
                self.shape, other.shape
            )));
        }
        let (m, k, n) = (self.rows(), self.cols(), other.cols());
        let mut out = vec![0.0f32; m * n];
        gemm_accum(&self.data, &other.data, &mut out, m, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// Transpose of a rank-2 tensor.
    pub fn transpose(&self) -> Result<Tensor> {
        if self.rank() != 2 {
            return Err(Error::shape("transpose wants rank 2"));
        }
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Elementwise subtraction.
    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::shape(format!(
                "sub {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::shape(format!(
                "add {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    /// Scale by a constant.
    pub fn scale(&self, c: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|v| v * c).collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    /// Check every element is finite (NaN/Inf guard on artifact outputs).
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// Extract row i of a rank-2 tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let n = self.cols();
        &self.data[i * n..(i + 1) * n]
    }
}

/// Int32 tensor for token buffers (artifact `i32` inputs).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "int shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(IntTensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        IntTensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let i = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(a.matmul(&i).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_shape_mismatch_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let tt = a.transpose().unwrap().transpose().unwrap();
        assert_eq!(a, tt);
    }

    #[test]
    fn fro_norm() {
        let a = Tensor::new(vec![2], vec![3.0, 4.0]).unwrap();
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(42);
        let t = Tensor::randn(&[100, 100], 2.0, &mut rng);
        assert!(t.mean().abs() < 0.05);
        let var = t.data().iter().map(|v| v * v).sum::<f32>() / t.len() as f32;
        assert!((var.sqrt() - 2.0).abs() < 0.05);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.clone().reshape(&[2, 8]).is_ok());
        assert!(t.reshape(&[3, 5]).is_err());
    }

    #[test]
    fn matmul_propagates_nan_through_zero() {
        // Regression: the old kernel skipped a == 0.0 entries, silently
        // turning 0 * NaN into 0 instead of NaN.
        let a = Tensor::new(vec![1, 2], vec![0.0, 1.0]).unwrap();
        let b = Tensor::new(vec![2, 1], vec![f32::NAN, 2.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert!(c.data()[0].is_nan(), "0 * NaN must propagate NaN");

        let binf = Tensor::new(vec![2, 1], vec![f32::INFINITY, 2.0]).unwrap();
        let cinf = a.matmul(&binf).unwrap();
        // 0 * inf = NaN per IEEE-754
        assert!(cinf.data()[0].is_nan(), "0 * inf must produce NaN");
    }

    #[test]
    fn parallel_gemm_matches_serial_above_threshold() {
        // Big enough to take the threaded path regardless of core count.
        let mut rng = Rng::new(21);
        let (m, k, n) = (64, 96, 64);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let c = a.matmul(&b).unwrap();
        let mut serial = vec![0.0f32; m * n];
        super::gemm_panel(a.data(), b.data(), &mut serial, k, n);
        assert_eq!(c.data(), &serial[..], "threaded and serial GEMM must agree bit-exactly");
    }

    #[test]
    fn gemm_accum_accumulates() {
        let a = Tensor::new(vec![1, 1], vec![2.0]).unwrap();
        let b = Tensor::new(vec![1, 1], vec![3.0]).unwrap();
        let mut out = vec![10.0f32];
        super::gemm_accum(a.data(), b.data(), &mut out, 1, 1, 1);
        assert_eq!(out[0], 16.0);
    }

    #[test]
    fn gemm_degenerate_dims_are_noops() {
        let mut out: Vec<f32> = vec![];
        super::gemm_accum(&[], &[], &mut out, 0, 4, 0);
        let a = Tensor::zeros(&[2, 0]);
        let b = Tensor::zeros(&[0, 3]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert!(c.data().iter().all(|&v| v == 0.0));
    }
}
