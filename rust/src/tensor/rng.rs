//! Deterministic xorshift* PRNG.
//!
//! Every stochastic choice in the reproduction (init, data generation,
//! calibration sampling) flows from one of these, seeded from the
//! experiment config, so runs are bit-reproducible without pulling in an
//! external RNG crate.

/// xorshift64* generator with Box-Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1), cached_normal: None }
    }

    /// Derive an independent stream (for per-layer / per-task seeding).
    pub fn fork(&mut self, salt: u64) -> Rng {
        let s = self.next_u64() ^ salt.wrapping_mul(0xBF58476D1CE4E5B9);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (caches the second draw).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f32::consts::PI * u2;
        self.cached_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.categorical(&[0.1, 0.1, 0.8])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
