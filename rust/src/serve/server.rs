//! The long-lived `repro serve` loop: std-only TCP + threads + channels.
//!
//! Thread layout:
//!
//! * **engine** — owns the [`Scheduler`]; drains submissions from an mpsc
//!   channel (non-blocking while the batch is busy, blocking when idle so
//!   an idle server burns no CPU), runs one scheduler step per iteration,
//!   and routes rendered frames to each request's connection writer.
//!   Requests whose client vanished are cancelled at the next step.
//! * **accept** — one `TcpListener::accept` loop; spawns a reader +
//!   writer thread pair per connection.
//! * **per-connection reader** — parses newline-delimited JSON requests
//!   and forwards them to the engine with a clone of the connection's
//!   frame sender.
//! * **per-connection writer** — serializes frames back to the socket,
//!   flushing per line so tokens stream as they are produced.
//!
//! Binding to port 0 picks an ephemeral port; the bound address is
//! printed as `serve: listening on <addr>` (the CI smoke test scrapes
//! this line) and returned from [`spawn`] for in-process tests.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::infer::{AdapterSet, PackedModel};
use crate::model::checkpoint;
use crate::serve::protocol::{self, AdapterOp, ClientLine, WireRequest};
use crate::serve::scheduler::{GenRequest, SchedConfig, Scheduler, StepEvent};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 selects an ephemeral port.
    pub addr: String,
    pub sched: SchedConfig,
    /// Honor `{"cmd":"shutdown"}` from clients (CI uses this for clean
    /// teardown; disable for anything internet-facing).
    pub allow_remote_shutdown: bool,
    /// Adapter sidecars registered at boot: `(name, path)` pairs from
    /// repeated `--adapter NAME=PATH` flags.  Sidecars are validated
    /// against the model config before the engine starts.
    pub adapters: Vec<(String, String)>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            sched: SchedConfig::default(),
            allow_remote_shutdown: true,
            adapters: Vec::new(),
        }
    }
}

enum EngineMsg {
    Submit { wire: WireRequest, queued_at: Instant, out: Sender<String> },
    /// One-off stats query: the engine renders a stats frame (KV block
    /// accounting + queue state) straight back to this connection.
    Stats { out: Sender<String> },
    /// Runtime registry change; the ack (or error) frame goes straight
    /// back to this connection.
    Adapter { op: AdapterOp, name: String, path: Option<String>, out: Sender<String> },
    Shutdown,
}

/// Handle on a running server (in-process tests + clean shutdown).
pub struct Server {
    pub addr: SocketAddr,
    engine: JoinHandle<()>,
    accept: JoinHandle<()>,
    tx: Sender<EngineMsg>,
    stopping: Arc<AtomicBool>,
}

impl Server {
    /// Ask the server to stop and join its threads.
    pub fn shutdown(self) {
        self.stopping.store(true, Ordering::SeqCst);
        let _ = self.tx.send(EngineMsg::Shutdown);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
        let _ = self.engine.join();
    }

    /// Block until the engine exits (a client sent `{"cmd":"shutdown"}`).
    pub fn wait(self) {
        let _ = self.engine.join();
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
    }
}

/// Bind, spawn the engine + accept threads, and return immediately.
pub fn spawn(model: Arc<PackedModel>, opts: ServeOptions) -> Result<Server> {
    spawn_with_draft(model, None, opts)
}

/// [`spawn`] with an optional speculative-decoding draft model (used
/// when `opts.sched.speculate > 0`): the engine's scheduler drafts `k`
/// tokens per cycle on it and verifies them on the target.
pub fn spawn_with_draft(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<Server> {
    // Load + validate boot adapters before binding: a bad sidecar fails
    // the whole boot instead of silently serving a partial registry.
    let mut preload: Vec<AdapterSet> = Vec::with_capacity(opts.adapters.len());
    for (name, path) in &opts.adapters {
        if name.is_empty() {
            return Err(Error::config(format!("--adapter needs NAME=PATH, got '={path}'")));
        }
        if preload.iter().any(|s| s.name == *name) {
            return Err(Error::config(format!("duplicate --adapter name '{name}'")));
        }
        let mut set = checkpoint::load_adapter(path, &model.cfg)?;
        set.name = name.clone();
        preload.push(set);
    }

    let listener = TcpListener::bind(&opts.addr)
        .map_err(|e| Error::io(format!("bind {}: {e}", opts.addr)))?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::io(format!("local_addr: {e}")))?;
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let stopping = Arc::new(AtomicBool::new(false));

    let sched_cfg = opts.sched;
    let engine = std::thread::spawn(move || run_engine(model, draft, sched_cfg, preload, rx));

    let accept_tx = tx.clone();
    let accept_stop = Arc::clone(&stopping);
    let allow_shutdown = opts.allow_remote_shutdown;
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = accept_tx.clone();
                    std::thread::spawn(move || handle_conn(stream, tx, allow_shutdown));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, engine, accept, tx, stopping })
}

/// Blocking entry point for the `repro serve` CLI.
pub fn run(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<()> {
    let adapter_names: Vec<String> = opts.adapters.iter().map(|(n, _)| n.clone()).collect();
    let server = spawn_with_draft(model, draft, opts)?;
    println!("serve: listening on {}", server.addr);
    if !adapter_names.is_empty() {
        println!(
            "serve: {} adapter(s) registered: {}",
            adapter_names.len(),
            adapter_names.join(", ")
        );
    }
    // Line-buffered stdout under redirection: flush so the CI smoke test
    // sees the address immediately.
    let _ = std::io::stdout().flush();
    server.wait();
    println!("serve: engine stopped");
    Ok(())
}

fn run_engine(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    cfg: SchedConfig,
    preload: Vec<AdapterSet>,
    rx: Receiver<EngineMsg>,
) {
    let mut sched = match draft {
        Some(d) if cfg.speculate > 0 => Scheduler::with_draft(&model, cfg, d),
        _ => Scheduler::new(&model, cfg),
    };
    // Names were validated in `spawn_with_draft`; a load can only fail on
    // a duplicate, which the pre-check excluded.
    for set in preload {
        if let Err(e) = sched.adapters_mut().load(set) {
            eprintln!("serve: adapter preload failed: {e}");
        }
    }
    let mut outs: HashMap<u64, Sender<String>> = HashMap::new();
    let mut next_key = 1u64;
    'engine: loop {
        // Drain submissions: block when idle, poll when the batch is hot.
        if sched.has_work() {
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !handle_msg(msg, &model, &mut sched, &mut outs, &mut next_key) {
                            break 'engine;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'engine,
                }
            }
        } else {
            match rx.recv() {
                Ok(msg) => {
                    if !handle_msg(msg, &model, &mut sched, &mut outs, &mut next_key) {
                        break 'engine;
                    }
                }
                Err(_) => break 'engine,
            }
        }

        if !sched.has_work() {
            continue;
        }
        match sched.step() {
            Ok(events) => {
                for ev in &events {
                    let (key, finished) = match ev {
                        StepEvent::Token { key, .. } => (*key, false),
                        StepEvent::Done { key, .. } => (*key, true),
                        StepEvent::Rejected { key, .. } => (*key, true),
                    };
                    let line = protocol::event_frame(ev);
                    let delivered = outs.get(&key).map(|out| out.send(line).is_ok());
                    if delivered == Some(false) {
                        // Client is gone: stop decoding for it.
                        sched.cancel(key);
                        outs.remove(&key);
                    } else if finished {
                        outs.remove(&key);
                    }
                }
            }
            Err(e) => {
                // A step failure poisons the whole batch (model-level
                // error): notify every waiter and reset.
                let frame = protocol::error_frame("", &format!("engine step failed: {e}"));
                for (_, out) in outs.drain() {
                    let _ = out.send(frame.clone());
                }
                sched.clear();
            }
        }
    }
}

/// Returns false when the engine should exit.
fn handle_msg(
    msg: EngineMsg,
    model: &PackedModel,
    sched: &mut Scheduler<'_>,
    outs: &mut HashMap<u64, Sender<String>>,
    next_key: &mut u64,
) -> bool {
    match msg {
        EngineMsg::Submit { wire, queued_at, out } => {
            let key = *next_key;
            *next_key += 1;
            outs.insert(key, out);
            sched.submit(GenRequest {
                key,
                id: wire.id,
                prompt: wire.prompt,
                max_new: wire.max_new,
                sampling: wire.sampling,
                stop: wire.stop,
                adapter: wire.adapter,
                queued_at,
            });
            true
        }
        EngineMsg::Stats { out } => {
            let frame = protocol::stats_frame(
                &sched.kv_stats(),
                sched.n_active(),
                sched.n_pending(),
                sched.n_completed(),
                sched.spec_stats().as_ref(),
                &sched.adapters().stats(),
                sched.adapters().baseline_tokens(),
            );
            let _ = out.send(frame);
            true
        }
        EngineMsg::Adapter { op, name, path, out } => {
            let result = match op {
                AdapterOp::Load => path
                    .as_deref()
                    .ok_or_else(|| Error::config("adapter load needs a path"))
                    .and_then(|p| checkpoint::load_adapter(p, &model.cfg))
                    .and_then(|mut set| {
                        set.name = name.clone();
                        sched.adapters_mut().load(set)
                    })
                    .map(|()| "loaded"),
                AdapterOp::Unload => sched.adapters_mut().unload(&name).map(|now| {
                    if now {
                        "unloaded"
                    } else {
                        "draining"
                    }
                }),
            };
            let frame = match result {
                Ok(status) => protocol::adapter_frame(op, &name, status),
                Err(e) => protocol::error_frame("", &e.to_string()),
            };
            let _ = out.send(frame);
            true
        }
        EngineMsg::Shutdown => false,
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<EngineMsg>, allow_shutdown: bool) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (otx, orx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for line in orx {
            if w.write_all(line.as_bytes()).is_err()
                || w.write_all(b"\n").is_err()
                || w.flush().is_err()
            {
                break; // client hung up; engine cancels on next send
            }
        }
    });

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match protocol::parse_line(line) {
            Ok(ClientLine::Shutdown) => {
                if allow_shutdown {
                    let _ = tx.send(EngineMsg::Shutdown);
                } else {
                    let _ = otx.send(protocol::error_frame("", "shutdown disabled"));
                }
                break;
            }
            Ok(ClientLine::Request(wire)) => {
                let msg =
                    EngineMsg::Submit { wire, queued_at: Instant::now(), out: otx.clone() };
                if tx.send(msg).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Stats) => {
                if tx.send(EngineMsg::Stats { out: otx.clone() }).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Adapter { op, name, path }) => {
                let msg = EngineMsg::Adapter { op, name, path, out: otx.clone() };
                if tx.send(msg).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Err(e) => {
                let _ = otx.send(protocol::error_frame("", &e.to_string()));
            }
        }
    }
    drop(otx);
    let _ = writer.join();
}
