//! The long-lived `repro serve` loop: std-only TCP + threads + channels.
//!
//! Thread layout:
//!
//! * **engine** — owns the [`Scheduler`]; drains submissions from an mpsc
//!   channel (non-blocking while the batch is busy, blocking when idle so
//!   an idle server burns no CPU), runs one scheduler step per iteration,
//!   and routes rendered frames to each request's connection writer.
//!   Requests whose client vanished are cancelled at the next step.
//! * **accept** — one `TcpListener::accept` loop; spawns a reader +
//!   writer thread pair per connection.
//! * **per-connection reader** — parses newline-delimited JSON requests
//!   and forwards them to the engine with a clone of the connection's
//!   frame sender.
//! * **per-connection writer** — serializes frames back to the socket,
//!   flushing per line so tokens stream as they are produced.
//!
//! Binding to port 0 picks an ephemeral port; the bound address is
//! printed as `serve: listening on <addr>` (the CI smoke test scrapes
//! this line) and returned from [`spawn`] for in-process tests.
//!
//! Telemetry rides alongside: one [`Telemetry`] is shared between the
//! scheduler (writes) and the exposition paths — the `metrics`/`trace`
//! protocol commands on the engine thread, an optional Prometheus-text
//! HTTP listener (`--metrics-addr`, printed as `serve: metrics on
//! <addr>`), and an optional newline-JSON tick journal (`--trace-log`).
//! None of it touches compute or RNG state, so token streams are byte
//! identical with everything enabled (CI `cmp`s the transcripts).

use std::collections::HashMap;
use std::fs::OpenOptions;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::infer::{AdapterSet, PackedModel};
use crate::model::checkpoint;
use crate::obs::{profile, prom, Telemetry, DEFAULT_TRACE_CAP};
use crate::serve::protocol::{self, AdapterOp, ClientLine, EngineSnapshot, WireRequest};
use crate::serve::scheduler::{GenRequest, SchedConfig, Scheduler, StepEvent};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 selects an ephemeral port.
    pub addr: String,
    pub sched: SchedConfig,
    /// Honor `{"cmd":"shutdown"}` from clients (CI uses this for clean
    /// teardown; disable for anything internet-facing).
    pub allow_remote_shutdown: bool,
    /// Adapter sidecars registered at boot: `(name, path)` pairs from
    /// repeated `--adapter NAME=PATH` flags.  Sidecars are validated
    /// against the model config before the engine starts.
    pub adapters: Vec<(String, String)>,
    /// Bind a second listener serving Prometheus text at `/metrics`
    /// (`--metrics-addr`); `None` = no HTTP exposition.
    pub metrics_addr: Option<String>,
    /// Append every scheduler tick's trace record as one JSON line
    /// (`--trace-log PATH`); the file is created/appended at boot and a
    /// write error disables the journal rather than killing the engine.
    pub trace_log: Option<String>,
    /// Turn on kernel profiling accumulators (`--profile`; sticky for
    /// the process, same switch as `REPRO_PROF=1`).
    pub profile: bool,
    /// Tick-trace ring capacity (`--trace-cap`).
    pub trace_cap: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            sched: SchedConfig::default(),
            allow_remote_shutdown: true,
            adapters: Vec::new(),
            metrics_addr: None,
            trace_log: None,
            profile: false,
            trace_cap: DEFAULT_TRACE_CAP,
        }
    }
}

enum EngineMsg {
    Submit { wire: WireRequest, queued_at: Instant, out: Sender<String> },
    /// One-off stats query: the engine renders a stats frame (KV block
    /// accounting + queue state) straight back to this connection.
    Stats { out: Sender<String> },
    /// Runtime registry change; the ack (or error) frame goes straight
    /// back to this connection.
    Adapter { op: AdapterOp, name: String, path: Option<String>, out: Sender<String> },
    /// Full telemetry registry snapshot rendered as one JSON frame.
    Metrics { out: Sender<String> },
    /// Last `n` scheduler tick records from the trace ring.
    Trace { n: usize, out: Sender<String> },
    Shutdown,
}

/// Handle on a running server (in-process tests + clean shutdown).
pub struct Server {
    pub addr: SocketAddr,
    /// Bound address of the Prometheus listener when one was requested.
    pub metrics_addr: Option<SocketAddr>,
    engine: JoinHandle<()>,
    accept: JoinHandle<()>,
    metrics: Option<JoinHandle<()>>,
    tx: Sender<EngineMsg>,
    stopping: Arc<AtomicBool>,
}

impl Server {
    /// Ask the server to stop and join its threads.
    pub fn shutdown(self) {
        self.stopping.store(true, Ordering::SeqCst);
        let _ = self.tx.send(EngineMsg::Shutdown);
        // Unblock the accept loops with throwaway connections.
        let _ = TcpStream::connect(self.addr);
        if let Some(maddr) = self.metrics_addr {
            let _ = TcpStream::connect(maddr);
        }
        let _ = self.accept.join();
        if let Some(h) = self.metrics {
            let _ = h.join();
        }
        let _ = self.engine.join();
    }

    /// Block until the engine exits (a client sent `{"cmd":"shutdown"}`).
    pub fn wait(self) {
        let _ = self.engine.join();
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(maddr) = self.metrics_addr {
            let _ = TcpStream::connect(maddr);
        }
        let _ = self.accept.join();
        if let Some(h) = self.metrics {
            let _ = h.join();
        }
    }
}

/// Bind, spawn the engine + accept threads, and return immediately.
pub fn spawn(model: Arc<PackedModel>, opts: ServeOptions) -> Result<Server> {
    spawn_with_draft(model, None, opts)
}

/// [`spawn`] with an optional speculative-decoding draft model (used
/// when `opts.sched.speculate > 0`): the engine's scheduler drafts `k`
/// tokens per cycle on it and verifies them on the target.
pub fn spawn_with_draft(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<Server> {
    // Load + validate boot adapters before binding: a bad sidecar fails
    // the whole boot instead of silently serving a partial registry.
    let mut preload: Vec<AdapterSet> = Vec::with_capacity(opts.adapters.len());
    for (name, path) in &opts.adapters {
        if name.is_empty() {
            return Err(Error::config(format!("--adapter needs NAME=PATH, got '={path}'")));
        }
        if preload.iter().any(|s| s.name == *name) {
            return Err(Error::config(format!("duplicate --adapter name '{name}'")));
        }
        let mut set = checkpoint::load_adapter(path, &model.cfg)?;
        set.name = name.clone();
        preload.push(set);
    }

    let listener = TcpListener::bind(&opts.addr)
        .map_err(|e| Error::io(format!("bind {}: {e}", opts.addr)))?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::io(format!("local_addr: {e}")))?;
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let stopping = Arc::new(AtomicBool::new(false));

    let obs = Telemetry::new(opts.trace_cap);
    if opts.profile {
        profile::enable();
    }
    let trace = match &opts.trace_log {
        Some(path) => {
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map_err(|e| Error::io(format!("open trace log {path}: {e}")))?;
            Some(BufWriter::new(f))
        }
        None => None,
    };
    let (metrics_addr, metrics) = match &opts.metrics_addr {
        Some(maddr) => {
            let mlistener = TcpListener::bind(maddr)
                .map_err(|e| Error::io(format!("bind metrics {maddr}: {e}")))?;
            let bound = mlistener
                .local_addr()
                .map_err(|e| Error::io(format!("metrics local_addr: {e}")))?;
            let mobs = Arc::clone(&obs);
            let mstop = Arc::clone(&stopping);
            let handle = std::thread::spawn(move || {
                for conn in mlistener.incoming() {
                    if mstop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let obs = Arc::clone(&mobs);
                            std::thread::spawn(move || serve_metrics_conn(stream, &obs));
                        }
                        Err(_) => break,
                    }
                }
            });
            (Some(bound), Some(handle))
        }
        None => (None, None),
    };

    let sched_cfg = opts.sched;
    let engine_obs = Arc::clone(&obs);
    let engine = std::thread::spawn(move || {
        run_engine(model, draft, sched_cfg, preload, rx, engine_obs, trace)
    });

    let accept_tx = tx.clone();
    let accept_stop = Arc::clone(&stopping);
    let allow_shutdown = opts.allow_remote_shutdown;
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = accept_tx.clone();
                    std::thread::spawn(move || handle_conn(stream, tx, allow_shutdown));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, metrics_addr, engine, accept, metrics, tx, stopping })
}

/// One short-lived HTTP exchange on the metrics listener: answer
/// `GET /metrics` (or `/`) with Prometheus text exposition 0.0.4 and
/// close.  Anything else gets a 404; malformed requests are dropped.
fn serve_metrics_conn(stream: TcpStream, obs: &Telemetry) {
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_half);
    let mut request = String::new();
    if reader.read_line(&mut request).is_err() {
        return;
    }
    // Drain the header block; the response does not depend on it.
    loop {
        let mut header = String::new();
        match reader.read_line(&mut header) {
            Ok(0) => break,
            Ok(_) if header == "\r\n" || header == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let mut parts = request.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut w = BufWriter::new(stream);
    if method == "GET" && (path == "/metrics" || path == "/") {
        let body = prom::render(obs);
        let _ = write!(
            w,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        let _ = w.write_all(body.as_bytes());
    } else {
        let body = "not found\n";
        let _ = write!(
            w,
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
    }
    let _ = w.flush();
}

/// Blocking entry point for the `repro serve` CLI.
pub fn run(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<()> {
    let adapter_names: Vec<String> = opts.adapters.iter().map(|(n, _)| n.clone()).collect();
    let server = spawn_with_draft(model, draft, opts)?;
    println!("serve: listening on {}", server.addr);
    if let Some(maddr) = server.metrics_addr {
        // The CI observability smoke scrapes this line for the port.
        println!("serve: metrics on {maddr}");
    }
    if !adapter_names.is_empty() {
        println!(
            "serve: {} adapter(s) registered: {}",
            adapter_names.len(),
            adapter_names.join(", ")
        );
    }
    // Line-buffered stdout under redirection: flush so the CI smoke test
    // sees the address immediately.
    let _ = std::io::stdout().flush();
    server.wait();
    println!("serve: engine stopped");
    Ok(())
}

fn run_engine(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    cfg: SchedConfig,
    preload: Vec<AdapterSet>,
    rx: Receiver<EngineMsg>,
    obs: Arc<Telemetry>,
    mut trace: Option<BufWriter<std::fs::File>>,
) {
    let mut sched = match draft {
        Some(d) if cfg.speculate > 0 => Scheduler::with_draft(&model, cfg, d),
        _ => Scheduler::new(&model, cfg),
    };
    sched.attach_obs(obs);
    // Names were validated in `spawn_with_draft`; a load can only fail on
    // a duplicate, which the pre-check excluded.
    for set in preload {
        if let Err(e) = sched.adapters_mut().load(set) {
            eprintln!("serve: adapter preload failed: {e}");
        }
    }
    let mut outs: HashMap<u64, Sender<String>> = HashMap::new();
    let mut next_key = 1u64;
    'engine: loop {
        // Drain submissions: block when idle, poll when the batch is hot.
        if sched.has_work() {
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !handle_msg(msg, &model, &mut sched, &mut outs, &mut next_key) {
                            break 'engine;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'engine,
                }
            }
        } else {
            match rx.recv() {
                Ok(msg) => {
                    if !handle_msg(msg, &model, &mut sched, &mut outs, &mut next_key) {
                        break 'engine;
                    }
                }
                Err(_) => break 'engine,
            }
        }

        if !sched.has_work() {
            continue;
        }
        match sched.step() {
            Ok(events) => {
                // Journal the tick before routing frames; a failed write
                // disables the journal, never the engine.
                if let Some(mut w) = trace.take() {
                    match sched.obs().last_tick() {
                        Some(rec)
                            if writeln!(w, "{}", rec.to_json().render()).is_err()
                                || w.flush().is_err() =>
                        {
                            eprintln!("serve: trace-log write failed; journal disabled");
                        }
                        _ => trace = Some(w),
                    }
                }
                for ev in &events {
                    let (key, finished) = match ev {
                        StepEvent::Token { key, .. } => (*key, false),
                        StepEvent::Done { key, .. } => (*key, true),
                        StepEvent::Rejected { key, .. } => (*key, true),
                    };
                    let line = protocol::event_frame(ev);
                    let delivered = outs.get(&key).map(|out| out.send(line).is_ok());
                    if delivered == Some(false) {
                        // Client is gone: stop decoding for it.
                        sched.cancel(key);
                        outs.remove(&key);
                    } else if finished {
                        outs.remove(&key);
                    }
                }
            }
            Err(e) => {
                // A step failure poisons the whole batch (model-level
                // error): notify every waiter and reset.
                let frame = protocol::error_frame("", &format!("engine step failed: {e}"));
                for (_, out) in outs.drain() {
                    let _ = out.send(frame.clone());
                }
                sched.clear();
            }
        }
    }
}

/// Returns false when the engine should exit.
fn handle_msg(
    msg: EngineMsg,
    model: &PackedModel,
    sched: &mut Scheduler<'_>,
    outs: &mut HashMap<u64, Sender<String>>,
    next_key: &mut u64,
) -> bool {
    match msg {
        EngineMsg::Submit { wire, queued_at, out } => {
            let key = *next_key;
            *next_key += 1;
            outs.insert(key, out);
            sched.submit(GenRequest {
                key,
                id: wire.id,
                prompt: wire.prompt,
                max_new: wire.max_new,
                sampling: wire.sampling,
                stop: wire.stop,
                adapter: wire.adapter,
                queued_at,
            });
            true
        }
        EngineMsg::Stats { out } => {
            let kv = sched.kv_stats();
            let spec = sched.spec_stats();
            let adapters = sched.adapters().stats();
            let build = crate::obs::build_info();
            let frame = protocol::stats_frame(&EngineSnapshot {
                kv: &kv,
                active: sched.n_active(),
                pending: sched.n_pending(),
                completed: sched.n_completed(),
                spec: spec.as_ref(),
                adapters: &adapters,
                baseline_tokens: sched.adapters().baseline_tokens(),
                build: &build,
                uptime_secs: sched.obs().uptime_secs(),
            });
            let _ = out.send(frame);
            true
        }
        EngineMsg::Metrics { out } => {
            let _ = out.send(protocol::metrics_frame(sched.obs()));
            true
        }
        EngineMsg::Trace { n, out } => {
            let (total, ticks) = sched.obs().last_ticks(n);
            let _ = out.send(protocol::trace_frame(total, &ticks));
            true
        }
        EngineMsg::Adapter { op, name, path, out } => {
            let result = match op {
                AdapterOp::Load => path
                    .as_deref()
                    .ok_or_else(|| Error::config("adapter load needs a path"))
                    .and_then(|p| checkpoint::load_adapter(p, &model.cfg))
                    .and_then(|mut set| {
                        set.name = name.clone();
                        sched.adapters_mut().load(set)
                    })
                    .map(|()| "loaded"),
                AdapterOp::Unload => sched.adapters_mut().unload(&name).map(|now| {
                    if now {
                        "unloaded"
                    } else {
                        "draining"
                    }
                }),
            };
            let frame = match result {
                Ok(status) => protocol::adapter_frame(op, &name, status),
                Err(e) => protocol::error_frame("", &e.to_string()),
            };
            let _ = out.send(frame);
            true
        }
        EngineMsg::Shutdown => false,
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<EngineMsg>, allow_shutdown: bool) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (otx, orx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for line in orx {
            if w.write_all(line.as_bytes()).is_err()
                || w.write_all(b"\n").is_err()
                || w.flush().is_err()
            {
                break; // client hung up; engine cancels on next send
            }
        }
    });

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match protocol::parse_line(line) {
            Ok(ClientLine::Shutdown) => {
                if allow_shutdown {
                    let _ = tx.send(EngineMsg::Shutdown);
                } else {
                    let _ = otx.send(protocol::error_frame("", "shutdown disabled"));
                }
                break;
            }
            Ok(ClientLine::Request(wire)) => {
                let msg =
                    EngineMsg::Submit { wire, queued_at: Instant::now(), out: otx.clone() };
                if tx.send(msg).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Stats) => {
                if tx.send(EngineMsg::Stats { out: otx.clone() }).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Metrics) => {
                if tx.send(EngineMsg::Metrics { out: otx.clone() }).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Trace { n }) => {
                if tx.send(EngineMsg::Trace { n, out: otx.clone() }).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Ok(ClientLine::Adapter { op, name, path }) => {
                let msg = EngineMsg::Adapter { op, name, path, out: otx.clone() };
                if tx.send(msg).is_err() {
                    let _ = otx.send(protocol::error_frame("", "engine stopped"));
                    break;
                }
            }
            Err(e) => {
                let _ = otx.send(protocol::error_frame("", &e.to_string()));
            }
        }
    }
    drop(otx);
    let _ = writer.join();
}
