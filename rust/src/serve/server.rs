//! The long-lived `repro serve` loop: std-only TCP + threads + channels.
//!
//! Thread layout:
//!
//! * **engine** — owns the [`Scheduler`]; drains submissions from an mpsc
//!   channel (non-blocking while the batch is busy, short-timeout blocking
//!   when idle so an idle server burns almost no CPU yet still notices
//!   drain signals), runs one scheduler step per iteration inside
//!   `catch_unwind`, and routes rendered frames to each request's
//!   connection writer.  Requests whose client vanished are cancelled at
//!   the next step.
//! * **accept** — one `TcpListener::accept` loop; spawns a reader +
//!   writer thread pair per connection.
//! * **per-connection reader** — parses newline-delimited JSON requests
//!   (bounded by `--max-line`) and forwards them to the engine with a
//!   clone of the connection's frame sender.
//! * **per-connection writer** — serializes frames back to the socket,
//!   flushing per line so tokens stream as they are produced.
//!
//! Fault tolerance (see the README "Fault tolerance" section):
//!
//! * **Overload control** — the scheduler's submission queue is bounded
//!   (`--max-pending`); a full queue answers with an `overloaded` error
//!   frame carrying `retry_after_ms` instead of queueing unboundedly.
//!   Each connection's output queue is bounded too (`--out-queue`); a
//!   client that stops reading accumulates an engine-side backlog and is
//!   evicted after `--slow-reader-ms`, releasing its KV pages.
//! * **Deadlines** — requests carry `deadline_ms` (default
//!   `--deadline-ms`); expired requests are rejected at admission or
//!   finished with `"finish":"deadline"` mid-decode.
//! * **Panic isolation** — a panic inside `Scheduler::step` is caught,
//!   the offending sequence is quarantined with an `internal` error
//!   frame, and the block pool / adapter refcounts are rebuilt from the
//!   survivors.  Only if the quarantine itself panics does the engine
//!   poison: it refuses new work with `unavailable` and keeps answering
//!   stats/metrics.
//! * **Tiered KV** — with `--kv-spill PATH` the engine attaches a
//!   [`crate::serve::tier::TieredKv`] disk tier at boot: block
//!   exhaustion preempts sequences to the spill file instead of
//!   finishing them with `capacity`, `"session"`-tagged requests can
//!   suspend and resume across connections, and `--prefix-store` keeps
//!   finished prompt KV pages for cross-request reuse.  An unwritable
//!   spill path fails the boot.
//! * **Graceful drain** — SIGINT/SIGTERM or `{"cmd":"drain"}` stops
//!   admissions, finishes in-flight sequences, flushes the trace
//!   journal, and exits 0.
//! * **Fault injection** — `--fault SPEC` / `REPRO_FAULT` arms the
//!   deterministic harness in [`crate::obs::fault`]; with no spec (or a
//!   zero-rate spec) every code path below is byte-identical to a
//!   fault-free build.
//!
//! Binding to port 0 picks an ephemeral port; the bound address is
//! printed as `serve: listening on <addr>` (the CI smoke test scrapes
//! this line) and returned from [`spawn`] for in-process tests.
//!
//! Telemetry rides alongside: one [`Telemetry`] is shared between the
//! scheduler (writes) and the exposition paths — the `metrics`/`trace`
//! protocol commands on the engine thread, an optional Prometheus-text
//! HTTP listener (`--metrics-addr`, printed as `serve: metrics on
//! <addr>`), and an optional newline-JSON tick journal (`--trace-log`).
//! None of it touches compute or RNG state, so token streams are byte
//! identical with everything enabled (CI `cmp`s the transcripts).

use std::collections::{HashMap, HashSet, VecDeque};
use std::fs::OpenOptions;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::infer::{AdapterSet, PackedModel};
use crate::model::checkpoint;
use crate::obs::{profile, prom, FaultPlan, FaultPoint, SeqPanic, Telemetry, DEFAULT_TRACE_CAP};
use crate::serve::protocol::{self, code, AdapterOp, ClientLine, EngineSnapshot, WireRequest};
use crate::serve::scheduler::{GenRequest, SchedConfig, Scheduler, StepEvent};
use crate::serve::tier::TieredKv;

/// Default cap on one request line, bytes (`--max-line`).
pub const DEFAULT_MAX_LINE: usize = 1 << 20;
/// Default per-connection output queue depth, frames (`--out-queue`).
pub const DEFAULT_OUT_QUEUE: usize = 1024;
/// Default grace before a backlogged connection is evicted, ms
/// (`--slow-reader-ms`).
pub const DEFAULT_SLOW_READER_MS: u64 = 2000;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 selects an ephemeral port.
    pub addr: String,
    pub sched: SchedConfig,
    /// Honor `{"cmd":"shutdown"}` from clients (CI uses this for clean
    /// teardown; disable for anything internet-facing).
    pub allow_remote_shutdown: bool,
    /// Adapter sidecars registered at boot: `(name, path)` pairs from
    /// repeated `--adapter NAME=PATH` flags.  Sidecars are validated
    /// against the model config before the engine starts.
    pub adapters: Vec<(String, String)>,
    /// Bind a second listener serving Prometheus text at `/metrics`
    /// (`--metrics-addr`); `None` = no HTTP exposition.
    pub metrics_addr: Option<String>,
    /// Append every scheduler tick's trace record as one JSON line
    /// (`--trace-log PATH`); the file is created/appended at boot and a
    /// write error disables the journal rather than killing the engine.
    pub trace_log: Option<String>,
    /// Turn on kernel profiling accumulators (`--profile`; sticky for
    /// the process, same switch as `REPRO_PROF=1`).
    pub profile: bool,
    /// Tick-trace ring capacity (`--trace-cap`).
    pub trace_cap: usize,
    /// Fault-injection spec (`--fault`, grammar in [`crate::obs::fault`]);
    /// `None` falls back to the `REPRO_FAULT` env var, and an unarmed
    /// spec leaves every injection point off.
    pub fault: Option<String>,
    /// Reject request lines longer than this many bytes (`--max-line`).
    pub max_line: usize,
    /// Bounded per-connection output queue depth (`--out-queue`).  When
    /// the queue is full the engine keeps a backlog and starts the
    /// slow-reader clock instead of blocking the batch.
    pub out_queue: usize,
    /// How long a connection may stay backlogged before it is evicted
    /// and its sequences cancelled (`--slow-reader-ms`; 0 = immediate).
    pub slow_reader_ms: u64,
    /// Spill-file path for the disk KV tier (`--kv-spill PATH`); `None`
    /// disables tiering (preemption, sessions, and the prefix store).
    /// The file is created/truncated at boot; an unwritable path fails
    /// the boot.
    pub kv_spill: Option<String>,
    /// Spill-slot budget (`--kv-spill-blocks N`); 0 = unbounded, the
    /// file grows as pages spill.
    pub kv_spill_blocks: usize,
    /// Keep a content-keyed prefix store on the spill file
    /// (`--prefix-store`; requires `--kv-spill`): finished adapter-less
    /// prompts publish their full KV pages, and later admissions with a
    /// matching token prefix promote them back instead of re-prefilling.
    pub prefix_store: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            sched: SchedConfig::default(),
            allow_remote_shutdown: true,
            adapters: Vec::new(),
            metrics_addr: None,
            trace_log: None,
            profile: false,
            trace_cap: DEFAULT_TRACE_CAP,
            fault: None,
            max_line: DEFAULT_MAX_LINE,
            out_queue: DEFAULT_OUT_QUEUE,
            slow_reader_ms: DEFAULT_SLOW_READER_MS,
            kv_spill: None,
            kv_spill_blocks: 0,
            prefix_store: false,
        }
    }
}

enum EngineMsg {
    Submit { wire: WireRequest, queued_at: Instant, conn: u64, out: SyncSender<String> },
    /// One-off stats query: the engine renders a stats frame (KV block
    /// accounting + queue state) straight back to this connection.
    Stats { out: SyncSender<String> },
    /// Runtime registry change; the ack (or error) frame goes straight
    /// back to this connection.
    Adapter { op: AdapterOp, name: String, path: Option<String>, out: SyncSender<String> },
    /// Full telemetry registry snapshot rendered as one JSON frame.
    Metrics { out: SyncSender<String> },
    /// Last `n` scheduler tick records from the trace ring.
    Trace { n: usize, out: SyncSender<String> },
    /// Begin a graceful drain: stop admitting, finish in-flight work,
    /// then exit the engine loop.
    Drain { out: SyncSender<String> },
    Shutdown,
}

/// Monotonic connection ids, assigned by the reader threads.
static NEXT_CONN: AtomicU64 = AtomicU64::new(1);

/// Process-wide drain signal (SIGINT/SIGTERM).  Installed only by
/// [`run`] — in-process test servers never touch signal disposition.
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static DRAIN: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    extern "C" fn on_signal(_sig: i32) {
        // Async-signal-safe: one atomic store, nothing else.
        DRAIN.store(true, Ordering::SeqCst);
    }

    #[cfg(unix)]
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}

    pub fn drain_requested() -> bool {
        DRAIN.load(Ordering::SeqCst)
    }
}

/// Handle on a running server (in-process tests + clean shutdown).
pub struct Server {
    pub addr: SocketAddr,
    /// Bound address of the Prometheus listener when one was requested.
    pub metrics_addr: Option<SocketAddr>,
    engine: JoinHandle<()>,
    accept: JoinHandle<()>,
    metrics: Option<JoinHandle<()>>,
    tx: Sender<EngineMsg>,
    stopping: Arc<AtomicBool>,
}

impl Server {
    /// Ask the server to stop and join its threads.
    pub fn shutdown(self) {
        self.stopping.store(true, Ordering::SeqCst);
        let _ = self.tx.send(EngineMsg::Shutdown);
        // Unblock the accept loops with throwaway connections.
        let _ = TcpStream::connect(self.addr);
        if let Some(maddr) = self.metrics_addr {
            let _ = TcpStream::connect(maddr);
        }
        let _ = self.accept.join();
        if let Some(h) = self.metrics {
            let _ = h.join();
        }
        let _ = self.engine.join();
    }

    /// Block until the engine exits (a client sent `{"cmd":"shutdown"}`
    /// or a drain completed).
    pub fn wait(self) {
        let _ = self.engine.join();
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(maddr) = self.metrics_addr {
            let _ = TcpStream::connect(maddr);
        }
        let _ = self.accept.join();
        if let Some(h) = self.metrics {
            let _ = h.join();
        }
    }
}

/// Bind, spawn the engine + accept threads, and return immediately.
pub fn spawn(model: Arc<PackedModel>, opts: ServeOptions) -> Result<Server> {
    spawn_with_draft(model, None, opts)
}

/// [`spawn`] with an optional speculative-decoding draft model (used
/// when `opts.sched.speculate > 0`): the engine's scheduler drafts `k`
/// tokens per cycle on it and verifies them on the target.
pub fn spawn_with_draft(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<Server> {
    // Load + validate boot adapters before binding: a bad sidecar fails
    // the whole boot instead of silently serving a partial registry.
    let mut preload: Vec<AdapterSet> = Vec::with_capacity(opts.adapters.len());
    for (name, path) in &opts.adapters {
        if name.is_empty() {
            return Err(Error::config(format!("--adapter needs NAME=PATH, got '={path}'")));
        }
        if preload.iter().any(|s| s.name == *name) {
            return Err(Error::config(format!("duplicate --adapter name '{name}'")));
        }
        let mut set = checkpoint::load_adapter(path, &model.cfg)?;
        set.name = name.clone();
        preload.push(set);
    }

    // Parse the fault spec up front so a typo fails the boot, not the
    // first injection.  An unarmed plan (all rates zero) is dropped so
    // the hot paths keep their no-fault branch.
    let fault_spec = opts.fault.clone().or_else(|| std::env::var("REPRO_FAULT").ok());
    let fault: Option<Arc<FaultPlan>> = match fault_spec.as_deref().map(str::trim) {
        Some(spec) if !spec.is_empty() => {
            let plan = FaultPlan::parse(spec)?;
            if plan.is_armed() {
                Some(Arc::new(plan))
            } else {
                None
            }
        }
        _ => None,
    };

    // Probe the spill path before binding so an unwritable disk fails
    // the boot, not the engine thread.  The real SpillFile (sized from
    // the scheduler's pool geometry) truncates it again moments later.
    if opts.prefix_store && opts.kv_spill.is_none() {
        return Err(Error::config("--prefix-store requires --kv-spill PATH"));
    }
    if let Some(path) = &opts.kv_spill {
        OpenOptions::new()
            .create(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io(format!("open kv-spill {path}: {e}")))?;
    }
    let tier_boot = TierBoot {
        path: opts.kv_spill.clone(),
        max_slots: opts.kv_spill_blocks,
        prefix_store: opts.prefix_store,
    };

    let listener = TcpListener::bind(&opts.addr)
        .map_err(|e| Error::io(format!("bind {}: {e}", opts.addr)))?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::io(format!("local_addr: {e}")))?;
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let stopping = Arc::new(AtomicBool::new(false));

    let obs = Telemetry::new(opts.trace_cap);
    if opts.profile {
        profile::enable();
    }
    let trace = match &opts.trace_log {
        Some(path) => {
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map_err(|e| Error::io(format!("open trace log {path}: {e}")))?;
            Some(BufWriter::new(f))
        }
        None => None,
    };
    let (metrics_addr, metrics) = match &opts.metrics_addr {
        Some(maddr) => {
            let mlistener = TcpListener::bind(maddr)
                .map_err(|e| Error::io(format!("bind metrics {maddr}: {e}")))?;
            let bound = mlistener
                .local_addr()
                .map_err(|e| Error::io(format!("metrics local_addr: {e}")))?;
            let mobs = Arc::clone(&obs);
            let mstop = Arc::clone(&stopping);
            let handle = std::thread::spawn(move || {
                for conn in mlistener.incoming() {
                    if mstop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let obs = Arc::clone(&mobs);
                            std::thread::spawn(move || serve_metrics_conn(stream, &obs));
                        }
                        Err(_) => break,
                    }
                }
            });
            (Some(bound), Some(handle))
        }
        None => (None, None),
    };

    let sched_cfg = opts.sched;
    let engine_obs = Arc::clone(&obs);
    let engine_fault = fault.clone();
    let slow_reader = Duration::from_millis(opts.slow_reader_ms);
    let engine = std::thread::spawn(move || {
        run_engine(
            model,
            draft,
            sched_cfg,
            preload,
            rx,
            engine_obs,
            trace,
            engine_fault,
            slow_reader,
            tier_boot,
        )
    });

    let accept_tx = tx.clone();
    let accept_stop = Arc::clone(&stopping);
    let conn_opts = ConnOpts {
        allow_shutdown: opts.allow_remote_shutdown,
        max_line: opts.max_line.max(1),
        out_queue: opts.out_queue.max(1),
        fault,
    };
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = accept_tx.clone();
                    let o = conn_opts.clone();
                    std::thread::spawn(move || handle_conn(stream, tx, o));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, metrics_addr, engine, accept, metrics, tx, stopping })
}

/// One short-lived HTTP exchange on the metrics listener: answer
/// `GET /metrics` (or `/`) with Prometheus text exposition 0.0.4 and
/// close.  Anything else gets a 404; malformed requests are dropped.
fn serve_metrics_conn(stream: TcpStream, obs: &Telemetry) {
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_half);
    let mut request = String::new();
    if reader.read_line(&mut request).is_err() {
        return;
    }
    // Drain the header block; the response does not depend on it.
    loop {
        let mut header = String::new();
        match reader.read_line(&mut header) {
            Ok(0) => break,
            Ok(_) if header == "\r\n" || header == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let mut parts = request.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let mut w = BufWriter::new(stream);
    if method == "GET" && (path == "/metrics" || path == "/") {
        let body = prom::render(obs);
        let _ = write!(
            w,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        let _ = w.write_all(body.as_bytes());
    } else {
        let body = "not found\n";
        let _ = write!(
            w,
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
    }
    let _ = w.flush();
}

/// Blocking entry point for the `repro serve` CLI.  Installs the
/// SIGINT/SIGTERM drain handler (in-process test servers do not).
pub fn run(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    opts: ServeOptions,
) -> Result<()> {
    sig::install();
    let adapter_names: Vec<String> = opts.adapters.iter().map(|(n, _)| n.clone()).collect();
    let fault_spec = opts.fault.clone().or_else(|| std::env::var("REPRO_FAULT").ok());
    let kv_spill = opts.kv_spill.clone();
    let prefix_store = opts.prefix_store;
    let server = spawn_with_draft(model, draft, opts)?;
    println!("serve: listening on {}", server.addr);
    if let Some(path) = &kv_spill {
        println!(
            "serve: kv spill on {path} (prefix store {})",
            if prefix_store { "on" } else { "off" }
        );
    }
    if let Some(maddr) = server.metrics_addr {
        // The CI observability smoke scrapes this line for the port.
        println!("serve: metrics on {maddr}");
    }
    if !adapter_names.is_empty() {
        println!(
            "serve: {} adapter(s) registered: {}",
            adapter_names.len(),
            adapter_names.join(", ")
        );
    }
    if let Some(spec) = fault_spec.as_deref().map(str::trim).filter(|s| !s.is_empty()) {
        println!("serve: fault injection armed: {spec}");
    }
    // Line-buffered stdout under redirection: flush so the CI smoke test
    // sees the address immediately.
    let _ = std::io::stdout().flush();
    server.wait();
    println!("serve: engine stopped");
    Ok(())
}

/// Engine-side view of one client connection.  Frames are pushed with
/// `try_send` so a slow reader can never block the batch; overflow goes
/// to `backlog` and starts the eviction clock.
struct ConnState {
    tx: SyncSender<String>,
    backlog: VecDeque<String>,
    /// When the connection first became backlogged; cleared once the
    /// backlog fully drains.
    stalled_since: Option<Instant>,
}

enum Push {
    /// Frame delivered (or backlogged after a still-draining backlog).
    Ok,
    /// Queue full: frame backlogged, eviction clock running.
    Full,
    /// Writer gone: connection must be dropped.
    Dead,
}

/// Try to drain `conn.backlog` into its bounded channel.  Returns false
/// if the writer disconnected.
fn flush_backlog(conn: &mut ConnState) -> bool {
    while let Some(front) = conn.backlog.pop_front() {
        match conn.tx.try_send(front) {
            Ok(()) => continue,
            Err(TrySendError::Full(front)) => {
                conn.backlog.push_front(front);
                break;
            }
            Err(TrySendError::Disconnected(_)) => return false,
        }
    }
    true
}

/// Push one frame to a connection without ever blocking the engine.
fn conn_push(conn: &mut ConnState, line: String, now: Instant) -> Push {
    if !flush_backlog(conn) {
        return Push::Dead;
    }
    if conn.backlog.is_empty() {
        match conn.tx.try_send(line) {
            Ok(()) => {
                conn.stalled_since = None;
                return Push::Ok;
            }
            Err(TrySendError::Full(line)) => conn.backlog.push_back(line),
            Err(TrySendError::Disconnected(_)) => return Push::Dead,
        }
    } else {
        conn.backlog.push_back(line);
    }
    if conn.stalled_since.is_none() {
        conn.stalled_since = Some(now);
    }
    Push::Full
}

/// Mutable engine state outside the scheduler: connection routing,
/// drain/poison flags, and the armed fault plan.
struct EngineState {
    /// request key -> connection id.
    outs: HashMap<u64, u64>,
    /// connection id -> output queue + backlog.
    conns: HashMap<u64, ConnState>,
    next_key: u64,
    draining: bool,
    /// Quarantine itself failed: scheduler state is untrusted.  Refuse
    /// generation work with `unavailable`, keep answering queries.
    poisoned: bool,
    fault: Option<Arc<FaultPlan>>,
    /// Fault-plan fire count already mirrored into the metric.
    fired_seen: u64,
    slow_reader: Duration,
}

/// Cancel every sequence routed to `cid` and forget the connection.
fn drop_conn(cid: u64, sched: &mut Scheduler<'_>, st: &mut EngineState) {
    st.conns.remove(&cid);
    let keys: Vec<u64> =
        st.outs.iter().filter(|(_, c)| **c == cid).map(|(k, _)| *k).collect();
    for k in keys {
        sched.cancel(k);
        st.outs.remove(&k);
    }
}

/// Per-iteration connection upkeep: retry backlogs, evict readers that
/// have been stalled past the budget, and garbage-collect connections
/// with no live requests and nothing left to deliver (dropping the
/// engine's sender lets the writer thread exit).
fn maintain_conns(sched: &mut Scheduler<'_>, st: &mut EngineState) {
    let now = Instant::now();
    let mut dead: Vec<u64> = Vec::new();
    let mut slow: Vec<u64> = Vec::new();
    for (&cid, conn) in st.conns.iter_mut() {
        if !flush_backlog(conn) {
            dead.push(cid);
            continue;
        }
        if conn.backlog.is_empty() {
            conn.stalled_since = None;
        } else if conn
            .stalled_since
            .is_some_and(|t| now.duration_since(t) >= st.slow_reader)
        {
            slow.push(cid);
        }
    }
    for cid in dead {
        drop_conn(cid, sched, st);
    }
    for cid in slow {
        sched.obs().metrics.slow_reader_evictions_total.inc();
        drop_conn(cid, sched, st);
    }
    let live: HashSet<u64> = st.outs.values().copied().collect();
    st.conns.retain(|cid, c| live.contains(cid) || !c.backlog.is_empty());
}

/// Route one step's events to their connections.
fn route_events(events: &[StepEvent], sched: &mut Scheduler<'_>, st: &mut EngineState) {
    let now = Instant::now();
    for ev in events {
        let (key, finished) = match ev {
            StepEvent::Token { key, .. } => (*key, false),
            StepEvent::Done { key, .. } => (*key, true),
            StepEvent::Rejected { key, .. } => (*key, true),
        };
        let Some(&cid) = st.outs.get(&key) else { continue };
        let line = protocol::event_frame(ev);
        let outcome = match st.conns.get_mut(&cid) {
            Some(conn) => conn_push(conn, line, now),
            None => {
                st.outs.remove(&key);
                continue;
            }
        };
        match outcome {
            Push::Dead => drop_conn(cid, sched, st),
            Push::Ok | Push::Full => {
                if finished {
                    st.outs.remove(&key);
                }
            }
        }
    }
}

/// Best-effort broadcast of one frame to every connection with in-flight
/// work, then forget all request routing.
fn broadcast_and_clear(frame: &str, st: &mut EngineState) {
    let cids: HashSet<u64> = st.outs.values().copied().collect();
    for cid in cids {
        if let Some(conn) = st.conns.get_mut(&cid) {
            let _ = conn.tx.try_send(frame.to_string());
        }
    }
    st.outs.clear();
}

/// Mirror the fault plan's fire count into `faults_injected_total`.
fn sync_fault_metric(sched: &Scheduler<'_>, st: &mut EngineState) {
    if let Some(f) = &st.fault {
        let total = f.fired();
        if total > st.fired_seen {
            sched.obs().metrics.faults_injected_total.add(total - st.fired_seen);
            st.fired_seen = total;
        }
    }
}

/// Tier boot parameters forwarded to the engine thread (the spill file
/// is sized from the scheduler's pool geometry, which only exists
/// there).
struct TierBoot {
    path: Option<String>,
    max_slots: usize,
    prefix_store: bool,
}

#[allow(clippy::too_many_arguments)]
fn run_engine(
    model: Arc<PackedModel>,
    draft: Option<Arc<PackedModel>>,
    cfg: SchedConfig,
    preload: Vec<AdapterSet>,
    rx: Receiver<EngineMsg>,
    obs: Arc<Telemetry>,
    mut trace: Option<BufWriter<std::fs::File>>,
    fault: Option<Arc<FaultPlan>>,
    slow_reader: Duration,
    tier: TierBoot,
) {
    let mut sched = match draft {
        Some(d) if cfg.speculate > 0 => Scheduler::with_draft(&model, cfg, d),
        _ => Scheduler::new(&model, cfg),
    };
    sched.attach_obs(obs);
    if let Some(plan) = &fault {
        sched.set_fault(Arc::clone(plan));
    }
    if let Some(path) = &tier.path {
        // The path was probed writable at spawn; a failure here (disk
        // pulled in the meantime) stops the engine before any work.
        match TieredKv::new(path, sched.pool(), tier.max_slots, tier.prefix_store) {
            Ok(t) => sched.attach_tier(t),
            Err(e) => {
                eprintln!("serve: kv-spill init failed: {e}");
                return;
            }
        }
    }
    // Names were validated in `spawn_with_draft`; a load can only fail on
    // a duplicate, which the pre-check excluded.
    for set in preload {
        if let Err(e) = sched.adapters_mut().load(set) {
            eprintln!("serve: adapter preload failed: {e}");
        }
    }
    let mut st = EngineState {
        outs: HashMap::new(),
        conns: HashMap::new(),
        next_key: 1,
        draining: false,
        poisoned: false,
        fault,
        fired_seen: 0,
        slow_reader,
    };
    'engine: loop {
        if sig::drain_requested() && !st.draining {
            st.draining = true;
            println!(
                "serve: draining ({} in flight; signal)",
                sched.n_pending() + sched.n_active()
            );
            let _ = std::io::stdout().flush();
        }

        // Drain submissions: short-timeout block when idle (so signals
        // and backlogs are still noticed), poll when the batch is hot.
        if sched.has_work() {
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !handle_msg(msg, &model, &mut sched, &mut st) {
                            break 'engine;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'engine,
                }
            }
        } else {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(msg) => {
                    if !handle_msg(msg, &model, &mut sched, &mut st) {
                        break 'engine;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break 'engine,
            }
        }

        if sched.has_work() && !st.poisoned {
            let stepped =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.step()));
            match stepped {
                Ok(Ok(events)) => {
                    // Journal the tick before routing frames; a failed
                    // write disables the journal, never the engine.
                    if let Some(mut w) = trace.take() {
                        match sched.obs().last_tick() {
                            Some(rec)
                                if writeln!(w, "{}", rec.to_json().render()).is_err()
                                    || w.flush().is_err() =>
                            {
                                eprintln!("serve: trace-log write failed; journal disabled");
                            }
                            _ => trace = Some(w),
                        }
                    }
                    route_events(&events, &mut sched, &mut st);
                }
                Ok(Err(e)) => {
                    // A step failure poisons the whole batch (model-level
                    // error): notify every waiter and reset.
                    let frame = protocol::error_frame(
                        "",
                        code::INTERNAL,
                        &format!("engine step failed: {e}"),
                    );
                    broadcast_and_clear(&frame, &mut st);
                    sched.clear();
                }
                Err(payload) => {
                    // A panic mid-step: quarantine the offending sequence
                    // (all sequences if the panic carries no attribution)
                    // and rebuild pool/registry bookkeeping from the
                    // survivors.  The engine keeps serving.
                    let key = payload.downcast_ref::<SeqPanic>().map(|p| p.key);
                    match key {
                        Some(k) => eprintln!("serve: tick panicked (seq {k}); quarantining"),
                        None => eprintln!("serve: tick panicked; quarantining batch"),
                    }
                    let recovered = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || sched.quarantine(key),
                    ));
                    match recovered {
                        Ok(events) => route_events(&events, &mut sched, &mut st),
                        Err(_) => {
                            // Quarantine itself panicked: scheduler state
                            // is untrusted.  Poison — refuse generation
                            // work but keep answering queries.
                            eprintln!("serve: quarantine failed; engine poisoned");
                            st.poisoned = true;
                            let frame = protocol::error_frame(
                                "",
                                code::INTERNAL,
                                "engine poisoned after failed quarantine",
                            );
                            broadcast_and_clear(&frame, &mut st);
                        }
                    }
                }
            }
        }

        maintain_conns(&mut sched, &mut st);
        sync_fault_metric(&sched, &mut st);

        if st.draining
            && (st.poisoned || !sched.has_work())
            && st.conns.values().all(|c| c.backlog.is_empty())
        {
            if let Some(mut w) = trace.take() {
                let _ = w.flush();
            }
            println!("serve: drained; {} request(s) completed", sched.n_completed());
            let _ = std::io::stdout().flush();
            break 'engine;
        }
    }
}

/// Suggested client backoff when the submission queue is full: scales
/// with queue depth so a deeper queue pushes retries further out.
fn retry_after_ms(sched: &Scheduler<'_>) -> u64 {
    let batch = sched.config().max_batch.max(1) as u64;
    (10 + (sched.n_pending() as u64 * 5) / batch).min(1000)
}

/// Returns false when the engine should exit.
fn handle_msg(
    msg: EngineMsg,
    model: &PackedModel,
    sched: &mut Scheduler<'_>,
    st: &mut EngineState,
) -> bool {
    match msg {
        EngineMsg::Submit { wire, queued_at, conn, out } => {
            if st.poisoned || st.draining {
                let reason = if st.poisoned {
                    "engine poisoned; refusing new work"
                } else {
                    "server draining"
                };
                let _ =
                    out.try_send(protocol::error_frame(&wire.id, code::UNAVAILABLE, reason));
                return true;
            }
            let default_ms = sched.config().deadline_ms;
            let deadline = wire
                .deadline_ms
                .or(if default_ms > 0 { Some(default_ms) } else { None })
                .map(|ms| queued_at + Duration::from_millis(ms));
            let key = st.next_key;
            st.next_key += 1;
            let req = GenRequest {
                key,
                id: wire.id,
                prompt: wire.prompt,
                max_new: wire.max_new,
                sampling: wire.sampling,
                stop: wire.stop,
                adapter: wire.adapter,
                queued_at,
                deadline,
                session: wire.session,
            };
            match sched.try_submit(req) {
                Ok(()) => {
                    st.conns.entry(conn).or_insert_with(|| ConnState {
                        tx: out,
                        backlog: VecDeque::new(),
                        stalled_since: None,
                    });
                    st.outs.insert(key, conn);
                }
                Err(req) => {
                    let _ = out.try_send(protocol::overloaded_frame(
                        &req.id,
                        retry_after_ms(sched),
                    ));
                }
            }
            true
        }
        EngineMsg::Stats { out } => {
            let kv = sched.kv_stats();
            let spec = sched.spec_stats();
            let tier = sched.tier_stats();
            let adapters = sched.adapters().stats();
            let build = crate::obs::build_info();
            let frame = protocol::stats_frame(&EngineSnapshot {
                kv: &kv,
                active: sched.n_active(),
                pending: sched.n_pending(),
                completed: sched.n_completed(),
                spec: spec.as_ref(),
                tier: tier.as_ref(),
                adapters: &adapters,
                baseline_tokens: sched.adapters().baseline_tokens(),
                build: &build,
                uptime_secs: sched.obs().uptime_secs(),
            });
            let _ = out.try_send(frame);
            true
        }
        EngineMsg::Metrics { out } => {
            let _ = out.try_send(protocol::metrics_frame(sched.obs()));
            true
        }
        EngineMsg::Trace { n, out } => {
            let (total, ticks) = sched.obs().last_ticks(n);
            let _ = out.try_send(protocol::trace_frame(total, &ticks));
            true
        }
        EngineMsg::Adapter { op, name, path, out } => {
            let result = match op {
                AdapterOp::Load => {
                    if st.fault.as_ref().is_some_and(|f| f.fires(FaultPoint::AdapterIo)) {
                        Err(Error::io("injected fault: adapter load I/O failure"))
                    } else {
                        path.as_deref()
                            .ok_or_else(|| Error::config("adapter load needs a path"))
                            .and_then(|p| checkpoint::load_adapter(p, &model.cfg))
                            .and_then(|mut set| {
                                set.name = name.clone();
                                sched.adapters_mut().load(set)
                            })
                            .map(|()| "loaded")
                    }
                }
                AdapterOp::Unload => sched.adapters_mut().unload(&name).map(|now| {
                    if now {
                        "unloaded"
                    } else {
                        "draining"
                    }
                }),
            };
            let frame = match result {
                Ok(status) => protocol::adapter_frame(op, &name, status),
                Err(e) => protocol::error_frame("", code::BAD_REQUEST, &e.to_string()),
            };
            let _ = out.try_send(frame);
            true
        }
        EngineMsg::Drain { out } => {
            if !st.draining {
                st.draining = true;
                println!(
                    "serve: draining ({} in flight)",
                    sched.n_pending() + sched.n_active()
                );
                let _ = std::io::stdout().flush();
            }
            let _ = out.try_send(protocol::drain_frame(
                "draining",
                sched.n_pending() + sched.n_active(),
            ));
            true
        }
        EngineMsg::Shutdown => false,
    }
}

/// Per-connection settings snapshot handed to each reader thread.
#[derive(Clone)]
struct ConnOpts {
    allow_shutdown: bool,
    max_line: usize,
    out_queue: usize,
    fault: Option<Arc<FaultPlan>>,
}

enum LineRead {
    /// One complete line is in the buffer (trailing `\n` stripped).
    Line,
    /// Clean end of stream.
    Eof,
    /// The line exceeded `max_line`; the remainder was discarded up to
    /// the next newline.
    TooLong,
    /// Transport error; the connection is unusable.
    IoErr,
}

/// Read one newline-terminated line of at most `max` bytes.  Oversized
/// lines are discarded to the next newline so one hostile line cannot
/// buffer unboundedly or desync the stream.
fn read_client_line(r: &mut impl BufRead, buf: &mut Vec<u8>, max: usize) -> LineRead {
    match r.by_ref().take(max as u64 + 1).read_until(b'\n', buf) {
        Ok(0) => LineRead::Eof,
        Ok(_) => {
            if buf.last() == Some(&b'\n') {
                buf.pop();
                if buf.len() > max {
                    return LineRead::TooLong;
                }
                return LineRead::Line;
            }
            if buf.len() > max {
                // Skip the rest of the oversized line.
                loop {
                    let (done, used) = match r.fill_buf() {
                        Ok(chunk) if chunk.is_empty() => (true, 0),
                        Ok(chunk) => match chunk.iter().position(|&b| b == b'\n') {
                            Some(pos) => (true, pos + 1),
                            None => (false, chunk.len()),
                        },
                        Err(_) => (true, 0),
                    };
                    r.consume(used);
                    if done {
                        break;
                    }
                }
                LineRead::TooLong
            } else {
                // Final line without a trailing newline (EOF).
                LineRead::Line
            }
        }
        Err(_) => LineRead::IoErr,
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<EngineMsg>, o: ConnOpts) {
    let conn_id = NEXT_CONN.fetch_add(1, Ordering::Relaxed);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (otx, orx) = mpsc::sync_channel::<String>(o.out_queue);
    let wfault = o.fault.clone();
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for line in orx {
            if wfault.as_ref().is_some_and(|f| f.fires(FaultPoint::ConnWrite)) {
                break; // injected write failure: drop the connection
            }
            if w.write_all(line.as_bytes()).is_err()
                || w.write_all(b"\n").is_err()
                || w.flush().is_err()
            {
                break; // client hung up; engine cancels on next push
            }
        }
    });

    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        match read_client_line(&mut reader, &mut buf, o.max_line) {
            LineRead::Eof | LineRead::IoErr => break,
            LineRead::TooLong => {
                let _ = otx.send(protocol::error_frame(
                    "",
                    code::BAD_REQUEST,
                    &format!("request line exceeds --max-line ({} bytes)", o.max_line),
                ));
                continue;
            }
            LineRead::Line => {}
        }
        let Ok(text) = std::str::from_utf8(&buf) else {
            let _ = otx.send(protocol::error_frame(
                "",
                code::BAD_REQUEST,
                "request line is not valid UTF-8",
            ));
            continue;
        };
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        match protocol::parse_line(line) {
            Ok(ClientLine::Shutdown) => {
                if o.allow_shutdown {
                    let _ = tx.send(EngineMsg::Shutdown);
                } else {
                    let _ = otx.send(protocol::error_frame(
                        "",
                        code::UNAVAILABLE,
                        "shutdown disabled",
                    ));
                }
                break;
            }
            Ok(ClientLine::Drain) => {
                if tx.send(EngineMsg::Drain { out: otx.clone() }).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Ok(ClientLine::Request(wire)) => {
                let msg = EngineMsg::Submit {
                    wire,
                    queued_at: Instant::now(),
                    conn: conn_id,
                    out: otx.clone(),
                };
                if tx.send(msg).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Ok(ClientLine::Stats) => {
                if tx.send(EngineMsg::Stats { out: otx.clone() }).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Ok(ClientLine::Metrics) => {
                if tx.send(EngineMsg::Metrics { out: otx.clone() }).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Ok(ClientLine::Trace { n }) => {
                if tx.send(EngineMsg::Trace { n, out: otx.clone() }).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Ok(ClientLine::Adapter { op, name, path }) => {
                let msg = EngineMsg::Adapter { op, name, path, out: otx.clone() };
                if tx.send(msg).is_err() {
                    let _ = otx.send(engine_stopped_frame());
                    break;
                }
            }
            Err(e) => {
                let _ = otx.send(protocol::error_frame("", code::BAD_REQUEST, &e.to_string()));
            }
        }
    }
    drop(otx);
    let _ = writer.join();
}

fn engine_stopped_frame() -> String {
    protocol::error_frame("", code::UNAVAILABLE, "engine stopped")
}
