//! Minimal JSON for the serve line protocol.
//!
//! The offline registry has no serde, so the newline-delimited protocol
//! rides on this ~200-line value type: a recursive-descent parser (UTF-8,
//! escape sequences incl. surrogate pairs, numbers via `f64`) and a
//! writer.  Objects are ordered `(key, value)` vectors — linear lookup is
//! fine at protocol scale and keeps rendering deterministic.

use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(v) if v.fract() == 0.0 && v.abs() < 9.0e15 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse one complete JSON value; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(Error::config(format!(
                "json: trailing content at byte {pos}"
            )));
        }
        Ok(v)
    }

    /// Render to a compact single-line string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if !v.is_finite() {
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for protocol emitters.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn err_at(pos: usize, what: &str) -> Error {
    Error::config(format!("json: {what} at byte {pos}"))
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        Err(err_at(*pos, "invalid literal"))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(err_at(*pos, "unexpected end of input")),
        Some(b'n') => expect(b, pos, "null").map(|_| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|_| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => parse_array(b, pos),
        Some(b'{') => parse_object(b, pos),
        Some(c) if *c == b'-' || c.is_ascii_digit() => parse_number(b, pos),
        Some(_) => Err(err_at(*pos, "unexpected character")),
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err_at(*pos, "expected ',' or ']'")),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(err_at(*pos, "expected object key"));
        }
        let key = parse_string(b, pos)?;
        // Reject duplicate keys outright: `get` is first-match, so a
        // last-wins or first-wins policy would make lines like
        // {"adapter":"a","adapter":"b"} silently route ambiguously.
        if fields.iter().any(|(k, _)| *k == key) {
            return Err(err_at(*pos, &format!("duplicate object key {key:?}")));
        }
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(err_at(*pos, "expected ':'"));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        fields.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(err_at(*pos, "expected ',' or '}'")),
        }
    }
}

fn parse_hex4(b: &[u8], pos: &mut usize) -> Result<u32> {
    if b.len() - *pos < 4 {
        return Err(err_at(*pos, "truncated \\u escape"));
    }
    let mut v = 0u32;
    for _ in 0..4 {
        let c = b[*pos];
        let d = match c {
            b'0'..=b'9' => (c - b'0') as u32,
            b'a'..=b'f' => (c - b'a') as u32 + 10,
            b'A'..=b'F' => (c - b'A') as u32 + 10,
            _ => return Err(err_at(*pos, "bad hex digit in \\u escape")),
        };
        v = v * 16 + d;
        *pos += 1;
    }
    Ok(v)
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    *pos += 1; // opening '"'
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(err_at(*pos, "unterminated string")),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => {
                        out.push('"');
                        *pos += 1;
                    }
                    Some(b'\\') => {
                        out.push('\\');
                        *pos += 1;
                    }
                    Some(b'/') => {
                        out.push('/');
                        *pos += 1;
                    }
                    Some(b'b') => {
                        out.push('\u{0008}');
                        *pos += 1;
                    }
                    Some(b'f') => {
                        out.push('\u{000C}');
                        *pos += 1;
                    }
                    Some(b'n') => {
                        out.push('\n');
                        *pos += 1;
                    }
                    Some(b'r') => {
                        out.push('\r');
                        *pos += 1;
                    }
                    Some(b't') => {
                        out.push('\t');
                        *pos += 1;
                    }
                    Some(b'u') => {
                        *pos += 1;
                        let hi = parse_hex4(b, pos)?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair: expect \uDC00..\uDFFF next
                            if b.get(*pos) == Some(&b'\\') && b.get(*pos + 1) == Some(&b'u') {
                                *pos += 2;
                                let lo = parse_hex4(b, pos)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(err_at(*pos, "bad low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                return Err(err_at(*pos, "lone high surrogate"));
                            }
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(err_at(*pos, "lone low surrogate"));
                        } else {
                            hi
                        };
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            None => return Err(err_at(*pos, "invalid codepoint")),
                        }
                    }
                    _ => return Err(err_at(*pos, "bad escape")),
                }
            }
            Some(_) => {
                // consume one UTF-8 scalar (input is a &str, so boundaries
                // are valid; find the end of this char)
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && (b[*pos] & 0xC0) == 0x80 {
                    *pos += 1;
                }
                // SAFETY-free: re-slice through str is not available on
                // bytes, so decode via from_utf8 on the scalar's bytes.
                match std::str::from_utf8(&b[start..*pos]) {
                    Ok(s) => out.push_str(s),
                    Err(_) => return Err(err_at(start, "invalid utf-8")),
                }
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])
        .map_err(|_| err_at(start, "invalid number bytes"))?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err_at(start, "invalid number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"id":"r1","prompt":[1,2,3],"max_new":8,"nested":{"a":[true,null]}}"#)
            .unwrap();
        assert_eq!(j.get("id").and_then(Json::as_str), Some("r1"));
        let prompt: Vec<i64> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(prompt, vec![1, 2, 3]);
        assert_eq!(j.get("max_new").and_then(Json::as_i64), Some(8));
        assert_eq!(
            j.get("nested").and_then(|n| n.get("a")).and_then(Json::as_arr).map(|a| a.len()),
            Some(2)
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Obj(vec![(
            "msg".into(),
            Json::Str("line1\nline2\t\"quoted\" \\ unicode: \u{263A}".into()),
        )]);
        let rendered = j.render();
        assert_eq!(Json::parse(&rendered).unwrap(), j);
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // U+1F600 as an escaped surrogate pair, and as raw UTF-8
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
        assert_eq!(
            Json::parse("\"\u{1F600}\"").unwrap(),
            Json::Str("\u{1F600}".into())
        );
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high surrogate");
        assert!(Json::parse(r#""\ude00""#).is_err(), "lone low surrogate");
    }

    #[test]
    fn unicode_escape_property_roundtrip() {
        // Random scalar values across the whole codepoint space: the
        // escaped form (\uXXXX for the BMP, a surrogate pair above it)
        // must parse to exactly that character, and whatever the writer
        // renders (raw UTF-8, or \u00XX for controls) must reparse to
        // the same value.  This is the path the stats frame's nested
        // spec/kv objects lean on hardest.
        use crate::tensor::Rng;
        let mut rng = Rng::new(0xE5C);
        for round in 0..400 {
            let c = loop {
                // bias every 4th draw into the control range so the
                // writer's \u00XX arm is exercised too
                let raw = if round % 4 == 0 {
                    rng.next_u64() % 0x20
                } else {
                    rng.next_u64() % 0x11_0000
                };
                let raw = raw as u32;
                if (0xD800..0xE000).contains(&raw) {
                    continue;
                }
                if let Some(c) = char::from_u32(raw) {
                    break c;
                }
            };
            let cp = c as u32;
            let esc = if cp < 0x10000 {
                format!("\"\\u{cp:04x}\"")
            } else {
                let u = cp - 0x10000;
                format!("\"\\u{:04x}\\u{:04x}\"", 0xD800 + (u >> 10), 0xDC00 + (u & 0x3FF))
            };
            assert_eq!(
                Json::parse(&esc).unwrap(),
                Json::Str(c.to_string()),
                "escaped form of U+{cp:04X} must parse to the character"
            );
            let j = Json::Obj(vec![("s".into(), Json::Str(format!("a{c}b")))]);
            assert_eq!(Json::parse(&j.render()).unwrap(), j, "render/parse of U+{cp:04X}");
        }
    }

    #[test]
    fn truncated_and_malformed_unicode_escapes_error() {
        for bad in [
            // truncated \u escapes (the parse_hex4 length guard)
            "\"\\u",
            "\"\\u1",
            "\"\\u12",
            "\"\\u123",
            "\"\\ud83d\\u",
            "\"\\ud83d\\ude0",
            // enough bytes but not hex
            r#""\u123g""#,
            r#""\uzzzz""#,
            // surrogate pairing violations
            r#""\ud83d""#,
            r#""\ud83dx""#,
            r#""\ud83d\n""#,
            r#""\ud83d\u0041""#,
            r#""\udfff\ude00""#,
            r#""\ude00""#,
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "nul", "\"unterminated", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn renders_ints_without_fraction() {
        assert_eq!(Json::Num(7.0).render(), "7");
        assert_eq!(Json::Num(-2.0).render(), "-2");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn renders_non_finite_as_null_everywhere() {
        // bare infinities (a +Inf histogram bound takes this path)
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).render(), "null");
        // nested inside containers the output must stay parseable JSON
        let j = Json::Obj(vec![
            ("le".to_string(), Json::Num(f64::INFINITY)),
            ("xs".to_string(), Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)])),
        ]);
        let rendered = j.render();
        assert_eq!(rendered, r#"{"le":null,"xs":[1,null]}"#);
        let back = Json::parse(&rendered).unwrap();
        assert_eq!(back.get("le"), Some(&Json::Null));
    }

    #[test]
    fn rejects_duplicate_keys() {
        for bad in [
            r#"{"a":1,"a":2}"#,
            r#"{"adapter":"a","adapter":"b"}"#,
            r#"{"x":{"k":1,"k":2}}"#,
            r#"{"a":1,"b":{"c":[{"d":0,"d":1}]}}"#,
        ] {
            assert!(Json::parse(bad).is_err(), "should reject duplicate keys in {bad}");
        }
        // distinct keys still fine, incl. repeated keys in SIBLING objects
        assert!(Json::parse(r#"[{"a":1},{"a":2}]"#).is_ok());
    }

    #[test]
    fn object_get_finds_first() {
        let j = Json::parse(r#"{"a":1,"b":2}"#).unwrap();
        assert_eq!(j.get("b").and_then(Json::as_i64), Some(2));
        assert!(j.get("missing").is_none());
        assert!(Json::Null.get("a").is_none());
    }
}
