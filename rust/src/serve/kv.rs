//! Flat per-sequence KV caches: the reference layout for paged decode.
//!
//! A [`KvCache`] holds one sequence's post-RoPE keys and values for every
//! transformer layer in two pre-allocated flat buffers (layer-major,
//! position-minor), sized once to `prompt_len + max_new` so the decode
//! loop never reallocates.  Retired buffers recycle through a [`KvPool`].
//!
//! Production serving now runs on the paged subsystem
//! ([`crate::serve::block::BlockPool`] +
//! [`crate::serve::paged::PagedKvCache`]); the flat slab stays alive as
//! the bit-exact equivalence oracle for it — the same role
//! `generate_recompute` plays for cached decode — and as the simple
//! storage behind `serve::decode::generate`.

use crate::error::{Error, Result};

/// Pre-allocated K/V storage for ONE sequence across ALL layers.
///
/// Layout: `k[(layer * cap + pos) * d .. +d]` is the key row of `pos`
/// within `layer` (same for `v`).  `len` counts *completed* positions and
/// is shared by all layers: during one forward pass each layer writes its
/// rows at `len..len + t` via [`KvCache::write_rows`], and the caller
/// advances `len` once with [`KvCache::advance`] after the last layer.
pub struct KvCache {
    n_layers: usize,
    d: usize,
    cap: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(n_layers: usize, d: usize, cap: usize) -> Self {
        KvCache {
            n_layers,
            d,
            cap,
            len: 0,
            k: vec![0.0; n_layers * cap * d],
            v: vec![0.0; n_layers * cap * d],
        }
    }

    /// Completed positions (the attention span of the next decode step).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache can hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Positions still writable.
    pub fn remaining(&self) -> usize {
        self.cap - self.len
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Rewind to empty (buffers are reused, not zeroed — every readable
    /// row is always written first).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes resident in this cache's buffers.
    pub fn resident_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Check this cache was allocated for `model`-shaped K/V rows.
    pub fn check_shape(&self, n_layers: usize, d: usize) -> Result<()> {
        if self.n_layers != n_layers || self.d != d {
            return Err(Error::shape(format!(
                "KvCache built for {} layers x d {}, model wants {} x {}",
                self.n_layers, self.d, n_layers, d
            )));
        }
        Ok(())
    }

    /// Write `t = krows.len() / d` new K/V rows of `layer` at positions
    /// `len..len + t`.  Does NOT advance `len` (all layers write the same
    /// positions during one pass).
    pub fn write_rows(&mut self, layer: usize, krows: &[f32], vrows: &[f32]) -> Result<()> {
        debug_assert_eq!(krows.len(), vrows.len());
        debug_assert!(layer < self.n_layers);
        let t = krows.len() / self.d;
        if self.len + t > self.cap {
            return Err(Error::shape(format!(
                "KvCache overflow: {} + {t} rows > capacity {}",
                self.len, self.cap
            )));
        }
        let off = (layer * self.cap + self.len) * self.d;
        self.k[off..off + krows.len()].copy_from_slice(krows);
        self.v[off..off + vrows.len()].copy_from_slice(vrows);
        Ok(())
    }

    /// Key rows `[0, upto)` of `layer`, contiguous row-major (upto, d).
    pub fn keys(&self, layer: usize, upto: usize) -> &[f32] {
        let off = layer * self.cap * self.d;
        &self.k[off..off + upto * self.d]
    }

    /// Value rows `[0, upto)` of `layer`, contiguous row-major (upto, d).
    pub fn values(&self, layer: usize, upto: usize) -> &[f32] {
        let off = layer * self.cap * self.d;
        &self.v[off..off + upto * self.d]
    }

    /// Commit `t` freshly written positions.
    pub fn advance(&mut self, t: usize) {
        debug_assert!(self.len + t <= self.cap);
        self.len += t;
    }

    /// Roll back to at most `len` committed positions (speculative-decode
    /// rejection).  Rows beyond `len` become garbage and are rewritten
    /// before any read — the same invariant `reset` relies on.  A `len`
    /// at or past the current length is a no-op.
    pub fn truncate(&mut self, len: usize) {
        self.len = self.len.min(len);
    }
}

/// Retired caches the pool keeps around (bounds worst-case idle memory).
const POOL_KEEP: usize = 32;

/// Recycling ring of [`KvCache`]s for one model shape.
pub struct KvPool {
    n_layers: usize,
    d: usize,
    free: Vec<KvCache>,
}

impl KvPool {
    pub fn new(n_layers: usize, d: usize) -> Self {
        KvPool { n_layers, d, free: Vec::new() }
    }

    /// Take a cache with capacity >= `cap`, reusing the BEST-FITTING
    /// (smallest sufficient) retired buffer, else allocating fresh.
    /// First-fit used to burn a 16k-cap slab on a 64-token request,
    /// forcing the next long request to allocate fresh; best-fit keeps
    /// big retirees for big asks.  (The paged [`crate::serve::block::BlockPool`]
    /// sidesteps the problem entirely — fixed-size pages make every fit
    /// exact.)
    pub fn take(&mut self, cap: usize) -> KvCache {
        let best = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, c)| c.capacity() >= cap)
            .min_by_key(|(_, c)| c.capacity())
            .map(|(i, _)| i);
        if let Some(i) = best {
            let mut c = self.free.swap_remove(i);
            c.reset();
            return c;
        }
        KvCache::new(self.n_layers, self.d, cap)
    }

    /// Return a cache to the ring.
    pub fn give(&mut self, cache: KvCache) {
        if self.free.len() < POOL_KEEP {
            self.free.push(cache);
        }
    }

    /// Retired caches currently held.
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_advance() {
        let (layers, d, cap) = (2usize, 4usize, 3usize);
        let mut c = KvCache::new(layers, d, cap);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.remaining(), 3);

        // two positions at once, both layers, then advance
        let k0: Vec<f32> = (0..2 * d).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..2 * d).map(|i| 10.0 + i as f32).collect();
        c.write_rows(0, &k0, &v0).unwrap();
        let k1: Vec<f32> = (0..2 * d).map(|i| 100.0 + i as f32).collect();
        c.write_rows(1, &k1, &v0).unwrap();
        c.advance(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.keys(0, 2), &k0[..]);
        assert_eq!(c.values(0, 2), &v0[..]);
        assert_eq!(c.keys(1, 2), &k1[..]);

        // one more position lands after the first two
        let k2: Vec<f32> = (0..d).map(|i| 200.0 + i as f32).collect();
        c.write_rows(0, &k2, &k2).unwrap();
        c.advance(1);
        assert_eq!(c.len(), 3);
        assert_eq!(&c.keys(0, 3)[2 * d..], &k2[..]);
        assert_eq!(c.remaining(), 0);

        // overflow is an error, not a panic
        assert!(c.write_rows(0, &k2, &k2).is_err());
    }

    #[test]
    fn truncate_rolls_back_and_rewrites() {
        let (layers, d, cap) = (1usize, 2usize, 6usize);
        let mut c = KvCache::new(layers, d, cap);
        let k: Vec<f32> = (0..4 * d).map(|i| i as f32).collect();
        c.write_rows(0, &k, &k).unwrap();
        c.advance(4);

        // roll back two positions: the kept prefix is untouched
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.remaining(), 4);
        assert_eq!(c.keys(0, 2), &k[..2 * d]);

        // at-or-past the current length is a no-op
        c.truncate(2);
        c.truncate(99);
        assert_eq!(c.len(), 2);

        // re-growing overwrites the garbage tail before it is read
        let k2: Vec<f32> = (0..d).map(|i| 100.0 + i as f32).collect();
        c.write_rows(0, &k2, &k2).unwrap();
        c.advance(1);
        assert_eq!(&c.keys(0, 3)[2 * d..], &k2[..]);

        c.truncate(0);
        assert!(c.is_empty());
    }

    #[test]
    fn shape_check() {
        let c = KvCache::new(2, 4, 3);
        assert!(c.check_shape(2, 4).is_ok());
        assert!(c.check_shape(3, 4).is_err());
        assert!(c.check_shape(2, 8).is_err());
    }

    #[test]
    fn pool_recycles_big_enough_buffers() {
        let mut pool = KvPool::new(2, 4);
        let mut a = pool.take(8);
        a.write_rows(0, &[1.0; 4], &[2.0; 4]).unwrap();
        a.advance(1);
        pool.give(a);
        assert_eq!(pool.idle(), 1);

        // smaller request reuses the retired buffer, reset to empty
        let b = pool.take(4);
        assert_eq!(b.capacity(), 8);
        assert!(b.is_empty());
        assert_eq!(pool.idle(), 0);

        // bigger request allocates fresh
        pool.give(b);
        let c = pool.take(16);
        assert_eq!(c.capacity(), 16);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn pool_take_is_best_fit() {
        let mut pool = KvPool::new(1, 2);
        pool.give(KvCache::new(1, 2, 64));
        pool.give(KvCache::new(1, 2, 8));
        pool.give(KvCache::new(1, 2, 16));

        // a tiny ask must NOT burn the 64-cap slab: smallest fit wins
        let a = pool.take(4);
        assert_eq!(a.capacity(), 8);
        // next-smallest sufficient buffer for a mid ask
        let b = pool.take(10);
        assert_eq!(b.capacity(), 16);
        // the big slab is still there for the big ask
        let c = pool.take(40);
        assert_eq!(c.capacity(), 64);
        assert_eq!(pool.idle(), 0);
    }
}
