//! `repro bench-serve`: a concurrent load generator for the line
//! protocol.
//!
//! Spawns `clients` threads, each holding one connection and issuing
//! `requests_per_client` streaming requests back to back; records
//! time-to-first-token and total latency per request against a shared
//! epoch, validates the streamed frames (in-order `index`es, `done`
//! token count matching the stream), and reports throughput plus latency
//! percentiles and the peak number of concurrently streaming requests —
//! the observable proof that continuous batching interleaves mid-flight
//! admissions.
//!
//! `common_prefix > 0` makes the first N prompt tokens identical across
//! every request (all clients derive them from the same seed), which
//! drives the server's prompt-prefix sharing; after the load drains, one
//! extra connection sends `{"cmd":"stats"}` and the scraped KV block
//! accounting (peak resident / peak shared pages) rides on the report —
//! that is where `repro bench-serve`'s `BENCH_serve.json` gets its
//! serving-memory numbers.
//!
//! `adapter_mix` turns the run into a mixed-adapter scenario: client `i`
//! routes every request to `adapter_mix[i % len]` (`"-"` = the baseline,
//! no `"adapter"` field), so one continuous batch carries several LoRA
//! deltas over the shared 2-bit base.  `churn_adapter` additionally
//! load/unloads a named adapter over a side connection WHILE the load
//! runs, exercising the registry's deferred-unload path under traffic.
//! The post-run stats scrape picks up the server's per-adapter token
//! counts and delta-GEMM overhead fractions for `BENCH_serve.json`.
//!
//! `sample_ms > 0` additionally polls `{"cmd":"stats"}` on a side
//! connection every `sample_ms` milliseconds WHILE the load runs,
//! recording a time series of batch size (active sequences), queue depth
//! and KV block occupancy — the mid-run view a single post-run scrape
//! cannot give (peak/median batch size, occupancy ramp).  The series and
//! its summaries ride on `BENCH_serve.json`.
//!
//! `sessions > 0` adds that many session clients to the mix (against a
//! `--kv-spill` server): each opens a `"session"`-tagged request, streams
//! half its token budget to completion, hangs up the connection, sleeps
//! `rejoin_ms`, then reconnects and continues the same session with
//! `prompt = original prompt + every received token`.  The continuation
//! resumes from the server's parked KV pages — the `done` frame's
//! `shared_prefix_tokens` equals `len(prompt) - 1` when not a single
//! position was re-prefilled — and its time-to-first-token is the resume
//! latency the report summarizes.  The post-run scrape also picks up the
//! stats frame's `tier` object (spill occupancy, preemptions, prefix
//! hit rate) when the server is tiered.
//!
//! The generator is resilient by design (it doubles as the chaos-test
//! driver): connect and transport failures reconnect with jittered
//! exponential backoff, `overloaded` rejections honor the server's
//! `retry_after_ms` up to `max_retries` attempts, each request has an
//! optional client-side `request_timeout_ms`, and every request ends in
//! exactly one terminal bucket — `completed`, `rejected`, `deadline`, or
//! `failed` — instead of the first error killing the whole run.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::metrics::latency::LatencySummary;
use crate::serve::json::Json;
use crate::tensor::Rng;

/// Load shape for one `bench-serve` run.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    pub addr: String,
    pub clients: usize,
    pub requests_per_client: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    /// Prompts draw uniform tokens from [0, vocab).
    pub vocab: usize,
    /// First `common_prefix` tokens of EVERY prompt are identical across
    /// all clients/requests (capped at `prompt_len`) — exercises the
    /// server's KV prefix sharing.
    pub common_prefix: usize,
    /// 0 = greedy; otherwise seeded sampling at this temperature.
    pub temperature: f32,
    pub seed: u64,
    /// Send `{"cmd":"shutdown"}` after the run (CI teardown).
    pub shutdown_after: bool,
    /// Write each request's generated tokens (one sorted `id t1 t2 ...`
    /// line per request) to this path — byte-comparable across runs, the
    /// CI proof that `--speculate` changes no output bits.
    pub transcript: Option<String>,
    /// Round-robin client->adapter routing: client `i` sends every
    /// request with `"adapter": adapter_mix[i % len]`; the entry `"-"`
    /// means the baseline (no adapter field).  Empty = all baseline.
    pub adapter_mix: Vec<String>,
    /// `(name, path)`: while the load runs, a side connection repeatedly
    /// loads then unloads this adapter via `{"cmd":"adapter"}` — the
    /// churn scenario.  Keep the name OUT of `adapter_mix` unless you
    /// want routed requests racing the unloads.
    pub churn_adapter: Option<(String, String)>,
    /// Poll `{"cmd":"stats"}` every this-many milliseconds during the
    /// run and record a batch-size / KV-occupancy time series.  0 = off.
    pub sample_ms: u64,
    /// Attach `"deadline_ms": N` to every request (0 = no deadline).
    pub deadline_ms: u64,
    /// Client-side socket read timeout per frame, ms (0 = block forever).
    /// A timed-out request reconnects and retries like any transport
    /// failure.
    pub request_timeout_ms: u64,
    /// Max re-attempts per request after `overloaded` rejections or
    /// transport failures before the request is counted terminal.
    pub max_retries: usize,
    /// Session clients run alongside the normal load: each streams half
    /// its `max_new` budget under a `"session"` id, drops the connection,
    /// waits `rejoin_ms`, reconnects and continues the session (prompt =
    /// original + every received token).  Wants a `--kv-spill` server;
    /// without one the continuation simply re-prefills.  0 = off.
    pub sessions: usize,
    /// How long a session client stays disconnected before rejoining.
    pub rejoin_ms: u64,
}

/// Per-request observation (offsets from the run epoch, seconds).
#[derive(Clone, Debug)]
struct ReqRecord {
    id: String,
    sent_at: f64,
    first_token_at: f64,
    done_at: f64,
    n_tokens: usize,
    tokens: Vec<i64>,
    /// Adapter this request was routed to (`None` = baseline).
    adapter: Option<String>,
    /// KV positions this request reused instead of prefilling (donor
    /// fork, session resume, or prefix-store promotion), from the done
    /// frame's `stats.shared_prefix_tokens`.
    shared_prefix_tokens: usize,
}

/// KV block accounting scraped from the server's stats frame after the
/// load drained (current counts are near-idle by then; the peaks carry
/// the run's memory story).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvSnapshot {
    pub block_size: usize,
    pub blocks_total: usize,
    pub resident_blocks: usize,
    pub shared_blocks: usize,
    pub peak_resident_blocks: usize,
    pub peak_shared_blocks: usize,
    pub block_bytes: usize,
    pub peak_resident_bytes: usize,
    /// Storage width of the pool layout (16 = f32, 8/4 = quantized).
    pub kv_bits: usize,
    /// What one page costs at f32 — the denominator for the ratio story.
    pub f32_block_bytes: usize,
}

impl KvSnapshot {
    /// Peak resident bytes as a fraction of the same peak page count at
    /// f32; 1.0 under the f32 layout, ~0.27 for sealed 8-bit pages.
    pub fn peak_resident_ratio(&self) -> f64 {
        let f32_cost = self.peak_resident_blocks * self.f32_block_bytes;
        if f32_cost == 0 {
            return 1.0;
        }
        self.peak_resident_bytes as f64 / f32_cost as f64
    }
}

/// Speculative-decoding counters scraped from the stats frame's `spec`
/// object (absent when the server does not speculate).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecSnapshot {
    pub k: usize,
    pub proposed: usize,
    pub accepted: usize,
    pub cycles: usize,
    pub fallbacks: usize,
    pub draft_peak_resident_blocks: usize,
}

impl SpecSnapshot {
    /// Accepted fraction of proposed draft tokens; 0.0 when nothing was
    /// proposed (total fallback must not read as perfect speculation).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }
}

/// Tiered-KV counters scraped from the stats frame's `tier` object
/// (absent when the server runs without `--kv-spill`).
#[derive(Clone, Copy, Debug, Default)]
pub struct TierSnapshot {
    pub spilled_blocks: usize,
    pub spilled_bytes: usize,
    pub slots_resident: usize,
    pub slots_total: usize,
    pub preemptions: usize,
    pub resumes: usize,
    pub suspended: usize,
    pub block_restores: usize,
    pub restore_failures: usize,
    pub sessions_stored: usize,
    pub session_resumes: usize,
    pub prefix_pages: usize,
    pub prefix_hits: usize,
    pub prefix_misses: usize,
    pub promotes: usize,
}

impl TierSnapshot {
    /// Fraction of prefix-store lookups that found reusable pages; 0.0
    /// when the store was never consulted.
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_hits + self.prefix_misses;
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / lookups as f64
    }
}

/// One registered adapter's registry accounting scraped from the stats
/// frame's `adapters` array.
#[derive(Clone, Debug, Default)]
pub struct AdapterSnapshot {
    pub name: String,
    pub rank: usize,
    pub n_adapted: usize,
    pub resident_bytes: usize,
    pub refs: usize,
    pub tokens: usize,
    pub draining: bool,
    /// Extra low-rank delta FLOPs as a fraction of the base model's
    /// per-token linear FLOPs.
    pub delta_overhead: f64,
}

/// One `{"cmd":"stats"}` round trip's worth of server accounting.
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub kv: KvSnapshot,
    pub spec: Option<SpecSnapshot>,
    pub tier: Option<TierSnapshot>,
    pub adapters: Vec<AdapterSnapshot>,
    pub baseline_tokens: usize,
    /// Sequences decoding in the batch at scrape time.
    pub active: usize,
    /// Requests queued behind the batch at scrape time.
    pub pending: usize,
}

/// One mid-run stats poll (offsets from the run epoch, seconds).
#[derive(Clone, Copy, Debug)]
pub struct LoadSample {
    pub t_secs: f64,
    /// Active sequences — the instantaneous batch size.
    pub active: usize,
    /// Queued requests not yet admitted.
    pub pending: usize,
    pub kv_resident_blocks: usize,
    pub kv_blocks_total: usize,
}

/// Aggregated results of one load run.
pub struct LoadReport {
    pub requests: usize,
    pub completed: usize,
    pub total_tokens: usize,
    pub wall_secs: f64,
    pub ttft: LatencySummary,
    pub total: LatencySummary,
    /// Peak number of requests simultaneously between first token and
    /// done — >= 2 demonstrates interleaved (continuously batched)
    /// streams.
    pub peak_concurrent_streams: usize,
    /// Post-run KV memory scrape (`None` if the server predates the
    /// stats command or the scrape failed).
    pub kv: Option<KvSnapshot>,
    /// Post-run speculative-decoding scrape (`None` when the server does
    /// not speculate or the scrape failed).
    pub spec: Option<SpecSnapshot>,
    /// Post-run tiered-KV scrape (`None` when the server runs without
    /// `--kv-spill` or the scrape failed).
    pub tier: Option<TierSnapshot>,
    /// Post-run registry scrape: one entry per adapter still registered
    /// (churned-away adapters are gone by then, by design).
    pub adapters: Vec<AdapterSnapshot>,
    /// Server-side count of tokens emitted on the baseline (no-adapter)
    /// path.
    pub baseline_tokens: usize,
    /// Client-observed completed tokens per route, sorted by name
    /// (`"-"` = baseline).  Present whether or not the scrape worked.
    pub tokens_by_route: Vec<(String, usize)>,
    /// Completed load->unload cycles the churn thread managed mid-run
    /// (0 without `churn_adapter`).
    pub churn_cycles: usize,
    /// Mid-run stats polls in epoch order (empty when `sample_ms` = 0 or
    /// every poll failed).
    pub samples: Vec<LoadSample>,
    /// Requests that ended in an `overloaded` rejection after retries
    /// were exhausted.
    pub rejected: usize,
    /// Requests that hit a deadline: admission-time `deadline` error
    /// frames plus streams finished with `"finish":"deadline"` (the
    /// latter also count as completed — they carry tokens).
    pub deadline: usize,
    /// Total re-attempts across all requests (overload backoff +
    /// transport reconnects).
    pub retried: usize,
    /// Requests that ended in a non-retryable error or exhausted
    /// transport retries.
    pub failed: usize,
    /// Session continuations that completed (out of `sessions` started).
    pub sessions_resumed: usize,
    /// Time-to-first-token of the session continuations — how long a
    /// rejoining client waits for its first new token.
    pub resume_latency: LatencySummary,
    /// Continuations that re-prefilled NOTHING: the done frame's
    /// `shared_prefix_tokens` covered every prompt position but the one
    /// the first decode step consumes.
    pub resume_zero_prefill: usize,
}

impl LoadReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / self.wall_secs
    }

    /// Peak sampled batch size (active sequences); 0 without sampling.
    pub fn batch_peak(&self) -> usize {
        self.samples.iter().map(|s| s.active).max().unwrap_or(0)
    }

    /// Median sampled batch size; 0 without sampling.
    pub fn batch_p50(&self) -> usize {
        if self.samples.is_empty() {
            return 0;
        }
        let mut v: Vec<usize> = self.samples.iter().map(|s| s.active).collect();
        v.sort_unstable();
        v[v.len() / 2]
    }

    /// Peak sampled KV occupancy (resident / total blocks), in [0, 1].
    pub fn kv_occupancy_peak(&self) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.kv_blocks_total > 0)
            .map(|s| s.kv_resident_blocks as f64 / s.kv_blocks_total as f64)
            .fold(0.0, f64::max)
    }
}

/// One client thread's terminal accounting: every request it owned
/// landed in exactly one of completed/rejected/deadline/failed (streams
/// finished with `"finish":"deadline"` count in both `records` and
/// `deadline`).
#[derive(Default)]
struct ClientStats {
    records: Vec<ReqRecord>,
    rejected: usize,
    deadline: usize,
    retried: usize,
    failed: usize,
}

/// Outcome of one attempt at one request.
enum Attempt {
    /// Stream completed; bool = it finished with `"finish":"deadline"`.
    Done(ReqRecord, bool),
    /// Admission-time `deadline` rejection (terminal, no retry).
    Deadline,
    /// `overloaded` rejection; carries the server's `retry_after_ms`.
    Overloaded(u64),
    /// Transport failure (send/read error, timeout, connection closed):
    /// reconnect and retry.
    Transport,
    /// Non-retryable failure (protocol violation, `bad_request`, ...).
    Fatal(String),
}

fn connect(addr: &str, timeout_ms: u64) -> Option<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr).ok()?;
    if timeout_ms > 0 {
        let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(timeout_ms)));
    }
    let writer = stream.try_clone().ok()?;
    Some((writer, BufReader::new(stream)))
}

/// Jittered exponential backoff before attempt `attempt` (1-based).
fn backoff(attempt: usize, extra_ms: u64, rng: &mut Rng) {
    let base = 10u64.saturating_mul(1 << attempt.min(6)).min(500);
    let jitter = rng.below(16) as u64;
    std::thread::sleep(std::time::Duration::from_millis(base + jitter + extra_ms));
}

/// Send one request line and consume its stream to a terminal frame.
fn stream_one(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
    id: &str,
    adapter: Option<&str>,
    epoch: Instant,
) -> Attempt {
    let sent_at = epoch.elapsed().as_secs_f64();
    if writer.write_all(line.as_bytes()).is_err() {
        return Attempt::Transport;
    }
    let mut first_token_at = None;
    let mut streamed = 0usize;
    let mut next_index = 0usize;
    loop {
        let mut resp = String::new();
        match reader.read_line(&mut resp) {
            Ok(0) | Err(_) => return Attempt::Transport,
            Ok(_) => {}
        }
        let Ok(j) = Json::parse(resp.trim()) else {
            return Attempt::Fatal(format!("{id}: unparseable frame: {resp}"));
        };
        let frame_id = j.get("id").and_then(Json::as_str);
        let event = j.get("event").and_then(Json::as_str);
        if frame_id != Some(id) {
            // Connection-scoped error frames arrive with an empty id
            // (engine failure, line-too-long, ...); anything else for a
            // foreign id is a routing bug.
            if event == Some("error") {
                let msg = j.get("message").and_then(Json::as_str).unwrap_or("?");
                return Attempt::Fatal(format!("server error: {msg}"));
            }
            if event == Some("drain") {
                continue; // drain ack from a shared connection; not ours
            }
            return Attempt::Fatal(format!("frame for unexpected id: {resp}"));
        }
        match event {
            Some("token") => {
                let idx = j.get("index").and_then(Json::as_i64).unwrap_or(-1);
                if idx != next_index as i64 {
                    return Attempt::Fatal(format!(
                        "{id}: out-of-order token index {idx}, want {next_index}"
                    ));
                }
                next_index += 1;
                streamed += 1;
                if first_token_at.is_none() {
                    first_token_at = Some(epoch.elapsed().as_secs_f64());
                }
            }
            Some("done") => {
                let tokens: Vec<i64> = j
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_i64).collect())
                    .unwrap_or_default();
                if tokens.len() != streamed {
                    return Attempt::Fatal(format!(
                        "{id}: done carries {} tokens but {streamed} were streamed",
                        tokens.len()
                    ));
                }
                let deadline_finish =
                    j.get("finish").and_then(Json::as_str) == Some("deadline");
                let shared = j
                    .get("stats")
                    .and_then(|s| s.get("shared_prefix_tokens"))
                    .and_then(Json::as_i64)
                    .unwrap_or(0)
                    .max(0) as usize;
                return Attempt::Done(
                    ReqRecord {
                        id: id.to_string(),
                        sent_at,
                        first_token_at: first_token_at.unwrap_or(sent_at),
                        done_at: epoch.elapsed().as_secs_f64(),
                        n_tokens: streamed,
                        tokens,
                        adapter: adapter.map(String::from),
                        shared_prefix_tokens: shared,
                    },
                    deadline_finish,
                );
            }
            Some("error") => {
                let code = j.get("code").and_then(Json::as_str).unwrap_or("");
                match code {
                    "overloaded" => {
                        let after = j
                            .get("retry_after_ms")
                            .and_then(Json::as_i64)
                            .unwrap_or(0)
                            .max(0) as u64;
                        return Attempt::Overloaded(after);
                    }
                    "deadline" => return Attempt::Deadline,
                    "unavailable" => return Attempt::Transport,
                    _ => {
                        let msg = j.get("message").and_then(Json::as_str).unwrap_or("?");
                        return Attempt::Fatal(format!("{id}: server error: {msg}"));
                    }
                }
            }
            _ => return Attempt::Fatal(format!("unknown frame: {resp}")),
        }
    }
}

fn run_client(addr: &str, client: usize, o: &LoadOptions, epoch: Instant) -> ClientStats {
    let mut rng = Rng::new(o.seed ^ (client as u64).wrapping_mul(0xA5A5_A5A5_A5A5_A5A5).max(1));
    let mut st = ClientStats::default();
    let mut conn = connect(addr, o.request_timeout_ms);

    // Every client derives the SAME shared prefix from the run seed
    // alone, so all requests agree on it token for token.
    let n_common = o.common_prefix.min(o.prompt_len);
    let mut crng = Rng::new(o.seed ^ 0xC0FF_EE00_0000_0001);
    let common: Vec<usize> = (0..n_common).map(|_| crng.below(o.vocab)).collect();

    // Round-robin route for THIS client ("-" or empty mix = baseline).
    let adapter = route_for(o, client);

    for ri in 0..o.requests_per_client {
        let id = format!("c{client}-r{ri}");
        let prompt: Vec<String> = common
            .iter()
            .copied()
            .chain((0..o.prompt_len - n_common).map(|_| rng.below(o.vocab)))
            .map(|t| t.to_string())
            .collect();
        let sampling = if o.temperature > 0.0 {
            format!(
                ",\"temperature\":{},\"seed\":{}",
                o.temperature,
                o.seed ^ (client * 1000 + ri) as u64
            )
        } else {
            String::new()
        };
        let route = adapter
            .map(|a| format!(",\"adapter\":\"{a}\""))
            .unwrap_or_default();
        let deadline = if o.deadline_ms > 0 {
            format!(",\"deadline_ms\":{}", o.deadline_ms)
        } else {
            String::new()
        };
        let line = format!(
            "{{\"id\":\"{id}\",\"prompt\":[{}],\"max_new\":{}{sampling}{route}{deadline}}}\n",
            prompt.join(","),
            o.max_new
        );

        if let Some(rec) = drive_request(addr, &mut conn, &line, &id, adapter, o, epoch, &mut rng, &mut st) {
            st.records.push(rec);
        }
    }

    st
}

/// Drive one request line to a terminal outcome under the shared
/// retry/backoff policy.  Non-completion terminals are charged to `st`'s
/// buckets; a completed stream is returned for the caller to record.
#[allow(clippy::too_many_arguments)]
fn drive_request(
    addr: &str,
    conn: &mut Option<(TcpStream, BufReader<TcpStream>)>,
    line: &str,
    id: &str,
    adapter: Option<&str>,
    o: &LoadOptions,
    epoch: Instant,
    rng: &mut Rng,
    st: &mut ClientStats,
) -> Option<ReqRecord> {
    let mut attempts = 0usize;
    loop {
        let Some((writer, reader)) = conn.as_mut() else {
            // (Re)connect with backoff; the request rides the retry
            // budget with the transport.
            if attempts >= o.max_retries {
                st.failed += 1;
                return None;
            }
            attempts += 1;
            st.retried += 1;
            backoff(attempts, 0, rng);
            *conn = connect(addr, o.request_timeout_ms);
            continue;
        };
        match stream_one(writer, reader, line, id, adapter, epoch) {
            Attempt::Done(rec, deadline_finish) => {
                if deadline_finish {
                    st.deadline += 1;
                }
                return Some(rec);
            }
            Attempt::Deadline => {
                st.deadline += 1;
                return None;
            }
            Attempt::Overloaded(after_ms) => {
                if attempts >= o.max_retries {
                    st.rejected += 1;
                    return None;
                }
                attempts += 1;
                st.retried += 1;
                backoff(attempts, after_ms, rng);
            }
            Attempt::Transport => {
                *conn = None; // rebuild on the next spin
                if attempts >= o.max_retries {
                    st.failed += 1;
                    return None;
                }
                // the reconnect arm above charges the retry
            }
            Attempt::Fatal(msg) => {
                eprintln!("bench-serve: {msg}");
                st.failed += 1;
                return None;
            }
        }
    }
}

/// One session client's outcome: its two requests' terminal accounting
/// plus the continuation's resume observations.
#[derive(Default)]
struct SessionStats {
    st: ClientStats,
    /// TTFT of the continuation request (None if it never completed).
    resume_ttft: Option<f64>,
    /// The continuation reused every reusable position (zero re-prefill).
    zero_prefill: bool,
}

/// One session client: open a `"session"`-tagged stream, consume half
/// the token budget to completion, hang up, wait `rejoin_ms`, reconnect
/// and continue the session with the prompt extended by every received
/// token.  Against a `--kv-spill` server the continuation resumes from
/// the parked pages instead of re-prefilling.
fn run_session_client(addr: &str, idx: usize, o: &LoadOptions, epoch: Instant) -> SessionStats {
    let mut rng = Rng::new(o.seed ^ (idx as u64 ^ 0x5E55).wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1));
    let mut out = SessionStats::default();
    let session = format!("sess-{idx}");
    let first_new = (o.max_new / 2).max(1);
    let second_new = o.max_new.saturating_sub(first_new).max(1);
    let prompt: Vec<i64> = (0..o.prompt_len.max(2)).map(|_| rng.below(o.vocab) as i64).collect();
    let join = |toks: &[i64]| toks.iter().map(i64::to_string).collect::<Vec<_>>().join(",");

    // Leg A: open the session and stream its first half to completion.
    let id_a = format!("s{idx}-a");
    let line_a = format!(
        "{{\"id\":\"{id_a}\",\"prompt\":[{}],\"max_new\":{first_new},\"session\":\"{session}\"}}\n",
        join(&prompt)
    );
    let mut conn = connect(addr, o.request_timeout_ms);
    let Some(rec_a) =
        drive_request(addr, &mut conn, &line_a, &id_a, None, o, epoch, &mut rng, &mut out.st)
    else {
        // The continuation can never run; charge it so every request
        // stays terminally accounted.
        out.st.failed += 1;
        return out;
    };

    // Hang up: dropping both socket halves closes the connection, which
    // parks the (already finished) session server-side.
    conn = None;
    std::thread::sleep(std::time::Duration::from_millis(o.rejoin_ms));

    // Leg B: rejoin and continue from the full token history.
    let mut prompt2 = prompt;
    prompt2.extend(rec_a.tokens.iter().copied());
    out.st.records.push(rec_a);
    let id_b = format!("s{idx}-b");
    let line_b = format!(
        "{{\"id\":\"{id_b}\",\"prompt\":[{}],\"max_new\":{second_new},\"session\":\"{session}\"}}\n",
        join(&prompt2)
    );
    conn = connect(addr, o.request_timeout_ms);
    if let Some(rec) =
        drive_request(addr, &mut conn, &line_b, &id_b, None, o, epoch, &mut rng, &mut out.st)
    {
        out.resume_ttft = Some(rec.first_token_at - rec.sent_at);
        // The first decode step consumes the final prompt position, so
        // prompt2.len() - 1 reused positions means nothing re-prefilled.
        out.zero_prefill = rec.shared_prefix_tokens + 1 >= prompt2.len();
        out.st.records.push(rec);
    }
    out
}

/// Which adapter this client routes to, if any.
fn route_for(o: &LoadOptions, client: usize) -> Option<&str> {
    if o.adapter_mix.is_empty() {
        return None;
    }
    let a = o.adapter_mix[client % o.adapter_mix.len()].as_str();
    (a != "-").then_some(a)
}

/// Send one `{"cmd":"adapter"}` line and read the single reply frame.
/// `Ok(true)` = acked with an adapter event, `Ok(false)` = the server
/// answered with an error frame (tolerated: e.g. a reload racing a
/// still-draining unload); `Err` = transport/parse failure.
fn adapter_cmd(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    body: &str,
) -> Result<bool> {
    writer
        .write_all(body.as_bytes())
        .map_err(|e| Error::io(format!("send adapter cmd: {e}")))?;
    let mut line = String::new();
    let n = reader
        .read_line(&mut line)
        .map_err(|e| Error::io(format!("read adapter ack: {e}")))?;
    if n == 0 {
        return Err(Error::io("server closed connection on adapter cmd"));
    }
    let j = Json::parse(line.trim())?;
    match j.get("event").and_then(Json::as_str) {
        Some("adapter") => Ok(true),
        Some("error") => Ok(false),
        _ => Err(Error::config(format!("unexpected adapter ack: {line}"))),
    }
}

/// The churn loop: load `name` from `path`, dwell briefly, unload, until
/// `done`.  Returns the number of completed load+unload cycles.
fn run_churn(
    addr: &str,
    name: &str,
    path: &str,
    done: &std::sync::atomic::AtomicBool,
) -> Result<usize> {
    use std::sync::atomic::Ordering;
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::io(format!("churn connect {addr}: {e}")))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| Error::io(format!("clone socket: {e}")))?;
    let mut reader = BufReader::new(stream);
    let load = format!(
        "{{\"cmd\":\"adapter\",\"op\":\"load\",\"name\":\"{name}\",\"path\":\"{path}\"}}\n"
    );
    let unload = format!("{{\"cmd\":\"adapter\",\"op\":\"unload\",\"name\":\"{name}\"}}\n");
    let mut cycles = 0usize;
    while !done.load(Ordering::Relaxed) {
        let loaded = adapter_cmd(&mut writer, &mut reader, &load)?;
        std::thread::sleep(std::time::Duration::from_millis(15));
        let unloaded = adapter_cmd(&mut writer, &mut reader, &unload)?;
        if loaded && unloaded {
            cycles += 1;
        }
        std::thread::sleep(std::time::Duration::from_millis(15));
    }
    // Leave the registry as we found it — a final best-effort unload in
    // case the loop exited between a load and its unload (nothing routes
    // to the churn adapter, so an unload never defers).
    let _ = adapter_cmd(&mut writer, &mut reader, &unload);
    Ok(cycles)
}

/// The sampler loop: poll the stats endpoint on its own connection every
/// `interval_ms` until `done`.  Failed polls are skipped (e.g. the first
/// poll racing the server boot) — the series just has a gap.
fn run_sampler(
    addr: &str,
    interval_ms: u64,
    epoch: Instant,
    done: &std::sync::atomic::AtomicBool,
) -> Vec<LoadSample> {
    use std::sync::atomic::Ordering;
    let mut samples = Vec::new();
    let interval = std::time::Duration::from_millis(interval_ms.max(1));
    while !done.load(Ordering::Relaxed) {
        std::thread::sleep(interval);
        if done.load(Ordering::Relaxed) {
            break;
        }
        if let Ok(s) = fetch_stats(addr) {
            samples.push(LoadSample {
                t_secs: epoch.elapsed().as_secs_f64(),
                active: s.active,
                pending: s.pending,
                kv_resident_blocks: s.kv.resident_blocks,
                kv_blocks_total: s.kv.blocks_total,
            });
        }
    }
    samples
}

/// Peak number of intervals `[first_token, done)` that overlap.
fn peak_overlap(records: &[ReqRecord]) -> usize {
    let mut edges: Vec<(f64, i32)> = Vec::with_capacity(records.len() * 2);
    for r in records {
        edges.push((r.first_token_at, 1));
        edges.push((r.done_at, -1));
    }
    // ends sort before starts at the same instant (half-open intervals)
    edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in edges {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Fire the load and gather the report.  Request-level failures land in
/// the report's terminal buckets (`rejected`/`deadline`/`failed`)
/// instead of aborting the run; only a malformed load shape errors.
pub fn run_load(o: &LoadOptions) -> Result<LoadReport> {
    if o.clients == 0 || o.requests_per_client == 0 {
        return Err(Error::config("bench-serve wants clients >= 1 and requests >= 1"));
    }
    let epoch = Instant::now();
    let churn_done = std::sync::atomic::AtomicBool::new(false);
    let sampler_done = std::sync::atomic::AtomicBool::new(false);
    type ScopeOut = (Vec<ClientStats>, Vec<SessionStats>, usize, Vec<LoadSample>);
    let (results, session_results, churn_cycles, samples): ScopeOut =
        std::thread::scope(|s| {
            let churn = o.churn_adapter.as_ref().map(|(name, path)| {
                let done = &churn_done;
                s.spawn(move || run_churn(&o.addr, name, path, done))
            });
            let sampler = (o.sample_ms > 0).then(|| {
                let done = &sampler_done;
                s.spawn(move || run_sampler(&o.addr, o.sample_ms, epoch, done))
            });
            let handles: Vec<_> = (0..o.clients)
                .map(|ci| s.spawn(move || run_client(&o.addr, ci, o, epoch)))
                .collect();
            let session_handles: Vec<_> = (0..o.sessions)
                .map(|si| s.spawn(move || run_session_client(&o.addr, si, o, epoch)))
                .collect();
            let results = handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(st) => st,
                    Err(_) => {
                        eprintln!("bench-serve: load client thread panicked");
                        ClientStats {
                            failed: o.requests_per_client,
                            ..ClientStats::default()
                        }
                    }
                })
                .collect();
            let session_results = session_handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(ss) => ss,
                    Err(_) => {
                        eprintln!("bench-serve: session client thread panicked");
                        SessionStats {
                            st: ClientStats { failed: 2, ..ClientStats::default() },
                            ..SessionStats::default()
                        }
                    }
                })
                .collect();
            churn_done.store(true, std::sync::atomic::Ordering::Relaxed);
            sampler_done.store(true, std::sync::atomic::Ordering::Relaxed);
            let cycles = match churn {
                Some(h) => match h.join() {
                    Ok(Ok(n)) => n,
                    Ok(Err(e)) => {
                        eprintln!("bench-serve: adapter churn thread failed: {e}");
                        0
                    }
                    Err(_) => {
                        eprintln!("bench-serve: adapter churn thread panicked");
                        0
                    }
                },
                None => 0,
            };
            let samples = match sampler {
                Some(h) => h.join().unwrap_or_default(),
                None => Vec::new(),
            };
            (results, session_results, cycles, samples)
        });
    let wall_secs = epoch.elapsed().as_secs_f64();

    // Scrape KV memory + speculative stats BEFORE any shutdown: the
    // peaks and counters describe the load we just generated.
    let stats = fetch_stats(&o.addr).ok();

    if o.shutdown_after {
        // After every client is done: a throwaway connection that only
        // asks the server to stop.
        if let Ok(mut s) = TcpStream::connect(&o.addr) {
            let _ = s.write_all(b"{\"cmd\":\"shutdown\"}\n");
        }
    }

    let mut records = Vec::new();
    let (mut rejected, mut deadline, mut retried, mut failed) = (0usize, 0usize, 0usize, 0usize);
    let mut resume_ttfts = Vec::new();
    let mut resume_zero_prefill = 0usize;
    let session_stats = session_results.into_iter().map(|ss| {
        if ss.resume_ttft.is_some() {
            resume_ttfts.push(ss.resume_ttft.unwrap());
            resume_zero_prefill += ss.zero_prefill as usize;
        }
        ss.st
    });
    for st in results.into_iter().chain(session_stats) {
        records.extend(st.records);
        rejected += st.rejected;
        deadline += st.deadline;
        retried += st.retried;
        failed += st.failed;
    }
    if let Some(path) = &o.transcript {
        write_transcript(path, &records)?;
    }
    // Every session client owns exactly two requests (a leg that never
    // ran because its predecessor failed is charged as failed).
    let requests = o.clients * o.requests_per_client + o.sessions * 2;
    let total_tokens: usize = records.iter().map(|r| r.n_tokens).sum();
    let ttft: Vec<f64> = records.iter().map(|r| r.first_token_at - r.sent_at).collect();
    let total: Vec<f64> = records.iter().map(|r| r.done_at - r.sent_at).collect();
    let mut by_route = std::collections::BTreeMap::<String, usize>::new();
    for r in &records {
        let key = r.adapter.clone().unwrap_or_else(|| "-".to_string());
        *by_route.entry(key).or_insert(0) += r.n_tokens;
    }
    Ok(LoadReport {
        requests,
        completed: records.len(),
        total_tokens,
        wall_secs,
        ttft: LatencySummary::from_secs(ttft),
        total: LatencySummary::from_secs(total),
        peak_concurrent_streams: peak_overlap(&records),
        kv: stats.as_ref().map(|s| s.kv),
        spec: stats.as_ref().and_then(|s| s.spec),
        tier: stats.as_ref().and_then(|s| s.tier),
        adapters: stats.as_ref().map(|s| s.adapters.clone()).unwrap_or_default(),
        baseline_tokens: stats.as_ref().map(|s| s.baseline_tokens).unwrap_or(0),
        tokens_by_route: by_route.into_iter().collect(),
        churn_cycles,
        samples,
        rejected,
        deadline,
        retried,
        failed,
        sessions_resumed: resume_ttfts.len(),
        resume_latency: LatencySummary::from_secs(resume_ttfts),
        resume_zero_prefill,
    })
}

/// One sorted `id t1 t2 ...` line per completed request — identical
/// load shapes against deterministic servers produce byte-identical
/// files regardless of scheduling or speculation.
fn write_transcript(path: &str, records: &[ReqRecord]) -> Result<()> {
    let mut lines: Vec<String> = records
        .iter()
        .map(|r| {
            let toks: Vec<String> = r.tokens.iter().map(i64::to_string).collect();
            format!("{} {}", r.id, toks.join(" "))
        })
        .collect();
    lines.sort();
    std::fs::write(path, lines.join("\n") + "\n")
        .map_err(|e| Error::io(format!("write transcript {path}: {e}")))
}

/// One-shot `{"cmd":"stats"}` round trip on a fresh connection.
pub fn fetch_stats(addr: &str) -> Result<StatsSnapshot> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::io(format!("connect {addr}: {e}")))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| Error::io(format!("clone socket: {e}")))?;
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"cmd\":\"stats\"}\n")
        .map_err(|e| Error::io(format!("send stats cmd: {e}")))?;
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .map_err(|e| Error::io(format!("read stats frame: {e}")))?;
    let j = Json::parse(line.trim())?;
    if j.get("event").and_then(Json::as_str) != Some("stats") {
        return Err(Error::config(format!("expected a stats frame, got: {line}")));
    }
    let kv = j
        .get("kv")
        .ok_or_else(|| Error::config("stats frame lacks a 'kv' object"))?;
    let field = |name: &str| kv.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
    let kv = KvSnapshot {
        block_size: field("block_size"),
        blocks_total: field("blocks_total"),
        resident_blocks: field("resident_blocks"),
        shared_blocks: field("shared_blocks"),
        peak_resident_blocks: field("peak_resident_blocks"),
        peak_shared_blocks: field("peak_shared_blocks"),
        block_bytes: field("block_bytes"),
        peak_resident_bytes: field("peak_resident_bytes"),
        kv_bits: field("kv_bits"),
        f32_block_bytes: field("f32_block_bytes"),
    };
    let spec = j.get("spec").map(|sj| {
        let f = |name: &str| sj.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
        SpecSnapshot {
            k: f("k"),
            proposed: f("proposed"),
            accepted: f("accepted"),
            cycles: f("cycles"),
            fallbacks: f("fallbacks"),
            draft_peak_resident_blocks: sj
                .get("draft_kv")
                .and_then(|d| d.get("peak_resident_blocks"))
                .and_then(Json::as_i64)
                .unwrap_or(0)
                .max(0) as usize,
        }
    });
    let tier = j.get("tier").map(|tj| {
        let f = |name: &str| tj.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
        TierSnapshot {
            spilled_blocks: f("spilled_blocks"),
            spilled_bytes: f("spilled_bytes"),
            slots_resident: f("slots_resident"),
            slots_total: f("slots_total"),
            preemptions: f("preemptions"),
            resumes: f("resumes"),
            suspended: f("suspended"),
            block_restores: f("block_restores"),
            restore_failures: f("restore_failures"),
            sessions_stored: f("sessions_stored"),
            session_resumes: f("session_resumes"),
            prefix_pages: f("prefix_pages"),
            prefix_hits: f("prefix_hits"),
            prefix_misses: f("prefix_misses"),
            promotes: f("promotes"),
        }
    });
    let adapters = j
        .get("adapters")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .map(|a| {
                    let f =
                        |n: &str| a.get(n).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
                    AdapterSnapshot {
                        name: a.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                        rank: f("rank"),
                        n_adapted: f("n_adapted"),
                        resident_bytes: f("resident_bytes"),
                        refs: f("refs"),
                        tokens: f("tokens"),
                        draining: a.get("draining").and_then(Json::as_bool).unwrap_or(false),
                        delta_overhead: a
                            .get("delta_overhead")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    }
                })
                .collect()
        })
        .unwrap_or_default();
    let baseline_tokens =
        j.get("baseline_tokens").and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
    let top = |name: &str| j.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
    Ok(StatsSnapshot {
        kv,
        spec,
        tier,
        adapters,
        baseline_tokens,
        active: top("active"),
        pending: top("pending"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_counts_concurrent_intervals() {
        let r = |a: f64, b: f64| ReqRecord {
            id: String::new(),
            sent_at: a,
            first_token_at: a,
            done_at: b,
            n_tokens: 1,
            tokens: vec![0],
            adapter: None,
            shared_prefix_tokens: 0,
        };
        // three overlapping, one disjoint
        let recs = vec![r(0.0, 1.0), r(0.2, 0.8), r(0.5, 1.5), r(2.0, 3.0)];
        assert_eq!(peak_overlap(&recs), 3);
        // back-to-back half-open intervals never overlap
        let recs = vec![r(0.0, 1.0), r(1.0, 2.0)];
        assert_eq!(peak_overlap(&recs), 1);
        assert_eq!(peak_overlap(&[]), 0);
    }

    #[test]
    fn adapter_mix_round_robins_clients() {
        let mut o = LoadOptions {
            addr: String::new(),
            clients: 5,
            requests_per_client: 1,
            prompt_len: 4,
            max_new: 4,
            vocab: 16,
            common_prefix: 0,
            temperature: 0.0,
            seed: 1,
            shutdown_after: false,
            transcript: None,
            adapter_mix: vec!["a".into(), "-".into(), "b".into()],
            churn_adapter: None,
            sample_ms: 0,
            deadline_ms: 0,
            request_timeout_ms: 0,
            max_retries: 0,
            sessions: 0,
            rejoin_ms: 0,
        };
        assert_eq!(route_for(&o, 0), Some("a"));
        assert_eq!(route_for(&o, 1), None); // "-" = baseline
        assert_eq!(route_for(&o, 2), Some("b"));
        assert_eq!(route_for(&o, 3), Some("a")); // wraps round-robin
        o.adapter_mix.clear();
        assert_eq!(route_for(&o, 0), None);
    }

    #[test]
    fn tier_prefix_hit_rate_handles_zero_lookups() {
        let mut t = TierSnapshot::default();
        assert_eq!(t.prefix_hit_rate(), 0.0, "no lookups must not read as a perfect rate");
        t.prefix_hits = 3;
        t.prefix_misses = 1;
        assert!((t.prefix_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn sample_summaries_cover_peak_median_occupancy() {
        let sample = |active: usize, resident: usize| LoadSample {
            t_secs: 0.0,
            active,
            pending: 0,
            kv_resident_blocks: resident,
            kv_blocks_total: 100,
        };
        let mut r = LoadReport {
            requests: 0,
            completed: 0,
            total_tokens: 0,
            wall_secs: 1.0,
            ttft: LatencySummary::from_secs(vec![]),
            total: LatencySummary::from_secs(vec![]),
            peak_concurrent_streams: 0,
            kv: None,
            spec: None,
            tier: None,
            adapters: Vec::new(),
            baseline_tokens: 0,
            tokens_by_route: Vec::new(),
            churn_cycles: 0,
            samples: vec![sample(2, 10), sample(7, 80), sample(4, 40)],
            rejected: 0,
            deadline: 0,
            retried: 0,
            failed: 0,
            sessions_resumed: 0,
            resume_latency: LatencySummary::from_secs(vec![]),
            resume_zero_prefill: 0,
        };
        assert_eq!(r.batch_peak(), 7);
        assert_eq!(r.batch_p50(), 4);
        assert!((r.kv_occupancy_peak() - 0.8).abs() < 1e-12);
        r.samples.clear();
        assert_eq!(r.batch_peak(), 0);
        assert_eq!(r.batch_p50(), 0);
        assert_eq!(r.kv_occupancy_peak(), 0.0);
    }
}
