//! `repro bench-serve`: a concurrent load generator for the line
//! protocol.
//!
//! Spawns `clients` threads, each holding one connection and issuing
//! `requests_per_client` streaming requests back to back; records
//! time-to-first-token and total latency per request against a shared
//! epoch, validates the streamed frames (in-order `index`es, `done`
//! token count matching the stream), and reports throughput plus latency
//! percentiles and the peak number of concurrently streaming requests —
//! the observable proof that continuous batching interleaves mid-flight
//! admissions.
//!
//! `common_prefix > 0` makes the first N prompt tokens identical across
//! every request (all clients derive them from the same seed), which
//! drives the server's prompt-prefix sharing; after the load drains, one
//! extra connection sends `{"cmd":"stats"}` and the scraped KV block
//! accounting (peak resident / peak shared pages) rides on the report —
//! that is where `repro bench-serve`'s `BENCH_serve.json` gets its
//! serving-memory numbers.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::metrics::latency::LatencySummary;
use crate::serve::json::Json;
use crate::tensor::Rng;

/// Load shape for one `bench-serve` run.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    pub addr: String,
    pub clients: usize,
    pub requests_per_client: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    /// Prompts draw uniform tokens from [0, vocab).
    pub vocab: usize,
    /// First `common_prefix` tokens of EVERY prompt are identical across
    /// all clients/requests (capped at `prompt_len`) — exercises the
    /// server's KV prefix sharing.
    pub common_prefix: usize,
    /// 0 = greedy; otherwise seeded sampling at this temperature.
    pub temperature: f32,
    pub seed: u64,
    /// Send `{"cmd":"shutdown"}` after the run (CI teardown).
    pub shutdown_after: bool,
    /// Write each request's generated tokens (one sorted `id t1 t2 ...`
    /// line per request) to this path — byte-comparable across runs, the
    /// CI proof that `--speculate` changes no output bits.
    pub transcript: Option<String>,
}

/// Per-request observation (offsets from the run epoch, seconds).
#[derive(Clone, Debug)]
struct ReqRecord {
    id: String,
    sent_at: f64,
    first_token_at: f64,
    done_at: f64,
    n_tokens: usize,
    tokens: Vec<i64>,
}

/// KV block accounting scraped from the server's stats frame after the
/// load drained (current counts are near-idle by then; the peaks carry
/// the run's memory story).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvSnapshot {
    pub block_size: usize,
    pub blocks_total: usize,
    pub resident_blocks: usize,
    pub shared_blocks: usize,
    pub peak_resident_blocks: usize,
    pub peak_shared_blocks: usize,
    pub block_bytes: usize,
    pub peak_resident_bytes: usize,
}

/// Speculative-decoding counters scraped from the stats frame's `spec`
/// object (absent when the server does not speculate).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecSnapshot {
    pub k: usize,
    pub proposed: usize,
    pub accepted: usize,
    pub cycles: usize,
    pub fallbacks: usize,
    pub draft_peak_resident_blocks: usize,
}

impl SpecSnapshot {
    /// Accepted fraction of proposed draft tokens; 0.0 when nothing was
    /// proposed (total fallback must not read as perfect speculation).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }
}

/// One `{"cmd":"stats"}` round trip's worth of server accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    pub kv: KvSnapshot,
    pub spec: Option<SpecSnapshot>,
}

/// Aggregated results of one load run.
pub struct LoadReport {
    pub requests: usize,
    pub completed: usize,
    pub total_tokens: usize,
    pub wall_secs: f64,
    pub ttft: LatencySummary,
    pub total: LatencySummary,
    /// Peak number of requests simultaneously between first token and
    /// done — >= 2 demonstrates interleaved (continuously batched)
    /// streams.
    pub peak_concurrent_streams: usize,
    /// Post-run KV memory scrape (`None` if the server predates the
    /// stats command or the scrape failed).
    pub kv: Option<KvSnapshot>,
    /// Post-run speculative-decoding scrape (`None` when the server does
    /// not speculate or the scrape failed).
    pub spec: Option<SpecSnapshot>,
}

impl LoadReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / self.wall_secs
    }
}

fn run_client(
    addr: &str,
    client: usize,
    o: &LoadOptions,
    epoch: Instant,
) -> Result<Vec<ReqRecord>> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::io(format!("connect {addr}: {e}")))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| Error::io(format!("clone socket: {e}")))?;
    let mut reader = BufReader::new(stream);
    let mut rng = Rng::new(o.seed ^ (client as u64).wrapping_mul(0xA5A5_A5A5_A5A5_A5A5).max(1));
    let mut records = Vec::with_capacity(o.requests_per_client);

    // Every client derives the SAME shared prefix from the run seed
    // alone, so all requests agree on it token for token.
    let n_common = o.common_prefix.min(o.prompt_len);
    let mut crng = Rng::new(o.seed ^ 0xC0FF_EE00_0000_0001);
    let common: Vec<usize> = (0..n_common).map(|_| crng.below(o.vocab)).collect();

    for ri in 0..o.requests_per_client {
        let id = format!("c{client}-r{ri}");
        let prompt: Vec<String> = common
            .iter()
            .copied()
            .chain((0..o.prompt_len - n_common).map(|_| rng.below(o.vocab)))
            .map(|t| t.to_string())
            .collect();
        let sampling = if o.temperature > 0.0 {
            format!(
                ",\"temperature\":{},\"seed\":{}",
                o.temperature,
                o.seed ^ (client * 1000 + ri) as u64
            )
        } else {
            String::new()
        };
        let line = format!(
            "{{\"id\":\"{id}\",\"prompt\":[{}],\"max_new\":{}{sampling}}}\n",
            prompt.join(","),
            o.max_new
        );
        let sent_at = epoch.elapsed().as_secs_f64();
        writer
            .write_all(line.as_bytes())
            .map_err(|e| Error::io(format!("send request: {e}")))?;

        let mut first_token_at = None;
        let mut streamed = 0usize;
        let mut next_index = 0usize;
        let record = loop {
            let mut resp = String::new();
            let n = reader
                .read_line(&mut resp)
                .map_err(|e| Error::io(format!("read frame: {e}")))?;
            if n == 0 {
                return Err(Error::io("server closed connection mid-stream"));
            }
            let j = Json::parse(resp.trim())?;
            if j.get("id").and_then(Json::as_str) != Some(id.as_str()) {
                // engine-level failures are broadcast with an empty id;
                // surface the message instead of a routing error
                if j.get("event").and_then(Json::as_str) == Some("error") {
                    let msg = j.get("message").and_then(Json::as_str).unwrap_or("?");
                    return Err(Error::config(format!("server error: {msg}")));
                }
                return Err(Error::config(format!("frame for unexpected id: {resp}")));
            }
            match j.get("event").and_then(Json::as_str) {
                Some("token") => {
                    let idx = j.get("index").and_then(Json::as_i64).unwrap_or(-1);
                    if idx != next_index as i64 {
                        return Err(Error::config(format!(
                            "{id}: out-of-order token index {idx}, want {next_index}"
                        )));
                    }
                    next_index += 1;
                    streamed += 1;
                    if first_token_at.is_none() {
                        first_token_at = Some(epoch.elapsed().as_secs_f64());
                    }
                }
                Some("done") => {
                    let tokens: Vec<i64> = j
                        .get("tokens")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_i64).collect())
                        .unwrap_or_default();
                    if tokens.len() != streamed {
                        return Err(Error::config(format!(
                            "{id}: done carries {} tokens but {streamed} were streamed",
                            tokens.len()
                        )));
                    }
                    break ReqRecord {
                        id: id.clone(),
                        sent_at,
                        first_token_at: first_token_at.unwrap_or(sent_at),
                        done_at: epoch.elapsed().as_secs_f64(),
                        n_tokens: streamed,
                        tokens,
                    };
                }
                Some("error") => {
                    let msg = j.get("message").and_then(Json::as_str).unwrap_or("?");
                    return Err(Error::config(format!("{id}: server error: {msg}")));
                }
                _ => return Err(Error::config(format!("unknown frame: {resp}"))),
            }
        };
        records.push(record);
    }

    Ok(records)
}

/// Peak number of intervals `[first_token, done)` that overlap.
fn peak_overlap(records: &[ReqRecord]) -> usize {
    let mut edges: Vec<(f64, i32)> = Vec::with_capacity(records.len() * 2);
    for r in records {
        edges.push((r.first_token_at, 1));
        edges.push((r.done_at, -1));
    }
    // ends sort before starts at the same instant (half-open intervals)
    edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in edges {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Fire the load and gather the report.  Fails if any client errors or
/// any stream is left incomplete.
pub fn run_load(o: &LoadOptions) -> Result<LoadReport> {
    if o.clients == 0 || o.requests_per_client == 0 {
        return Err(Error::config("bench-serve wants clients >= 1 and requests >= 1"));
    }
    let epoch = Instant::now();
    let results: Vec<Result<Vec<ReqRecord>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..o.clients)
            .map(|ci| s.spawn(move || run_client(&o.addr, ci, o, epoch)))
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::io("load client thread panicked")),
            })
            .collect()
    });
    let wall_secs = epoch.elapsed().as_secs_f64();

    // Scrape KV memory + speculative stats BEFORE any shutdown: the
    // peaks and counters describe the load we just generated.
    let stats = fetch_stats(&o.addr).ok();

    if o.shutdown_after {
        // After every client is done: a throwaway connection that only
        // asks the server to stop.
        if let Ok(mut s) = TcpStream::connect(&o.addr) {
            let _ = s.write_all(b"{\"cmd\":\"shutdown\"}\n");
        }
    }

    let mut records = Vec::new();
    for r in results {
        records.extend(r?);
    }
    if let Some(path) = &o.transcript {
        write_transcript(path, &records)?;
    }
    let requests = o.clients * o.requests_per_client;
    let total_tokens: usize = records.iter().map(|r| r.n_tokens).sum();
    let ttft: Vec<f64> = records.iter().map(|r| r.first_token_at - r.sent_at).collect();
    let total: Vec<f64> = records.iter().map(|r| r.done_at - r.sent_at).collect();
    Ok(LoadReport {
        requests,
        completed: records.len(),
        total_tokens,
        wall_secs,
        ttft: LatencySummary::from_secs(ttft),
        total: LatencySummary::from_secs(total),
        peak_concurrent_streams: peak_overlap(&records),
        kv: stats.map(|s| s.kv),
        spec: stats.and_then(|s| s.spec),
    })
}

/// One sorted `id t1 t2 ...` line per completed request — identical
/// load shapes against deterministic servers produce byte-identical
/// files regardless of scheduling or speculation.
fn write_transcript(path: &str, records: &[ReqRecord]) -> Result<()> {
    let mut lines: Vec<String> = records
        .iter()
        .map(|r| {
            let toks: Vec<String> = r.tokens.iter().map(i64::to_string).collect();
            format!("{} {}", r.id, toks.join(" "))
        })
        .collect();
    lines.sort();
    std::fs::write(path, lines.join("\n") + "\n")
        .map_err(|e| Error::io(format!("write transcript {path}: {e}")))
}

/// One-shot `{"cmd":"stats"}` round trip on a fresh connection.
pub fn fetch_stats(addr: &str) -> Result<StatsSnapshot> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::io(format!("connect {addr}: {e}")))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| Error::io(format!("clone socket: {e}")))?;
    let mut reader = BufReader::new(stream);
    writer
        .write_all(b"{\"cmd\":\"stats\"}\n")
        .map_err(|e| Error::io(format!("send stats cmd: {e}")))?;
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .map_err(|e| Error::io(format!("read stats frame: {e}")))?;
    let j = Json::parse(line.trim())?;
    if j.get("event").and_then(Json::as_str) != Some("stats") {
        return Err(Error::config(format!("expected a stats frame, got: {line}")));
    }
    let kv = j
        .get("kv")
        .ok_or_else(|| Error::config("stats frame lacks a 'kv' object"))?;
    let field = |name: &str| kv.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
    let kv = KvSnapshot {
        block_size: field("block_size"),
        blocks_total: field("blocks_total"),
        resident_blocks: field("resident_blocks"),
        shared_blocks: field("shared_blocks"),
        peak_resident_blocks: field("peak_resident_blocks"),
        peak_shared_blocks: field("peak_shared_blocks"),
        block_bytes: field("block_bytes"),
        peak_resident_bytes: field("peak_resident_bytes"),
    };
    let spec = j.get("spec").map(|sj| {
        let f = |name: &str| sj.get(name).and_then(Json::as_i64).unwrap_or(0).max(0) as usize;
        SpecSnapshot {
            k: f("k"),
            proposed: f("proposed"),
            accepted: f("accepted"),
            cycles: f("cycles"),
            fallbacks: f("fallbacks"),
            draft_peak_resident_blocks: sj
                .get("draft_kv")
                .and_then(|d| d.get("peak_resident_blocks"))
                .and_then(Json::as_i64)
                .unwrap_or(0)
                .max(0) as usize,
        }
    });
    Ok(StatsSnapshot { kv, spec })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_counts_concurrent_intervals() {
        let r = |a: f64, b: f64| ReqRecord {
            id: String::new(),
            sent_at: a,
            first_token_at: a,
            done_at: b,
            n_tokens: 1,
            tokens: vec![0],
        };
        // three overlapping, one disjoint
        let recs = vec![r(0.0, 1.0), r(0.2, 0.8), r(0.5, 1.5), r(2.0, 3.0)];
        assert_eq!(peak_overlap(&recs), 3);
        // back-to-back half-open intervals never overlap
        let recs = vec![r(0.0, 1.0), r(1.0, 2.0)];
        assert_eq!(peak_overlap(&recs), 1);
        assert_eq!(peak_overlap(&[]), 0);
    }
}
