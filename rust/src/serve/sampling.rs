//! Seeded stochastic decoding: temperature / top-k / top-p next to
//! greedy argmax.
//!
//! Everything flows from the crate's deterministic `tensor::Rng`
//! (xorshift64*), so a `(params, seed)` pair replays the exact same token
//! stream — the property the reproducibility tests in `tests/serve.rs`
//! pin down.  NaN logits are excluded up front (see `infer::argmax` for
//! the matching greedy behavior), ties sort to the lowest index, and
//! degenerate rows fall back to token 0 instead of panicking.

use std::cmp::Ordering;

use crate::infer::argmax;
use crate::tensor::Rng;

/// Decoding controls for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0` means greedy argmax.
    pub temperature: f32,
    /// Keep only the k highest-probability tokens (0 = disabled).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest prefix of the sorted
    /// distribution with cumulative probability >= top_p (1.0 = disabled).
    pub top_p: f32,
    /// Seed of the per-request rng stream.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 17 }
    }
}

impl SamplingParams {
    /// Greedy decoding expressed as sampling params (temperature 0).
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, ..Default::default() }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Independent per-sequence rng stream for batched sampling: sequence `i`
/// of a request seeded `s` always draws from the same stream, regardless
/// of batch composition or decode path (cached vs recompute).
pub fn seq_rng(seed: u64, i: usize) -> Rng {
    Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

/// Draw one token from a logits row under `p`.  Deterministic given the
/// rng state; total on NaN/empty rows (falls back to greedy / token 0).
pub fn sample(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> usize {
    if p.is_greedy() {
        return argmax(logits);
    }
    let mut cand: Vec<(usize, f32)> = logits
        .iter()
        .copied()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .collect();
    if cand.is_empty() {
        return 0;
    }
    // Sort by logit descending, index ascending on ties (stable and
    // deterministic across runs).
    cand.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    if p.top_k > 0 && p.top_k < cand.len() {
        cand.truncate(p.top_k);
    }
    // Softmax over temperature-scaled logits, max-subtracted for
    // stability.  cand[0] holds the max because 1/temperature > 0.
    let inv_t = 1.0 / p.temperature;
    let mx = cand[0].1 * inv_t;
    if !mx.is_finite() {
        // +inf (or overflowed) top logit: the distribution degenerates to
        // a point mass on the best candidate.
        return cand[0].0;
    }
    if p.top_p <= 0.0 {
        // degenerate nucleus: the smallest prefix reaching any mass is
        // the single best candidate
        return cand[0].0;
    }
    let mut weights: Vec<f32> = cand.iter().map(|(_, v)| (v * inv_t - mx).exp()).collect();
    let mut total: f32 = weights.iter().sum();
    if p.top_p < 1.0 {
        let mut acc = 0.0f32;
        let mut keep = weights.len();
        for (i, w) in weights.iter().enumerate() {
            acc += w / total;
            if acc >= p.top_p {
                keep = i + 1;
                break;
            }
        }
        weights.truncate(keep);
        cand.truncate(keep);
        total = weights.iter().sum();
    }
    let mut u = rng.next_f32() * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return cand[i].0;
        }
    }
    cand[cand.len() - 1].0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_params_match_argmax() {
        let logits = [0.1f32, 2.5, -1.0, 2.5];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy();
        assert!(p.is_greedy());
        assert_eq!(sample(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn top_k_one_is_argmax_regardless_of_rng() {
        let logits = [0.3f32, -0.2, 4.0, 1.0];
        let p = SamplingParams { temperature: 1.0, top_k: 1, ..Default::default() };
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            assert_eq!(sample(&logits, &p, &mut rng), 2);
        }
    }

    #[test]
    fn tiny_top_p_degenerates_to_argmax() {
        let logits = [0.0f32, 3.0, 1.0];
        for top_p in [1e-6f32, 0.0, -1.0] {
            let p = SamplingParams { temperature: 0.7, top_p, ..Default::default() };
            for seed in 0..20u64 {
                let mut rng = Rng::new(seed);
                assert_eq!(sample(&logits, &p, &mut rng), 1, "top_p={top_p}");
            }
        }
    }

    #[test]
    fn same_seed_same_stream() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let p = SamplingParams { temperature: 1.0, seed: 42, ..Default::default() };
        let a: Vec<usize> = {
            let mut rng = seq_rng(p.seed, 0);
            (0..50).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<usize> = {
            let mut rng = seq_rng(p.seed, 0);
            (0..50).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
        let c: Vec<usize> = {
            let mut rng = seq_rng(p.seed, 1);
            (0..50).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        assert_ne!(a, c, "distinct sequence streams should differ");
    }

    #[test]
    fn covers_support_at_high_temperature() {
        let logits = [0.0f32, 0.1, 0.2];
        let p = SamplingParams { temperature: 5.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all tokens should be reachable");
    }

    #[test]
    fn nan_and_degenerate_rows_are_total() {
        let p = SamplingParams::default();
        let mut rng = Rng::new(5);
        assert_eq!(sample(&[], &p, &mut rng), 0);
        assert_eq!(sample(&[f32::NAN, f32::NAN], &p, &mut rng), 0);
        // NaN is never sampled
        let logits = [f32::NAN, 1.0, f32::NAN];
        for _ in 0..50 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
        // +inf degenerates deterministically to the best index
        assert_eq!(sample(&[0.0, f32::INFINITY, 1.0], &p, &mut rng), 1);
    }
}
