//! The model-wide KV block pool: fixed-size pages + free list + refcounts.
//!
//! A [`BlockPool`] owns every physical KV page the serving engine can
//! use.  One block stores `block_size` consecutive positions of post-RoPE
//! K and V for **all** layers (layer-major, slot-minor within the block —
//! the same row layout as the flat [`crate::serve::kv::KvCache`], just
//! chopped into pages), so a sequence's storage is a *block table* of
//! page ids instead of one worst-case slab.
//!
//! Blocks are refcounted: requests with a common prompt prefix map the
//! same physical pages (see [`crate::serve::paged::PagedKvCache`]), and a
//! page returns to the free list only when its last holder releases it.
//! Because pages are fixed-size, allocation is exact-fit by construction
//! — the best-fit search the variable-capacity [`crate::serve::kv::KvPool`]
//! needs does not exist here; `try_alloc` is a free-list pop.
//!
//! The pool is budgeted (`max_blocks`): storage grows lazily up to the
//! budget and never beyond, which is what lets the scheduler admit by
//! block count instead of worst-case rows.  High-water marks
//! (`peak_resident`, `peak_shared`) are tracked so a post-run stats query
//! still reports the memory the run actually touched.

/// Physical storage of one KV page: `block_size` rows of K and V per
/// layer.  Row `(layer, slot)` of `k` lives at
/// `(layer * block_size + slot) * d .. + d` (same for `v`).
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Aggregate pool statistics (block counts + bytes), rendered into the
/// protocol's stats frame.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvStats {
    /// Positions per block.
    pub block_size: usize,
    /// Block budget (allocation ceiling).
    pub blocks_total: usize,
    /// Blocks with backing storage allocated (free-listed ones included).
    pub resident_blocks: usize,
    /// Allocated blocks currently on the free list.
    pub free_blocks: usize,
    /// Allocated blocks currently held by at least one sequence.
    pub used_blocks: usize,
    /// Blocks held by two or more sequences right now (prefix sharing).
    pub shared_blocks: usize,
    /// High-water mark of `resident_blocks`.
    pub peak_resident_blocks: usize,
    /// High-water mark of `shared_blocks`.
    pub peak_shared_blocks: usize,
    /// Bytes of one block's K+V storage.
    pub block_bytes: usize,
    /// Bytes currently resident (`resident_blocks * block_bytes`).
    pub resident_bytes: usize,
    /// High-water mark of resident bytes.
    pub peak_resident_bytes: usize,
}

/// Fixed-size KV page allocator for one model shape.
pub struct BlockPool {
    n_layers: usize,
    d: usize,
    block_size: usize,
    max_blocks: usize,
    blocks: Vec<Block>,
    refs: Vec<u32>,
    free: Vec<usize>,
    /// Blocks with refcount >= 2 right now.
    shared_now: usize,
    peak_resident: usize,
    peak_shared: usize,
    /// Fault-injection plan: when armed, the `alloc` point can make
    /// `try_alloc` fail as if the budget were exhausted.
    fault: Option<std::sync::Arc<crate::obs::FaultPlan>>,
}

impl BlockPool {
    /// A pool of up to `max_blocks` pages of `block_size` positions each,
    /// for a model with `n_layers` layers and `d`-wide K/V rows.  Storage
    /// is allocated lazily as blocks are first handed out.
    pub fn new(n_layers: usize, d: usize, block_size: usize, max_blocks: usize) -> Self {
        BlockPool {
            n_layers,
            d,
            block_size: block_size.max(1),
            max_blocks,
            blocks: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            shared_now: 0,
            peak_resident: 0,
            peak_shared: 0,
            fault: None,
        }
    }

    /// Arm the `alloc` fault-injection point (`--fault alloc:...`).
    pub fn set_fault(&mut self, plan: std::sync::Arc<crate::obs::FaultPlan>) {
        self.fault = Some(plan);
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Positions per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Allocation ceiling (blocks).
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Blocks that `try_alloc` could hand out right now.
    pub fn available(&self) -> usize {
        self.free.len() + (self.max_blocks - self.blocks.len())
    }

    /// f32s in one block's K (or V) plane.
    fn plane_len(&self) -> usize {
        self.n_layers * self.block_size * self.d
    }

    /// Bytes of one block's K+V storage.
    pub fn block_bytes(&self) -> usize {
        2 * self.plane_len() * std::mem::size_of::<f32>()
    }

    /// Take one block with refcount 1, reusing a free-listed page when
    /// possible, growing storage otherwise.  `None` when the budget is
    /// exhausted — the caller backs off (admission) or finishes the
    /// sequence with `capacity` (decode).
    pub fn try_alloc(&mut self) -> Option<usize> {
        if let Some(f) = &self.fault {
            if f.fires(crate::obs::FaultPoint::Alloc) {
                return None;
            }
        }
        if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.refs[id], 0);
            self.refs[id] = 1;
            return Some(id);
        }
        if self.blocks.len() >= self.max_blocks {
            return None;
        }
        let n = self.plane_len();
        self.blocks.push(Block { k: vec![0.0; n], v: vec![0.0; n] });
        self.refs.push(1);
        let id = self.blocks.len() - 1;
        if self.blocks.len() > self.peak_resident {
            self.peak_resident = self.blocks.len();
        }
        Some(id)
    }

    /// Add one holder to `id` (prefix sharing).
    pub fn retain(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "retain of a free block");
        self.refs[id] += 1;
        if self.refs[id] == 2 {
            self.shared_now += 1;
            if self.shared_now > self.peak_shared {
                self.peak_shared = self.shared_now;
            }
        }
    }

    /// Drop one holder of `id`; the block returns to the free list when
    /// the last holder lets go.
    pub fn release(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "release of a free block");
        self.refs[id] -= 1;
        match self.refs[id] {
            1 => self.shared_now -= 1,
            0 => self.free.push(id),
            _ => {}
        }
    }

    /// Current holder count of `id` (0 = free-listed).
    pub fn ref_count(&self, id: usize) -> u32 {
        self.refs[id]
    }

    /// Copy `src`'s entire K/V payload into `dst` (copy-on-write: the
    /// writer keeps `dst`, other holders keep `src`).  Rows beyond the
    /// copier's committed length are carried along as garbage, which is
    /// fine — readable rows are always written before they are read.
    pub fn copy_block(&mut self, src: usize, dst: usize) {
        debug_assert_ne!(src, dst);
        let (lo, hi, src_is_lo) = if src < dst { (src, dst, true) } else { (dst, src, false) };
        let (a, b) = self.blocks.split_at_mut(hi);
        let (s, t) = if src_is_lo { (&a[lo], &mut b[0]) } else { (&b[0], &mut a[lo]) };
        t.k.copy_from_slice(&s.k);
        t.v.copy_from_slice(&s.v);
    }

    /// Write `t = krows.len() / d` K/V rows of `layer` into `id` starting
    /// at in-block slot `slot0`.
    pub fn write_rows(
        &mut self,
        id: usize,
        layer: usize,
        slot0: usize,
        krows: &[f32],
        vrows: &[f32],
    ) {
        debug_assert_eq!(krows.len(), vrows.len());
        debug_assert!(layer < self.n_layers);
        debug_assert!(slot0 * self.d + krows.len() <= self.block_size * self.d);
        let off = (layer * self.block_size + slot0) * self.d;
        let b = &mut self.blocks[id];
        b.k[off..off + krows.len()].copy_from_slice(krows);
        b.v[off..off + vrows.len()].copy_from_slice(vrows);
    }

    /// Contiguous key rows `[slot0, slot0 + t)` of `layer` in `id`.
    pub fn k_rows(&self, id: usize, layer: usize, slot0: usize, t: usize) -> &[f32] {
        let off = (layer * self.block_size + slot0) * self.d;
        &self.blocks[id].k[off..off + t * self.d]
    }

    /// Contiguous value rows `[slot0, slot0 + t)` of `layer` in `id`.
    pub fn v_rows(&self, id: usize, layer: usize, slot0: usize, t: usize) -> &[f32] {
        let off = (layer * self.block_size + slot0) * self.d;
        &self.blocks[id].v[off..off + t * self.d]
    }

    /// Rebuild refcounts, free list, and sharing counts from scratch out
    /// of the surviving sequences' block tables (panic recovery: after an
    /// unwind mid-step the incremental bookkeeping cannot be trusted).
    /// Resident storage is kept — pages referenced by no survivor are
    /// free-listed, not deallocated — and high-water marks survive.
    pub fn rebuild<'a>(&mut self, tables: impl Iterator<Item = &'a [usize]>) {
        for r in self.refs.iter_mut() {
            *r = 0;
        }
        for table in tables {
            for &id in table {
                debug_assert!(id < self.refs.len(), "survivor references unknown block");
                if id < self.refs.len() {
                    self.refs[id] += 1;
                }
            }
        }
        self.free.clear();
        self.shared_now = 0;
        for (id, &r) in self.refs.iter().enumerate() {
            if r == 0 {
                self.free.push(id);
            } else if r >= 2 {
                self.shared_now += 1;
            }
        }
        if self.shared_now > self.peak_shared {
            self.peak_shared = self.shared_now;
        }
    }

    /// Snapshot of counts, shares, and high-water marks.
    pub fn stats(&self) -> KvStats {
        let resident = self.blocks.len();
        let free = self.free.len();
        let bb = self.block_bytes();
        KvStats {
            block_size: self.block_size,
            blocks_total: self.max_blocks,
            resident_blocks: resident,
            free_blocks: free,
            used_blocks: resident - free,
            shared_blocks: self.shared_now,
            peak_resident_blocks: self.peak_resident,
            peak_shared_blocks: self.peak_shared,
            block_bytes: bb,
            resident_bytes: resident * bb,
            peak_resident_bytes: self.peak_resident * bb,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_within_budget() {
        let mut pool = BlockPool::new(2, 4, 8, 3);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        assert!(pool.try_alloc().is_none(), "budget of 3 is exhausted");
        assert_eq!(pool.stats().resident_blocks, 3);
        assert_eq!(pool.stats().used_blocks, 3);

        pool.release(b);
        assert_eq!(pool.available(), 1);
        let b2 = pool.try_alloc().unwrap();
        assert_eq!(b2, b, "free-listed page is reused, not grown");
        assert_eq!(pool.stats().resident_blocks, 3, "no growth past first 3");

        pool.release(a);
        pool.release(b2);
        pool.release(c);
        let s = pool.stats();
        assert_eq!(s.used_blocks, 0);
        assert_eq!(s.free_blocks, 3);
        assert_eq!(s.peak_resident_blocks, 3);
    }

    #[test]
    fn refcounts_and_shared_tracking() {
        let mut pool = BlockPool::new(1, 2, 4, 4);
        let a = pool.try_alloc().unwrap();
        assert_eq!(pool.ref_count(a), 1);
        assert_eq!(pool.stats().shared_blocks, 0);

        pool.retain(a);
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 3);
        assert_eq!(pool.stats().shared_blocks, 1);
        assert_eq!(pool.stats().peak_shared_blocks, 1);

        pool.release(a);
        assert_eq!(pool.stats().shared_blocks, 1, "still 2 holders");
        pool.release(a);
        assert_eq!(pool.stats().shared_blocks, 0);
        assert_eq!(pool.stats().used_blocks, 1);
        pool.release(a);
        assert_eq!(pool.stats().used_blocks, 0);
        assert_eq!(pool.stats().peak_shared_blocks, 1, "peak survives release");
    }

    #[test]
    fn rebuild_recounts_from_tables() {
        let mut pool = BlockPool::new(1, 2, 4, 4);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        pool.retain(a); // simulate sharing
        assert_eq!(pool.stats().used_blocks, 3);

        // Survivors hold [a, b] and [a]; c's holder vanished mid-panic.
        let t1 = vec![a, b];
        let t2 = vec![a];
        pool.rebuild([&t1[..], &t2[..]].into_iter());
        assert_eq!(pool.ref_count(a), 2);
        assert_eq!(pool.ref_count(b), 1);
        assert_eq!(pool.ref_count(c), 0, "orphaned page reclaimed");
        let s = pool.stats();
        assert_eq!(s.used_blocks, 2);
        assert_eq!(s.free_blocks, 1);
        assert_eq!(s.shared_blocks, 1);
        let c2 = pool.try_alloc().unwrap();
        assert_eq!(c2, c, "reclaimed page is allocatable again");
    }

    #[test]
    fn fault_plan_fails_alloc() {
        let plan = std::sync::Arc::new(crate::obs::FaultPlan::parse("alloc:@2:1").unwrap());
        let mut pool = BlockPool::new(1, 2, 4, 4);
        pool.set_fault(plan);
        assert!(pool.try_alloc().is_some());
        assert!(pool.try_alloc().is_none(), "2nd allocation injected to fail");
        assert!(pool.try_alloc().is_some(), "one-shot fault clears");
    }

    #[test]
    fn rows_roundtrip_and_copy_block() {
        let (layers, d, bs) = (2usize, 3usize, 4usize);
        let mut pool = BlockPool::new(layers, d, bs, 2);
        let a = pool.try_alloc().unwrap();
        let k: Vec<f32> = (0..2 * d).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..2 * d).map(|i| 10.0 + i as f32).collect();
        pool.write_rows(a, 1, 1, &k, &v);
        assert_eq!(pool.k_rows(a, 1, 1, 2), &k[..]);
        assert_eq!(pool.v_rows(a, 1, 1, 2), &v[..]);
        assert_eq!(pool.k_rows(a, 0, 1, 2), &[0.0; 6][..], "other layer untouched");

        let b = pool.try_alloc().unwrap();
        pool.copy_block(a, b);
        assert_eq!(pool.k_rows(b, 1, 1, 2), &k[..]);
        assert_eq!(pool.v_rows(b, 1, 1, 2), &v[..]);
        // and the reverse direction exercises the other split arm
        pool.write_rows(b, 0, 0, &[7.0; 3], &[8.0; 3]);
        pool.copy_block(b, a);
        assert_eq!(pool.k_rows(a, 0, 0, 1), &[7.0; 3][..]);
    }
}
