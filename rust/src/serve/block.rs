//! The model-wide KV block pool: fixed-size pages + free list + refcounts.
//!
//! A [`BlockPool`] owns every physical KV page the serving engine can
//! use.  One block stores `block_size` consecutive positions of post-RoPE
//! K and V for **all** layers (layer-major, slot-minor within the block —
//! the same row layout as the flat [`crate::serve::kv::KvCache`], just
//! chopped into pages), so a sequence's storage is a *block table* of
//! page ids instead of one worst-case slab.
//!
//! Blocks are refcounted: requests with a common prompt prefix map the
//! same physical pages (see [`crate::serve::paged::PagedKvCache`]), and a
//! page returns to the free list only when its last holder releases it.
//! Because pages are fixed-size, allocation is exact-fit by construction
//! — the best-fit search the variable-capacity [`crate::serve::kv::KvPool`]
//! needs does not exist here; `try_alloc` is a free-list pop.
//!
//! The pool is budgeted (`max_blocks`): storage grows lazily up to the
//! budget and never beyond, which is what lets the scheduler admit by
//! block count instead of worst-case rows.  High-water marks
//! (`peak_resident`, `peak_shared`) are tracked so a post-run stats query
//! still reports the memory the run actually touched.
//!
//! ## Quantized layouts
//!
//! A pool built with [`BlockPool::with_layout`] and
//! [`KvLayout::Quant`] stores *sealed* pages as group-wise
//! affine-quantized codes (a zero-included asymmetric grid — see
//! `quantize_plane` — packed by `quant/pack.rs`) instead of raw f32
//! planes: one `(scale, zero)` pair per `group` consecutive values of a
//! row — a head slice when `group == head_dim` — so each page carries
//! its own quantization grid.  Pages start *staged* (plain f32, the write
//! buffer); [`BlockPool::seal_block`] quantizes a fully-committed page
//! and drops the staging planes, shrinking it to roughly
//! `bits/32 + 5/group` of its f32 footprint.  Reads go through
//! [`BlockPool::segment`], which hands the attention core either the f32
//! slices or a [`KvQuantView`] to dequantize on the fly; a write into a
//! sealed page transparently reopens it (dequantize back to staging —
//! bitwise the same values sealed reads returned — then overwrite).
//! `KvLayout::F32` keeps the exact pre-quantization behavior and remains
//! the bitwise oracle.

use crate::error::{Error, Result};
use crate::kernels::dequant::{kv_dequant_scalar, KvQuantView};
use crate::quant::{affine, pack_codes};

/// Storage layout of KV pages in a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Raw f32 planes — the default and the bitwise oracle.
    F32,
    /// Group-wise affine-quantized sealed pages: `bits`-wide codes with
    /// one scale/zero per `group` consecutive values.
    Quant { bits: u32, group: usize },
}

impl KvLayout {
    /// Effective storage width in bits (16 = f32 path; the flag speaks
    /// `--kv-bits 16` for "no KV quantization").
    pub fn bits(self) -> u32 {
        match self {
            KvLayout::F32 => 16,
            KvLayout::Quant { bits, .. } => bits,
        }
    }
}

/// One quantized plane (all layers' K, or all layers' V, of one page):
/// packed codes plus the per-group affine grid.
#[derive(Clone)]
struct QuantPlane {
    codes: Vec<u8>,
    scales: Vec<f32>,
    zeros: Vec<u8>,
}

/// Sealed-page payload: quantized K and V planes.
#[derive(Clone)]
struct QuantBlock {
    k: QuantPlane,
    v: QuantPlane,
}

/// Physical storage of one KV page: `block_size` rows of K and V per
/// layer.  Row `(layer, slot)` of `k` lives at
/// `(layer * block_size + slot) * d .. + d` (same for `v`).
///
/// Under a quantized layout a page is either *staged* (`q` is `None`,
/// `k`/`v` hold f32 rows) or *sealed* (`q` holds the packed codes and
/// `k`/`v` are empty).  Under `KvLayout::F32`, `q` is always `None`.
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
    q: Option<QuantBlock>,
}

/// One readable run of KV rows handed to the attention core: either raw
/// f32 row slices or quantized views to dequantize during the walk.
pub enum KvSegment<'a> {
    /// `(k_rows, v_rows)` — `rows * d` f32s each.
    F32(&'a [f32], &'a [f32]),
    /// Quantized K/V views over the first `rows` rows of a sealed page's
    /// layer run (view value index `r * d + j` = row `r`, component `j`).
    Quant { k: KvQuantView<'a>, v: KvQuantView<'a>, rows: usize },
}

impl<'a> KvSegment<'a> {
    /// Row count of the segment given the KV row width.
    pub fn rows(&self, d: usize) -> usize {
        match self {
            KvSegment::F32(k, _) => k.len() / d,
            KvSegment::Quant { rows, .. } => *rows,
        }
    }

    /// The raw f32 slices; panics on a quantized segment (tests and flat
    /// call sites only — the attention core matches on the enum).
    pub fn as_f32(&self) -> (&'a [f32], &'a [f32]) {
        match self {
            KvSegment::F32(k, v) => (k, v),
            KvSegment::Quant { .. } => panic!("as_f32 on a quantized KV segment"),
        }
    }
}

/// Aggregate pool statistics (block counts + bytes), rendered into the
/// protocol's stats frame.
#[derive(Clone, Copy, Debug, Default)]
pub struct KvStats {
    /// Positions per block.
    pub block_size: usize,
    /// Block budget (allocation ceiling).
    pub blocks_total: usize,
    /// Blocks with backing storage allocated (free-listed ones included).
    pub resident_blocks: usize,
    /// Allocated blocks currently on the free list.
    pub free_blocks: usize,
    /// Allocated blocks currently held by at least one sequence.
    pub used_blocks: usize,
    /// Blocks held by two or more sequences right now (prefix sharing).
    pub shared_blocks: usize,
    /// High-water mark of `resident_blocks`.
    pub peak_resident_blocks: usize,
    /// High-water mark of `shared_blocks`.
    pub peak_shared_blocks: usize,
    /// Bytes of one block's K+V storage at rest (sealed size under a
    /// quantized layout; the f32 size otherwise).
    pub block_bytes: usize,
    /// True bytes currently resident: staged pages cost the f32 size,
    /// sealed pages the quantized size.
    pub resident_bytes: usize,
    /// High-water mark of true resident bytes.
    pub peak_resident_bytes: usize,
    /// Storage width: 16 = f32 pages, 8/4 = quantized sealed pages.
    pub kv_bits: u32,
    /// Bytes one block would occupy under the f32 layout — the
    /// denominator of the compression ratio.
    pub f32_block_bytes: usize,
}

/// Fixed-size KV page allocator for one model shape.
pub struct BlockPool {
    n_layers: usize,
    d: usize,
    block_size: usize,
    max_blocks: usize,
    layout: KvLayout,
    blocks: Vec<Block>,
    refs: Vec<u32>,
    free: Vec<usize>,
    /// Blocks with refcount >= 2 right now.
    shared_now: usize,
    peak_resident: usize,
    peak_shared: usize,
    /// True resident bytes right now (staged pages at f32 size, sealed
    /// pages at quantized size), maintained incrementally at every
    /// grow / seal / reopen / recycle transition.
    bytes_now: usize,
    peak_bytes: usize,
    /// Fault-injection plan: when armed, the `alloc` point can make
    /// `try_alloc` fail as if the budget were exhausted.
    fault: Option<std::sync::Arc<crate::obs::FaultPlan>>,
}

impl BlockPool {
    /// A pool of up to `max_blocks` pages of `block_size` positions each,
    /// for a model with `n_layers` layers and `d`-wide K/V rows.  Storage
    /// is allocated lazily as blocks are first handed out.  f32 layout —
    /// the bitwise oracle.
    pub fn new(n_layers: usize, d: usize, block_size: usize, max_blocks: usize) -> Self {
        Self::with_layout(n_layers, d, block_size, max_blocks, KvLayout::F32)
    }

    /// A pool with an explicit page layout.  Quantized layouts require
    /// `bits` in {4, 8}, `group` dividing `d`, and byte-aligned groups
    /// (`group * bits % 8 == 0`) so every row and layer run of the packed
    /// plane starts on a byte boundary.
    pub fn with_layout(
        n_layers: usize,
        d: usize,
        block_size: usize,
        max_blocks: usize,
        layout: KvLayout,
    ) -> Self {
        if let KvLayout::Quant { bits, group } = layout {
            assert!(bits == 4 || bits == 8, "kv quant bits must be 4 or 8, got {bits}");
            assert!(group > 0 && d % group == 0, "kv group {group} must divide row width {d}");
            assert!(
                (group * bits as usize) % 8 == 0,
                "kv group {group} x {bits} bits must be byte-aligned"
            );
        }
        BlockPool {
            n_layers,
            d,
            block_size: block_size.max(1),
            max_blocks,
            layout,
            blocks: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            shared_now: 0,
            peak_resident: 0,
            peak_shared: 0,
            bytes_now: 0,
            peak_bytes: 0,
            fault: None,
        }
    }

    /// Arm the `alloc` fault-injection point (`--fault alloc:...`).
    pub fn set_fault(&mut self, plan: std::sync::Arc<crate::obs::FaultPlan>) {
        self.fault = Some(plan);
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Positions per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Allocation ceiling (blocks).
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Blocks that `try_alloc` could hand out right now.
    pub fn available(&self) -> usize {
        self.free.len() + (self.max_blocks - self.blocks.len())
    }

    /// The page storage layout.
    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    /// Storage width in bits (16 = f32).
    pub fn kv_bits(&self) -> u32 {
        self.layout.bits()
    }

    /// f32s in one block's K (or V) plane.
    fn plane_len(&self) -> usize {
        self.n_layers * self.block_size * self.d
    }

    /// Bytes of one block's K+V storage under the f32 layout (also the
    /// cost of a *staged* page under a quantized layout).
    pub fn f32_block_bytes(&self) -> usize {
        2 * self.plane_len() * std::mem::size_of::<f32>()
    }

    /// Bytes of one block's K+V storage at rest: the sealed (quantized)
    /// size under a quantized layout, the f32 size otherwise.
    pub fn block_bytes(&self) -> usize {
        match self.layout {
            KvLayout::F32 => self.f32_block_bytes(),
            KvLayout::Quant { .. } => self.quant_block_bytes(),
        }
    }

    /// Bytes of one sealed page: packed codes + per-group scale (f32) and
    /// zero (u8), K and V planes.
    fn quant_block_bytes(&self) -> usize {
        match self.layout {
            KvLayout::F32 => self.f32_block_bytes(),
            KvLayout::Quant { bits, group } => {
                let n = self.plane_len();
                let groups = n / group;
                2 * (n * bits as usize / 8 + groups * (std::mem::size_of::<f32>() + 1))
            }
        }
    }

    /// Bytes block `id` occupies right now.
    fn resident_bytes_of(&self, id: usize) -> usize {
        if self.blocks[id].q.is_some() {
            self.quant_block_bytes()
        } else {
            self.f32_block_bytes()
        }
    }

    /// Apply a resident-byte transition (`old` -> `new` bytes for one
    /// block) and roll the high-water mark.
    fn note_bytes(&mut self, old: usize, new: usize) {
        self.bytes_now = self.bytes_now + new - old;
        if self.bytes_now > self.peak_bytes {
            self.peak_bytes = self.bytes_now;
        }
    }

    /// Take one block with refcount 1, reusing a free-listed page when
    /// possible, growing storage otherwise.  `None` when the budget is
    /// exhausted — the caller backs off (admission) or finishes the
    /// sequence with `capacity` (decode).
    pub fn try_alloc(&mut self) -> Option<usize> {
        if let Some(f) = &self.fault {
            if f.fires(crate::obs::FaultPoint::Alloc) {
                return None;
            }
        }
        if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.refs[id], 0);
            self.refs[id] = 1;
            // A recycled page may still be sealed from its previous
            // life; reset it to staged eagerly (its contents are garbage
            // — new rows are always written before they are read), so
            // the write path never pays a pointless dequantize.
            if self.blocks[id].q.take().is_some() {
                let n = self.plane_len();
                self.blocks[id].k = vec![0.0; n];
                self.blocks[id].v = vec![0.0; n];
                let (qb, fb) = (self.quant_block_bytes(), self.f32_block_bytes());
                self.note_bytes(qb, fb);
            }
            return Some(id);
        }
        if self.blocks.len() >= self.max_blocks {
            return None;
        }
        let n = self.plane_len();
        self.blocks.push(Block { k: vec![0.0; n], v: vec![0.0; n], q: None });
        self.refs.push(1);
        let id = self.blocks.len() - 1;
        if self.blocks.len() > self.peak_resident {
            self.peak_resident = self.blocks.len();
        }
        self.note_bytes(0, self.f32_block_bytes());
        Some(id)
    }

    /// Add one holder to `id` (prefix sharing).
    pub fn retain(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "retain of a free block");
        self.refs[id] += 1;
        if self.refs[id] == 2 {
            self.shared_now += 1;
            if self.shared_now > self.peak_shared {
                self.peak_shared = self.shared_now;
            }
        }
    }

    /// Drop one holder of `id`; the block returns to the free list when
    /// the last holder lets go.
    pub fn release(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "release of a free block");
        self.refs[id] -= 1;
        match self.refs[id] {
            1 => self.shared_now -= 1,
            0 => self.free.push(id),
            _ => {}
        }
    }

    /// Current holder count of `id` (0 = free-listed).
    pub fn ref_count(&self, id: usize) -> u32 {
        self.refs[id]
    }

    /// Copy `src`'s entire K/V payload into `dst` (copy-on-write: the
    /// writer keeps `dst`, other holders keep `src`).  Rows beyond the
    /// copier's committed length are carried along as garbage, which is
    /// fine — readable rows are always written before they are read.
    pub fn copy_block(&mut self, src: usize, dst: usize) {
        debug_assert_ne!(src, dst);
        let before = self.resident_bytes_of(dst);
        let (lo, hi, src_is_lo) = if src < dst { (src, dst, true) } else { (dst, src, false) };
        let (a, b) = self.blocks.split_at_mut(hi);
        let (s, t) = if src_is_lo { (&a[lo], &mut b[0]) } else { (&b[0], &mut a[lo]) };
        if s.q.is_none() && t.q.is_none() {
            t.k.copy_from_slice(&s.k);
            t.v.copy_from_slice(&s.v);
        } else {
            // Sealed pages replicate whole (codes + grid), staged pages
            // replicate their staging planes — `dst` becomes an exact
            // state clone either way.
            t.k = s.k.clone();
            t.v = s.v.clone();
            t.q = s.q.clone();
        }
        let after = self.resident_bytes_of(dst);
        self.note_bytes(before, after);
    }

    /// Write `t = krows.len() / d` K/V rows of `layer` into `id` starting
    /// at in-block slot `slot0`.
    pub fn write_rows(
        &mut self,
        id: usize,
        layer: usize,
        slot0: usize,
        krows: &[f32],
        vrows: &[f32],
    ) {
        debug_assert_eq!(krows.len(), vrows.len());
        debug_assert!(layer < self.n_layers);
        debug_assert!(slot0 * self.d + krows.len() <= self.block_size * self.d);
        self.reopen_block(id);
        let off = (layer * self.block_size + slot0) * self.d;
        let b = &mut self.blocks[id];
        b.k[off..off + krows.len()].copy_from_slice(krows);
        b.v[off..off + vrows.len()].copy_from_slice(vrows);
    }

    /// Quantize block `id`'s staging planes into packed codes and drop
    /// the f32 storage.  No-op under `KvLayout::F32` or when already
    /// sealed.  Callers seal only fully-committed pages (the paged cache
    /// enforces this); a later write reopens transparently.
    ///
    /// Each plane is quantized in one pass over `group`-sized runs —
    /// since `group` divides `d`, groups land exactly on per-(layer,
    /// slot, head-slice) runs of the plane.
    pub fn seal_block(&mut self, id: usize) {
        let (bits, group) = match self.layout {
            KvLayout::F32 => return,
            KvLayout::Quant { bits, group } => (bits, group),
        };
        if self.blocks[id].q.is_some() {
            return;
        }
        let (fb, qb) = (self.f32_block_bytes(), self.quant_block_bytes());
        let b = &mut self.blocks[id];
        let k = quantize_plane(std::mem::take(&mut b.k), group, bits);
        let v = quantize_plane(std::mem::take(&mut b.v), group, bits);
        b.q = Some(QuantBlock { k, v });
        self.note_bytes(fb, qb);
    }

    /// Whether block `id` is currently sealed (quantized storage).
    pub fn is_sealed(&self, id: usize) -> bool {
        self.blocks[id].q.is_some()
    }

    /// Dequantize a sealed block back to staging so it can be written.
    /// The staging values are bitwise identical to what sealed reads
    /// returned (`s * (q - z)` per value), so reopening cannot drift the
    /// committed rows; only a subsequent reseal re-quantizes.
    fn reopen_block(&mut self, id: usize) {
        let Some(q) = self.blocks[id].q.take() else { return };
        let (bits, group) = match self.layout {
            KvLayout::F32 => unreachable!("sealed block in an f32 pool"),
            KvLayout::Quant { bits, group } => (bits, group),
        };
        let n = self.plane_len();
        let d = self.d;
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        dequantize_plane(&q.k, d, group, bits, &mut k);
        dequantize_plane(&q.v, d, group, bits, &mut v);
        let b = &mut self.blocks[id];
        b.k = k;
        b.v = v;
        let (qb, fb) = (self.quant_block_bytes(), self.f32_block_bytes());
        self.note_bytes(qb, fb);
    }

    /// The readable run `[0, take)` rows of `layer` in block `id`, in
    /// whatever representation the block currently has.  This is the
    /// accessor the paged segment walk uses; `k_rows`/`v_rows` remain for
    /// staged (and all-f32) pages.
    pub fn segment(&self, id: usize, layer: usize, take: usize) -> KvSegment<'_> {
        debug_assert!(layer < self.n_layers && take <= self.block_size);
        let b = &self.blocks[id];
        match (&b.q, self.layout) {
            (Some(q), KvLayout::Quant { bits, group }) => {
                let lvals = self.block_size * self.d;
                let byte0 = layer * lvals * bits as usize / 8;
                let nbytes = take * self.d * bits as usize / 8;
                let g0 = layer * lvals / group;
                let ng = take * self.d / group;
                let k = KvQuantView {
                    codes: &q.k.codes[byte0..byte0 + nbytes],
                    scales: &q.k.scales[g0..g0 + ng],
                    zeros: &q.k.zeros[g0..g0 + ng],
                    d: self.d,
                    group,
                    bits,
                };
                let v = KvQuantView {
                    codes: &q.v.codes[byte0..byte0 + nbytes],
                    scales: &q.v.scales[g0..g0 + ng],
                    zeros: &q.v.zeros[g0..g0 + ng],
                    d: self.d,
                    group,
                    bits,
                };
                KvSegment::Quant { k, v, rows: take }
            }
            _ => KvSegment::F32(
                self.k_rows(id, layer, 0, take),
                self.v_rows(id, layer, 0, take),
            ),
        }
    }

    /// Contiguous key rows `[slot0, slot0 + t)` of `layer` in `id`.
    pub fn k_rows(&self, id: usize, layer: usize, slot0: usize, t: usize) -> &[f32] {
        let off = (layer * self.block_size + slot0) * self.d;
        &self.blocks[id].k[off..off + t * self.d]
    }

    /// Contiguous value rows `[slot0, slot0 + t)` of `layer` in `id`.
    pub fn v_rows(&self, id: usize, layer: usize, slot0: usize, t: usize) -> &[f32] {
        let off = (layer * self.block_size + slot0) * self.d;
        &self.blocks[id].v[off..off + t * self.d]
    }

    /// Largest byte payload [`export_block`](Self::export_block) can
    /// produce: one tag byte plus both f32 staging planes.  The spill
    /// file sizes its slots to this so staged and sealed pages share one
    /// slot geometry.
    pub fn max_export_bytes(&self) -> usize {
        1 + self.f32_block_bytes()
    }

    /// Serialize block `id`'s exact storage state: a tag byte (0 =
    /// staged, 1 = sealed) followed by the verbatim plane bytes (f32
    /// little-endian for staged pages; packed codes + LE scales + zeros
    /// per plane for sealed ones).  `import_block` of these bytes
    /// reconstructs a bit-identical page — the tier's whole correctness
    /// story rests on this being a byte copy, not a re-encode.
    pub fn export_block(&self, id: usize) -> Vec<u8> {
        let b = &self.blocks[id];
        match &b.q {
            None => {
                let mut out = Vec::with_capacity(1 + self.f32_block_bytes());
                out.push(0u8);
                for plane in [&b.k, &b.v] {
                    for &x in plane.iter() {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                out
            }
            Some(q) => {
                let mut out = Vec::with_capacity(1 + self.quant_block_bytes());
                out.push(1u8);
                for p in [&q.k, &q.v] {
                    out.extend_from_slice(&p.codes);
                    for &s in &p.scales {
                        out.extend_from_slice(&s.to_le_bytes());
                    }
                    out.extend_from_slice(&p.zeros);
                }
                out
            }
        }
    }

    /// Restore an [`export_block`](Self::export_block) record into block
    /// `id` (a freshly `try_alloc`'d page, so currently staged),
    /// recreating the exact staged-or-sealed state the bytes were
    /// exported from.  Errors on a record whose tag or length does not
    /// match this pool's shape/layout — the caller treats that like a
    /// failed disk read.
    pub fn import_block(&mut self, id: usize, bytes: &[u8]) -> Result<()> {
        let n = self.plane_len();
        let Some((tag, payload)) = bytes.split_first() else {
            return Err(Error::config("kv spill: empty page record"));
        };
        match *tag {
            0 => {
                if payload.len() != 2 * n * 4 {
                    return Err(Error::config(format!(
                        "kv spill: staged page record is {} bytes, pool shape needs {}",
                        payload.len(),
                        2 * n * 4
                    )));
                }
                debug_assert!(self.blocks[id].q.is_none(), "import into a sealed page");
                let b = &mut self.blocks[id];
                for (dst, src) in b.k.iter_mut().zip(payload[..4 * n].chunks_exact(4)) {
                    *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                }
                for (dst, src) in b.v.iter_mut().zip(payload[4 * n..].chunks_exact(4)) {
                    *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                }
                Ok(())
            }
            1 => {
                let (bits, group) = match self.layout {
                    KvLayout::F32 => {
                        return Err(Error::config(
                            "kv spill: sealed page record in an f32 pool",
                        ))
                    }
                    KvLayout::Quant { bits, group } => (bits, group),
                };
                let codes_len = n * bits as usize / 8;
                let groups = n / group;
                let plane_bytes = codes_len + groups * 4 + groups;
                if payload.len() != 2 * plane_bytes {
                    return Err(Error::config(format!(
                        "kv spill: sealed page record is {} bytes, pool layout needs {}",
                        payload.len(),
                        2 * plane_bytes
                    )));
                }
                let parse_plane = |p: &[u8]| QuantPlane {
                    codes: p[..codes_len].to_vec(),
                    scales: p[codes_len..codes_len + 4 * groups]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                    zeros: p[codes_len + 4 * groups..].to_vec(),
                };
                let k = parse_plane(&payload[..plane_bytes]);
                let v = parse_plane(&payload[plane_bytes..]);
                let (fb, qb) = (self.f32_block_bytes(), self.quant_block_bytes());
                let b = &mut self.blocks[id];
                b.k = Vec::new();
                b.v = Vec::new();
                b.q = Some(QuantBlock { k, v });
                self.note_bytes(fb, qb);
                Ok(())
            }
            t => Err(Error::config(format!("kv spill: unknown page tag {t}"))),
        }
    }

    /// Rebuild refcounts, free list, and sharing counts from scratch out
    /// of the surviving sequences' block tables (panic recovery: after an
    /// unwind mid-step the incremental bookkeeping cannot be trusted).
    /// Resident storage is kept — pages referenced by no survivor are
    /// free-listed, not deallocated — and high-water marks survive.
    pub fn rebuild<'a>(&mut self, tables: impl Iterator<Item = &'a [usize]>) {
        for r in self.refs.iter_mut() {
            *r = 0;
        }
        for table in tables {
            for &id in table {
                debug_assert!(id < self.refs.len(), "survivor references unknown block");
                if id < self.refs.len() {
                    self.refs[id] += 1;
                }
            }
        }
        self.free.clear();
        self.shared_now = 0;
        for (id, &r) in self.refs.iter().enumerate() {
            if r == 0 {
                self.free.push(id);
            } else if r >= 2 {
                self.shared_now += 1;
            }
        }
        if self.shared_now > self.peak_shared {
            self.peak_shared = self.shared_now;
        }
    }

    /// Snapshot of counts, shares, and high-water marks.
    pub fn stats(&self) -> KvStats {
        let resident = self.blocks.len();
        let free = self.free.len();
        KvStats {
            block_size: self.block_size,
            blocks_total: self.max_blocks,
            resident_blocks: resident,
            free_blocks: free,
            used_blocks: resident - free,
            shared_blocks: self.shared_now,
            peak_resident_blocks: self.peak_resident,
            peak_shared_blocks: self.peak_shared,
            block_bytes: self.block_bytes(),
            resident_bytes: self.bytes_now,
            peak_resident_bytes: self.peak_bytes,
            kv_bits: self.kv_bits(),
            f32_block_bytes: self.f32_block_bytes(),
        }
    }
}

/// Quantize one `(n, 1)`-shaped plane group-wise with a **zero-included**
/// asymmetric affine grid: per `group` consecutive values,
/// `lo = min(min, 0)`, `hi = max(max, 0)`, `s = (hi - lo) / (2^bits - 1)`,
/// `z = round(-lo / s)`.
///
/// This deliberately differs from the weight grid (`affine::scales_zeros`)
/// in one way: the weight grid clamps the zero-point into `[0, m]`, which
/// silently shifts the representable range on groups that don't straddle
/// zero — harmless for near-zero-mean weight groups, but a KV group is one
/// head's slice of one (layer, position) row and is routinely one-sided,
/// where the clamp cuts off up to the group's full distance-to-zero
/// *independent of bit width*.  Including zero in the range instead keeps
/// `z` in `[0, m]` by construction (so it narrows to u8 exactly) and
/// restores the one-step error bound `|v - dq| <= s` everywhere, at the
/// cost of a slightly coarser step on one-sided groups.  Codes are packed
/// little-endian via the weight packer.
fn quantize_plane(plane: Vec<f32>, group: usize, bits: u32) -> QuantPlane {
    let m = ((1u32 << bits) - 1) as f32;
    let n = plane.len();
    let groups = n / group;
    let mut codes = vec![0u32; n];
    let mut scales = vec![0.0f32; groups];
    let mut zeros = vec![0u8; groups];
    for g in 0..groups {
        let blk = &plane[g * group..(g + 1) * group];
        let hi = blk.iter().fold(0.0f32, |a, &x| a.max(x));
        let lo = blk.iter().fold(0.0f32, |a, &x| a.min(x));
        let s = ((hi - lo) / m).max(1e-8);
        let z = affine::round_ties_even(-lo / s).clamp(0.0, m);
        scales[g] = s;
        zeros[g] = z as u8;
        for (i, &v) in blk.iter().enumerate() {
            let q = (affine::round_ties_even(v / s) + z).clamp(0.0, m);
            codes[g * group + i] = q as u32;
        }
    }
    QuantPlane { codes: pack_codes(&codes, bits), scales, zeros }
}

/// Dequantize a sealed plane back into `out` through the same scalar
/// kernel the fused attention walk uses, so the reopened staging values
/// are bitwise identical to what sealed reads produced.
fn dequantize_plane(p: &QuantPlane, d: usize, group: usize, bits: u32, out: &mut [f32]) {
    let view =
        KvQuantView { codes: &p.codes, scales: &p.scales, zeros: &p.zeros, d, group, bits };
    kv_dequant_scalar(&view, 0, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_within_budget() {
        let mut pool = BlockPool::new(2, 4, 8, 3);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        assert!(pool.try_alloc().is_none(), "budget of 3 is exhausted");
        assert_eq!(pool.stats().resident_blocks, 3);
        assert_eq!(pool.stats().used_blocks, 3);

        pool.release(b);
        assert_eq!(pool.available(), 1);
        let b2 = pool.try_alloc().unwrap();
        assert_eq!(b2, b, "free-listed page is reused, not grown");
        assert_eq!(pool.stats().resident_blocks, 3, "no growth past first 3");

        pool.release(a);
        pool.release(b2);
        pool.release(c);
        let s = pool.stats();
        assert_eq!(s.used_blocks, 0);
        assert_eq!(s.free_blocks, 3);
        assert_eq!(s.peak_resident_blocks, 3);
    }

    #[test]
    fn refcounts_and_shared_tracking() {
        let mut pool = BlockPool::new(1, 2, 4, 4);
        let a = pool.try_alloc().unwrap();
        assert_eq!(pool.ref_count(a), 1);
        assert_eq!(pool.stats().shared_blocks, 0);

        pool.retain(a);
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 3);
        assert_eq!(pool.stats().shared_blocks, 1);
        assert_eq!(pool.stats().peak_shared_blocks, 1);

        pool.release(a);
        assert_eq!(pool.stats().shared_blocks, 1, "still 2 holders");
        pool.release(a);
        assert_eq!(pool.stats().shared_blocks, 0);
        assert_eq!(pool.stats().used_blocks, 1);
        pool.release(a);
        assert_eq!(pool.stats().used_blocks, 0);
        assert_eq!(pool.stats().peak_shared_blocks, 1, "peak survives release");
    }

    #[test]
    fn rebuild_recounts_from_tables() {
        let mut pool = BlockPool::new(1, 2, 4, 4);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        pool.retain(a); // simulate sharing
        assert_eq!(pool.stats().used_blocks, 3);

        // Survivors hold [a, b] and [a]; c's holder vanished mid-panic.
        let t1 = vec![a, b];
        let t2 = vec![a];
        pool.rebuild([&t1[..], &t2[..]].into_iter());
        assert_eq!(pool.ref_count(a), 2);
        assert_eq!(pool.ref_count(b), 1);
        assert_eq!(pool.ref_count(c), 0, "orphaned page reclaimed");
        let s = pool.stats();
        assert_eq!(s.used_blocks, 2);
        assert_eq!(s.free_blocks, 1);
        assert_eq!(s.shared_blocks, 1);
        let c2 = pool.try_alloc().unwrap();
        assert_eq!(c2, c, "reclaimed page is allocatable again");
    }

    #[test]
    fn fault_plan_fails_alloc() {
        let plan = std::sync::Arc::new(crate::obs::FaultPlan::parse("alloc:@2:1").unwrap());
        let mut pool = BlockPool::new(1, 2, 4, 4);
        pool.set_fault(plan);
        assert!(pool.try_alloc().is_some());
        assert!(pool.try_alloc().is_none(), "2nd allocation injected to fail");
        assert!(pool.try_alloc().is_some(), "one-shot fault clears");
    }

    #[test]
    fn rows_roundtrip_and_copy_block() {
        let (layers, d, bs) = (2usize, 3usize, 4usize);
        let mut pool = BlockPool::new(layers, d, bs, 2);
        let a = pool.try_alloc().unwrap();
        let k: Vec<f32> = (0..2 * d).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..2 * d).map(|i| 10.0 + i as f32).collect();
        pool.write_rows(a, 1, 1, &k, &v);
        assert_eq!(pool.k_rows(a, 1, 1, 2), &k[..]);
        assert_eq!(pool.v_rows(a, 1, 1, 2), &v[..]);
        assert_eq!(pool.k_rows(a, 0, 1, 2), &[0.0; 6][..], "other layer untouched");

        let b = pool.try_alloc().unwrap();
        pool.copy_block(a, b);
        assert_eq!(pool.k_rows(b, 1, 1, 2), &k[..]);
        assert_eq!(pool.v_rows(b, 1, 1, 2), &v[..]);
        // and the reverse direction exercises the other split arm
        pool.write_rows(b, 0, 0, &[7.0; 3], &[8.0; 3]);
        pool.copy_block(b, a);
        assert_eq!(pool.k_rows(a, 0, 0, 1), &[7.0; 3][..]);
    }

    #[test]
    fn f32_pool_stats_report_full_width() {
        let mut pool = BlockPool::new(1, 2, 4, 2);
        let _ = pool.try_alloc().unwrap();
        let s = pool.stats();
        assert_eq!(s.kv_bits, 16);
        assert_eq!(s.block_bytes, s.f32_block_bytes);
        assert_eq!(s.resident_bytes, s.block_bytes, "one resident staged page");
        assert_eq!(s.peak_resident_bytes, s.block_bytes);
    }

    #[test]
    fn quant_pool_seals_reads_and_reopens_consistently() {
        let (layers, d, bs, group) = (2usize, 64usize, 4usize, 64usize);
        let mut pool =
            BlockPool::with_layout(layers, d, bs, 4, KvLayout::Quant { bits: 8, group });
        let a = pool.try_alloc().unwrap();
        for layer in 0..layers {
            let k: Vec<f32> =
                (0..bs * d).map(|i| (i as f32 * 0.37 + layer as f32).sin()).collect();
            let v: Vec<f32> =
                (0..bs * d).map(|i| (i as f32 * 0.11 - layer as f32).cos()).collect();
            pool.write_rows(a, layer, 0, &k, &v);
        }
        let fb = pool.f32_block_bytes();
        assert_eq!(pool.stats().resident_bytes, fb, "staged page costs f32 bytes");

        pool.seal_block(a);
        assert!(pool.is_sealed(a));
        let s = pool.stats();
        assert_eq!(s.kv_bits, 8);
        assert!(
            s.resident_bytes * 10 < fb * 3,
            "sealed 8-bit page must shrink below 0.3x: {} vs {}",
            s.resident_bytes,
            fb
        );
        assert_eq!(s.block_bytes, s.resident_bytes, "one sealed page resident");

        // What sealed reads return for layer 1 ...
        let mut sealed_k = vec![0.0f32; bs * d];
        match pool.segment(a, 1, bs) {
            KvSegment::Quant { k, rows, .. } => {
                assert_eq!(rows, bs);
                kv_dequant_scalar(&k, 0, &mut sealed_k);
            }
            KvSegment::F32(..) => panic!("expected a quantized segment"),
        }
        // ... must be bitwise what staging holds after a reopening write
        // to a *different* layer.
        let one_row: Vec<f32> = (0..d).map(|i| 0.5 - i as f32 * 0.01).collect();
        pool.write_rows(a, 0, 0, &one_row, &one_row);
        assert!(!pool.is_sealed(a));
        assert_eq!(pool.k_rows(a, 1, 0, bs), &sealed_k[..]);
        assert_eq!(pool.stats().resident_bytes, fb, "reopened page costs f32 bytes");
    }

    #[test]
    fn export_import_roundtrips_staged_and_sealed() {
        let (layers, d, bs, group) = (2usize, 8usize, 4usize, 8usize);
        let mut pool =
            BlockPool::with_layout(layers, d, bs, 4, KvLayout::Quant { bits: 4, group });
        let a = pool.try_alloc().unwrap();
        for layer in 0..layers {
            let k: Vec<f32> = (0..bs * d).map(|i| (i as f32 * 0.7 + layer as f32).sin()).collect();
            let v: Vec<f32> = (0..bs * d).map(|i| (i as f32 * 0.3 - layer as f32).cos()).collect();
            pool.write_rows(a, layer, 0, &k, &v);
        }

        // staged roundtrip: restored planes are bit-identical
        let staged = pool.export_block(a);
        assert_eq!(staged[0], 0);
        assert_eq!(staged.len(), pool.max_export_bytes());
        let b = pool.try_alloc().unwrap();
        pool.import_block(b, &staged).unwrap();
        assert_eq!(pool.k_rows(b, 1, 0, bs), pool.k_rows(a, 1, 0, bs));
        assert_eq!(pool.v_rows(b, 0, 0, bs), pool.v_rows(a, 0, 0, bs));
        assert_eq!(pool.export_block(b), staged, "re-export is byte-identical");

        // sealed roundtrip: codes + grid survive verbatim
        pool.seal_block(a);
        let sealed = pool.export_block(a);
        assert_eq!(sealed[0], 1);
        assert!(sealed.len() < staged.len(), "sealed record is compressed");
        let c = pool.try_alloc().unwrap();
        pool.import_block(c, &sealed).unwrap();
        assert!(pool.is_sealed(c));
        assert_eq!(pool.export_block(c), sealed, "re-export is byte-identical");

        // malformed records are rejected, not panicked on
        let d2 = pool.try_alloc().unwrap();
        assert!(pool.import_block(d2, &[]).is_err());
        assert!(pool.import_block(d2, &sealed[..sealed.len() - 1]).is_err());
        assert!(pool.import_block(d2, &[9, 1, 2]).is_err());
    }

    #[test]
    fn recycled_sealed_page_resets_to_staging() {
        let mut pool = BlockPool::with_layout(1, 8, 2, 2, KvLayout::Quant { bits: 4, group: 8 });
        let a = pool.try_alloc().unwrap();
        pool.write_rows(a, 0, 0, &[1.0; 16], &[2.0; 16]);
        pool.seal_block(a);
        pool.release(a);
        let b = pool.try_alloc().unwrap();
        assert_eq!(b, a, "free-listed page is reused");
        assert!(!pool.is_sealed(b), "recycled page is reset to staging");
        assert_eq!(pool.stats().resident_bytes, pool.f32_block_bytes());
    }
}
