//! Runtime adapter registry: named [`AdapterSet`]s served over one shared
//! packed base.
//!
//! The engine owns one registry. Adapters enter it at boot
//! (`serve --adapter NAME=PATH`) or at runtime via the line protocol's
//! `{"cmd":"adapter","op":"load",...}`; requests route to one by name.
//! Entries are refcounted by in-flight sequences: `acquire` at admission,
//! `release` at finish/evict/cancel. Unloading an adapter with live
//! sequences marks it draining — no new requests may route to it, and the
//! entry is dropped when the last sequence releases it.

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::infer::{AdapterSet, ADAPTER_SLOTS};
use crate::model::ModelConfig;

/// One registry entry's public snapshot, as reported in the `stats` frame
/// and the bench report.
#[derive(Debug, Clone)]
pub struct AdapterStat {
    pub name: String,
    pub rank: usize,
    pub n_adapted: usize,
    pub resident_bytes: usize,
    /// In-flight sequences currently routed to this adapter.
    pub refs: usize,
    /// Total tokens emitted by sequences routed to this adapter.
    pub tokens: u64,
    /// Unload requested but deferred until `refs` drains to 0.
    pub draining: bool,
    /// Estimated extra FLOPs of the low-rank delta GEMMs relative to the
    /// shared base GEMMs: sum 2r(d_in+d_out) / sum 2*d_in*d_out.
    pub delta_overhead: f64,
}

struct Entry {
    set: Arc<AdapterSet>,
    refs: usize,
    tokens: u64,
    draining: bool,
    delta_overhead: f64,
}

/// Refcounted name -> [`AdapterSet`] map owned by the serve engine.
pub struct AdapterRegistry {
    cfg: ModelConfig,
    entries: HashMap<String, Entry>,
    /// Insertion order, so stats frames are deterministic.
    order: Vec<String>,
    /// Tokens emitted by sequences on the model's default (baseline) path.
    baseline_tokens: u64,
}

/// FLOP fraction the per-sequence delta GEMMs add on top of the shared
/// base GEMMs for one token: sum over adapted linears of 2r(d_in+d_out),
/// over sum over ALL linears of 2*d_in*d_out.
pub fn delta_overhead(set: &AdapterSet, cfg: &ModelConfig) -> f64 {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let shapes: [(usize, usize); ADAPTER_SLOTS] =
        [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)];
    // base counts every linear whether adapted or not: the shared GEMM runs
    // regardless, and the fraction answers "how much slower than baseline".
    let per_block: f64 = shapes.iter().map(|&(i, o)| 2.0 * i as f64 * o as f64).sum();
    let base = per_block * cfg.n_layers as f64;
    let mut delta = 0f64;
    for block in &set.layers {
        for (slot, ad) in block.iter().enumerate() {
            if let Some(ad) = ad {
                let (d_in, d_out) = shapes[slot];
                delta += 2.0 * ad.a.cols() as f64 * (d_in + d_out) as f64;
            }
        }
    }
    if base == 0.0 {
        0.0
    } else {
        delta / base
    }
}

impl AdapterRegistry {
    pub fn new(cfg: ModelConfig) -> Self {
        AdapterRegistry { cfg, entries: HashMap::new(), order: Vec::new(), baseline_tokens: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Register `set` under its own name. Rejects duplicates, including a
    /// same-named adapter still draining.
    pub fn load(&mut self, set: AdapterSet) -> Result<()> {
        let name = set.name.clone();
        if name.is_empty() {
            return Err(Error::config("adapter name must be non-empty"));
        }
        if let Some(e) = self.entries.get(&name) {
            return Err(Error::config(if e.draining {
                format!("adapter '{name}' is draining; retry after unload completes")
            } else {
                format!("adapter '{name}' already loaded")
            }));
        }
        let overhead = delta_overhead(&set, &self.cfg);
        self.entries.insert(
            name.clone(),
            Entry {
                set: Arc::new(set),
                refs: 0,
                tokens: 0,
                draining: false,
                delta_overhead: overhead,
            },
        );
        self.order.push(name);
        Ok(())
    }

    /// Unload by name. Returns `Ok(true)` if removed immediately,
    /// `Ok(false)` if deferred until in-flight sequences drain.
    pub fn unload(&mut self, name: &str) -> Result<bool> {
        let e = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::config(format!("unknown adapter '{name}'")))?;
        if e.refs == 0 {
            self.entries.remove(name);
            self.order.retain(|n| n != name);
            Ok(true)
        } else {
            e.draining = true;
            Ok(false)
        }
    }

    /// Resolve + refcount an adapter for a newly admitted sequence.
    /// Draining adapters refuse new sequences.
    pub fn acquire(&mut self, name: &str) -> Result<Arc<AdapterSet>> {
        let e = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::config(format!("unknown adapter '{name}'")))?;
        if e.draining {
            return Err(Error::config(format!("adapter '{name}' is draining")));
        }
        e.refs += 1;
        Ok(Arc::clone(&e.set))
    }

    /// Drop one sequence's hold. Completes a deferred unload when the last
    /// reference drains. Unknown names are ignored (the entry may already
    /// have been force-removed).
    pub fn release(&mut self, name: &str) {
        let done = match self.entries.get_mut(name) {
            Some(e) => {
                e.refs = e.refs.saturating_sub(1);
                e.draining && e.refs == 0
            }
            None => false,
        };
        if done {
            self.entries.remove(name);
            self.order.retain(|n| n != name);
        }
    }

    /// Rebuild per-entry refcounts from the surviving sequences' routes
    /// (panic recovery — the incremental acquire/release bookkeeping
    /// cannot be trusted after an unwind mid-step).  Draining entries
    /// whose last holder vanished complete their deferred unload.
    pub fn rebuild_refs<'a>(&mut self, routes: impl Iterator<Item = &'a str>) {
        for e in self.entries.values_mut() {
            e.refs = 0;
        }
        for name in routes {
            if let Some(e) = self.entries.get_mut(name) {
                e.refs += 1;
            }
        }
        let done: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| e.draining && e.refs == 0)
            .map(|(n, _)| n.clone())
            .collect();
        for name in done {
            self.entries.remove(&name);
            self.order.retain(|n| *n != name);
        }
    }

    /// Attribute `n` emitted tokens to `name` (or the baseline when `None`).
    pub fn count_tokens(&mut self, name: Option<&str>, n: u64) {
        match name {
            Some(name) => {
                if let Some(e) = self.entries.get_mut(name) {
                    e.tokens += n;
                }
            }
            None => self.baseline_tokens += n,
        }
    }

    pub fn baseline_tokens(&self) -> u64 {
        self.baseline_tokens
    }

    /// Snapshot every entry in load order.
    pub fn stats(&self) -> Vec<AdapterStat> {
        self.order
            .iter()
            .filter_map(|name| {
                self.entries.get(name).map(|e| AdapterStat {
                    name: name.clone(),
                    rank: e.set.rank(),
                    n_adapted: e.set.n_adapted(),
                    resident_bytes: e.set.resident_bytes(),
                    refs: e.refs,
                    tokens: e.tokens,
                    draining: e.draining,
                    delta_overhead: e.delta_overhead,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Adapter;
    use crate::tensor::{Rng, Tensor};

    fn tiny_set(name: &str, rng: &mut Rng) -> AdapterSet {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut layers: Vec<[Option<Adapter>; ADAPTER_SLOTS]> = Vec::new();
        for _ in 0..cfg.n_layers {
            let mut block: [Option<Adapter>; ADAPTER_SLOTS] = Default::default();
            block[0] = Some(Adapter {
                a: Tensor::randn(&[cfg.d_model, 2], 0.1, rng),
                b_t: Tensor::randn(&[2, cfg.d_model], 0.1, rng),
                scale: 1.0,
                col_scale: None,
            });
            layers.push(block);
        }
        AdapterSet { name: name.to_string(), layers }
    }

    #[test]
    fn load_resolve_unload() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(3);
        let mut reg = AdapterRegistry::new(cfg);
        assert!(reg.is_empty());
        reg.load(tiny_set("a", &mut rng)).unwrap();
        reg.load(tiny_set("b", &mut rng)).unwrap();
        assert_eq!(reg.len(), 2);
        // duplicate name rejected
        assert!(reg.load(tiny_set("a", &mut rng)).is_err());
        // unknown names error on acquire/unload
        assert!(reg.acquire("nope").is_err());
        assert!(reg.unload("nope").is_err());
        // idle unload removes immediately
        assert!(reg.unload("b").unwrap());
        assert_eq!(reg.len(), 1);
        let got = reg.acquire("a").unwrap();
        assert_eq!(got.name, "a");
        reg.release("a");
        assert!(reg.unload("a").unwrap());
        assert!(reg.is_empty());
    }

    #[test]
    fn unload_defers_until_drained() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(4);
        let mut reg = AdapterRegistry::new(cfg);
        reg.load(tiny_set("a", &mut rng)).unwrap();
        let _held = reg.acquire("a").unwrap();
        let _held2 = reg.acquire("a").unwrap();
        // two holders -> unload defers
        assert!(!reg.unload("a").unwrap());
        assert!(reg.stats()[0].draining);
        // draining adapters refuse new sequences and reloads
        assert!(reg.acquire("a").is_err());
        assert!(reg.load(tiny_set("a", &mut rng)).is_err());
        reg.release("a");
        assert_eq!(reg.len(), 1, "still one holder");
        reg.release("a");
        assert!(reg.is_empty(), "last release completes the unload");
        // releasing an already-removed name is a no-op
        reg.release("a");
    }

    #[test]
    fn token_attribution_and_stats() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(5);
        let mut reg = AdapterRegistry::new(cfg);
        reg.load(tiny_set("a", &mut rng)).unwrap();
        reg.count_tokens(Some("a"), 5);
        reg.count_tokens(Some("a"), 2);
        reg.count_tokens(None, 3);
        reg.count_tokens(Some("ghost"), 9); // silently dropped
        let st = reg.stats();
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].tokens, 7);
        assert_eq!(st[0].rank, 2);
        assert_eq!(st[0].n_adapted, 4, "one adapted linear per block");
        assert!(st[0].resident_bytes > 0);
        assert!(st[0].delta_overhead > 0.0 && st[0].delta_overhead < 0.1);
        assert_eq!(reg.baseline_tokens(), 3);
    }

    #[test]
    fn overhead_fraction_matches_hand_count() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut rng = Rng::new(6);
        let set = tiny_set("a", &mut rng);
        let (d, f) = (cfg.d_model, cfg.d_ffn);
        let base = cfg.n_layers as f64
            * (4.0 * 2.0 * (d * d) as f64 + 2.0 * 2.0 * (d * f) as f64 + 2.0 * (f * d) as f64);
        let delta = cfg.n_layers as f64 * 2.0 * 2.0 * (d + d) as f64;
        let got = delta_overhead(&set, &cfg);
        assert!((got - delta / base).abs() < 1e-12, "got {got}, want {}", delta / base);
    }
}
