//! The serving subsystem: KV-cached incremental decoding behind a
//! continuous-batching token server.
//!
//! Built on `infer`'s packed-weight engine, this module turns the
//! O(T^2) per-token decode of PR 1 into a production-shaped loop:
//!
//! * [`kv`] — pre-allocated per-sequence K/V buffers ([`KvCache`]) and a
//!   recycling [`KvPool`].
//! * [`decode`] — `PackedModel::forward_chunk` (prefill) and
//!   `PackedModel::forward_step` (one batched decode step), plus
//!   [`decode::generate`] / [`decode::generate_recompute`] — the cached
//!   path is bit-identical to full-prefix recompute.
//! * [`sampling`] — seeded temperature / top-k / top-p next to greedy.
//! * [`scheduler`] — step-granular continuous batching with per-request
//!   stats.
//! * [`json`] / [`protocol`] — the newline-delimited JSON line protocol.
//! * [`server`] — the long-lived `repro serve` TCP loop (std threads +
//!   channels).
//! * [`loadgen`] — the `repro bench-serve` concurrent load generator.

pub mod decode;
pub mod json;
pub mod kv;
pub mod loadgen;
pub mod protocol;
pub mod sampling;
pub mod scheduler;
pub mod server;

pub use kv::{KvCache, KvPool};
pub use sampling::SamplingParams;
pub use scheduler::{FinishReason, GenRequest, RequestStats, SchedConfig, Scheduler, StepEvent};
pub use server::{ServeOptions, Server};
