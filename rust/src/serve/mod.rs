//! The serving subsystem: paged KV memory + KV-cached incremental
//! decoding behind a continuous-batching token server.
//!
//! Built on `infer`'s packed-weight engine, this module turns the
//! O(T^2) per-token decode of PR 1 into a production-shaped loop:
//!
//! * [`block`] — the model-wide [`BlockPool`] of fixed-size KV pages
//!   (free list, refcounts, high-water stats), with an optional
//!   group-wise affine-quantized page layout (`--kv-bits 8|4`): full
//!   pages are sealed into packed codes and dequantized inside the
//!   attention walk, ~4x/8x more sequences per block budget.
//! * [`paged`] — per-sequence [`PagedKvCache`] block tables with
//!   copy-on-write prompt-prefix sharing; grows one page at a time.
//! * [`kv`] — the flat per-sequence slab ([`KvCache`] + recycling
//!   [`KvPool`]), retained as the bit-exact equivalence oracle for the
//!   paged layout.
//! * [`decode`] — chunk prefill / batched decode steps over either
//!   layout (one shared segment-walking attention core, so paged ==
//!   flat bit for bit), batched multi-sequence prefill, plus
//!   [`decode::generate`] / [`decode::generate_paged`] /
//!   [`decode::generate_recompute`].
//! * [`sampling`] — seeded temperature / top-k / top-p next to greedy.
//! * [`scheduler`] — step-granular continuous batching: admission by
//!   block budget, same-tick admissions prefilled in one batched pass,
//!   prefix-shared pages across requests, per-request stats.
//! * [`spec`] — speculative decoding: a draft model proposes `k` tokens
//!   per cycle, the target verifies them in ONE multi-position pass
//!   (`forward_verify_paged`), rejected positions are popped with the
//!   refcount-aware `truncate` primitives — emitted streams stay
//!   **bitwise identical** to plain decode for greedy and seeded
//!   sampling alike; per-sequence fallback on draft-pool exhaustion or
//!   acceptance collapse.
//! * [`adapters`] — the refcounted runtime [`AdapterRegistry`]: named
//!   LoRA/DoRA [`crate::infer::AdapterSet`]s served over one shared 2-bit
//!   base, loaded at boot (`--adapter NAME=PATH`) or at runtime
//!   (`{"cmd":"adapter","op":"load"}`), with deferred unload while
//!   sequences are in flight and per-adapter token accounting.
//! * [`json`] / [`protocol`] — the newline-delimited JSON line protocol
//!   (now incl. `{"cmd":"stats"}` -> KV memory + adapter stats frames,
//!   per-request `"adapter"` routing, the `adapter` command, and the
//!   `{"cmd":"metrics"}` / `{"cmd":"trace"}` telemetry queries).
//! * [`server`] — the long-lived `repro serve` TCP loop (std threads +
//!   channels), plus the optional Prometheus `/metrics` listener and the
//!   `--trace-log` tick journal.  Fault-tolerant: bounded submission +
//!   per-connection output queues with `overloaded` rejections and
//!   slow-reader eviction, per-request deadlines, `catch_unwind` panic
//!   quarantine with pool/registry rebuild, and graceful drain on
//!   SIGINT/SIGTERM or `{"cmd":"drain"}`.  A deterministic
//!   fault-injection harness ([`crate::obs::fault`], `--fault` /
//!   `REPRO_FAULT`) exercises all of it; unarmed, every path is
//!   byte-identical to the fault-free build.
//! * [`tier`] — tiered KV: a CRC-checked spill file behind the block
//!   pool (`--kv-spill PATH`), where pages move **verbatim** so restored
//!   state is bit-identical.  Feeds three schedulers' worth of headroom:
//!   preempt-to-spill instead of capacity finishes under block
//!   exhaustion, `"session"`-tagged suspend/resume without re-prefill
//!   across connections, and a content-keyed persistent prefix store
//!   (`--prefix-store`) that extends CoW prefix sharing across
//!   connections and time with promote-on-read from disk.
//! * [`loadgen`] — the `repro bench-serve` concurrent load generator
//!   (common-prefix prompts to exercise sharing, KV stats scrape,
//!   mid-run `--sample-ms` batch/occupancy series, `BENCH_serve.json`);
//!   retries `overloaded` rejections with jittered backoff and survives
//!   connection loss instead of dying on the first error.
//!
//! Telemetry itself (metric registry, tick/request tracing, kernel
//! profiling, Prometheus rendering) lives in [`crate::obs`]; the
//! scheduler writes into one shared [`crate::obs::Telemetry`] and every
//! exposition path reads from it.  Nothing in `obs` touches compute or
//! RNG state, so token streams are byte-identical with telemetry on.

pub mod adapters;
pub mod block;
pub mod decode;
pub mod json;
pub mod kv;
pub mod loadgen;
pub mod paged;
pub mod protocol;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod spec;
pub mod tier;

pub use adapters::{AdapterRegistry, AdapterStat};
pub use block::{BlockPool, KvLayout, KvSegment, KvStats};
pub use kv::{KvCache, KvPool};
pub use paged::PagedKvCache;
pub use sampling::SamplingParams;
pub use scheduler::{FinishReason, GenRequest, RequestStats, SchedConfig, Scheduler, StepEvent};
pub use server::{ServeOptions, Server};
pub use spec::{generate_speculative, SpecGenReport, SpecStats};
pub use tier::{SessionEntry, SpillFile, TierStats, TieredKv};
